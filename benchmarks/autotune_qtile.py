"""q_tile autotune sweep CLI: time the fused walk per candidate tile and
height, emit one JSON row per (height, tile), and print the winners as a
``kernels.autotune.BAKED``-style table ready to paste back into the repo.

Run it through the compiled harness so the winners describe the mode that
matters::

    ./run_compiled.sh benchmarks/autotune_qtile.py --heights 5,7,9
    REPRO_PALLAS_AUTOTUNE=.autotune.json \\
        ./run_compiled.sh benchmarks/autotune_qtile.py --heights 7

With ``REPRO_PALLAS_AUTOTUNE`` set, the winners are also merged into that
cache file, and every later ``q_tile=None`` walk in the same environment
picks them up automatically (``ops.default_q_tile``).  ``--payload-bits``
adds an int64 map-mode sweep leg (needs JAX_ENABLE_X64).
"""

from __future__ import annotations

import argparse

from benchmarks.common import DEFAULT_SEED, emit

DEFAULT_HEIGHTS = (5, 7, 9)


def run(heights, *, batch: int = 1024, n_keys: int = 50_000,
        repeats: int = 3, iters: int = 10, payload_bits: int = 0,
        seed: int = DEFAULT_SEED, write_cache: bool = True):
    from repro.kernels import autotune
    from repro.kernels.ops import default_interpret

    compiled = not default_interpret()
    bits = 64 if payload_bits else 32
    rows, winners = [], {}
    for h in heights:
        best, timings = autotune.sweep_height(
            h, batch=batch, n_keys=n_keys, repeats=repeats, iters=iters,
            payload_bits=payload_bits, seed=seed)
        for tile, sec in sorted(timings.items()):
            rows.append(emit({
                "bench": "autotune_qtile", "backend": "deltatree",
                "engine": "lockstep", "height": h, "q_tile": tile,
                "bits": bits, "batch": batch, "seed": seed,
                "seconds": round(sec, 6), "winner": tile == best}))
        winners[autotune._key(h, compiled, bits)] = best
    if write_cache:
        path = autotune.save_cache(winners)
        if path:
            print(f"# autotune cache updated -> {path}", flush=True)
    mode = "compiled" if compiled else "interpret"
    print(f"# BAKED entries ({mode}, {bits}-bit):", flush=True)
    for h in heights:
        key = autotune._key(h, compiled, bits)
        print(f"#     ({h}, {compiled}, {bits}): {winners[key]},", flush=True)
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False, heights=DEFAULT_HEIGHTS, payload_bits=0):
    del backend, engine  # single-backend sweep by construction
    if smoke:
        return run((5,), batch=256, n_keys=2_000, repeats=1, iters=2,
                   payload_bits=payload_bits, seed=seed)
    if quick:
        return run(heights, payload_bits=payload_bits, seed=seed)
    return run(heights, batch=4096, n_keys=500_000, repeats=5, iters=20,
               payload_bits=payload_bits, seed=seed)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--heights", default=None,
                    help="comma-separated tree heights (default 5,7,9)")
    ap.add_argument("--payload-bits", type=int, default=0,
                    help="nonzero adds the int64 map-mode leg "
                         "(requires JAX_ENABLE_X64)")
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    args = ap.parse_args()
    hs = (tuple(int(x) for x in args.heights.split(","))
          if args.heights else DEFAULT_HEIGHTS)
    main(quick=not args.full, seed=args.seed, smoke=args.smoke,
         heights=hs, payload_bits=args.payload_bits)
