"""DeltaForest scaling sweep: shard count x batch size vs single-tree baseline.

For each (shards, batch) point the same randomized mixed workload (search +
insert/delete at ``update_pct``) runs against the ``forest`` backend and
against the ``deltatree`` baseline built from the same initial key set —
both through ``make_index`` — with the jit warm.  Every point additionally
runs the lockstep engine through both forest dispatches — the dense
per-shard vmap reference (``fused=False``) and the fused cross-shard
frontier — so each sweep point records a ``"dispatch": "fused"`` row with
``speedup_vs_vmap``.  Emits one JSON row per run on stdout
(machine-parsable, one line each), e.g.::

    {"bench": "forest_scale", "shards": 4, "batch": 1024, "seed": 0,
     "engine": "lockstep", "dispatch": "fused", ...
     "ops_per_s": ..., "baseline_ops_per_s": ..., "speedup": ...,
     "speedup_vs_vmap": ...}

On a single CPU device the forest's "shards" mesh degenerates to vmap, so
speedups here measure routing overhead + smaller-tree effects; run with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (or real accelerators)
to exercise true shard_map fan-out.
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    DEFAULT_SEED, add_common_args, backend_kwargs, emit, run_index,
)

KEY_MAX = 2_000_000


def run(shard_counts, batches, initial_size: int, total_ops: int,
        update_pct: float, height: int = 7, seed: int = DEFAULT_SEED,
        engine: str | None = None):
    import jax

    rng = np.random.default_rng(seed)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    rows = []
    for batch in batches:
        base = run_index("deltatree", vals, KEY_MAX, update_pct, batch,
                         total_ops, seed=seed, engine=engine,
                         **backend_kwargs("deltatree", vals.size,
                                          key_max=KEY_MAX, height=height,
                                          total_ops=total_ops))
        for shards in shard_counts:
            kw = backend_kwargs("forest", vals.size, key_max=KEY_MAX,
                                height=height, num_shards=shards,
                                total_ops=total_ops)
            point = {
                "bench": "forest_scale",
                "shards": shards,
                "batch": batch,
                "seed": seed,
                "devices": jax.device_count(),
                "update_pct": update_pct,
                "initial_keys": int(vals.size),
                "baseline_ops_per_s": base["ops_per_s"],
            }
            if engine != "lockstep":
                # --engine lockstep would duplicate the explicit fused
                # leg below (same config, same seed) — skip the extra
                # timed run and the ambiguous second "fused" row
                perf = run_index("forest", vals, KEY_MAX, update_pct, batch,
                                 total_ops, seed=seed, engine=engine, **kw)
                rows.append(emit({
                    **point,
                    "engine": perf["engine"],
                    "dispatch": perf["dispatch"],
                    "ops_per_s": perf["ops_per_s"],
                    "speedup": round(perf["ops_per_s"] / base["ops_per_s"],
                                     3),
                }))
            # fused-vs-vmap pair: the same lockstep workload through the
            # dense per-shard dispatch and the fused cross-shard frontier
            # (TreeConfig.engine selects fused by default; fused=False
            # pins the reference) — the dispatch-level speedup is the
            # tentpole's own perf row
            vmap_r = run_index("forest", vals, KEY_MAX, update_pct, batch,
                               total_ops, seed=seed, engine="lockstep",
                               fused=False, **kw)
            rows.append(emit({
                **point, "engine": "lockstep", "dispatch": "vmap",
                "ops_per_s": vmap_r["ops_per_s"],
                "speedup": round(vmap_r["ops_per_s"] / base["ops_per_s"], 3),
            }))
            fused_r = run_index("forest", vals, KEY_MAX, update_pct, batch,
                                total_ops, seed=seed, engine="lockstep",
                                fused=True, **kw)
            rows.append(emit({
                **point, "engine": "lockstep", "dispatch": "fused",
                "ops_per_s": fused_r["ops_per_s"],
                "speedup": round(fused_r["ops_per_s"] / base["ops_per_s"], 3),
                "speedup_vs_vmap": round(
                    fused_r["ops_per_s"] / vmap_r["ops_per_s"], 3),
            }))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    del backend  # this sweep is forest-vs-deltatree by construction
    if smoke:
        return run(shard_counts=(2,), batches=(64,), initial_size=2_000,
                   total_ops=128, update_pct=5.0, seed=seed, engine=engine)
    if quick:
        return run(shard_counts=(1, 2, 4), batches=(256, 1024),
                   initial_size=50_000, total_ops=8_000, update_pct=5.0,
                   seed=seed, engine=engine)
    return run(shard_counts=(1, 2, 4, 8), batches=(256, 1024, 4096),
               initial_size=500_000, total_ops=100_000, update_pct=5.0,
               seed=seed, engine=engine)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, engine=args.engine,
         smoke=args.smoke)
