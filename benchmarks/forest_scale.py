"""DeltaForest scaling sweep: shard count x batch size vs single-tree baseline.

For each (shards, batch) point the same randomized mixed workload (search +
insert/delete at ``update_pct``) runs against a DeltaForest and against the
single-ΔTree baseline built from the same initial key set, with the jit
warm.  Emits one JSON row per point on stdout (machine-parsable, one line
each), e.g.::

    {"bench": "forest_scale", "shards": 4, "batch": 1024, ...
     "ops_per_s": ..., "baseline_ops_per_s": ..., "speedup": ...}

On a single CPU device the forest's "shards" mesh degenerates to vmap, so
speedups here measure routing overhead + smaller-tree effects; run with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (or real accelerators)
to exercise true shard_map fan-out.
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import mixed_kinds, run_deltatree
import repro.distributed as D
from repro.core import TreeConfig

KEY_MAX = 2_000_000


def _forest_cfg(num_shards: int, height: int, n_keys: int) -> D.ForestConfig:
    per_shard = max(64, int(4 * n_keys / num_shards / (2 ** (height - 1))))
    return D.ForestConfig(
        num_shards=num_shards,
        tree=TreeConfig(height=height, max_dnodes=per_shard, buf_cap=32,
                        max_rounds=256),
        key_max=KEY_MAX,
    )


def run_forest(num_shards: int, height: int, initial: np.ndarray,
               update_pct: float, batch: int, total_ops: int,
               seed: int = 0) -> dict:
    fcfg = _forest_cfg(num_shards, height, initial.size)
    forest = D.bulk_build(fcfg, initial)
    rng = np.random.default_rng(seed)
    # warmup compile — two feedback iterations: the first update's output
    # carries the "shards"-mesh sharding (the host-built input doesn't), so
    # the second call retraces once; after that the jit cache is steady
    for _ in range(2):
        kinds = mixed_kinds(rng, batch, update_pct)
        keys = rng.integers(1, KEY_MAX, size=batch).astype(np.int32)
        f, _ = D.search_batch(fcfg, forest, jnp.asarray(keys))
        f.block_until_ready()
        if update_pct > 0:
            forest, r, _ = D.update_batch(fcfg, forest, jnp.asarray(kinds),
                                          jnp.asarray(keys))
            r.block_until_ready()

    steps = max(total_ops // batch, 1)
    n_search = n_update = 0
    any_update = update_pct > 0
    t0 = time.perf_counter()
    for _ in range(steps):
        kinds = mixed_kinds(rng, batch, update_pct)
        keys = rng.integers(1, KEY_MAX, size=batch).astype(np.int32)
        f, _ = D.search_batch(fcfg, forest, jnp.asarray(keys))
        n_search += int((kinds == 0).sum())
        if any_update:
            forest, r, _ = D.update_batch(fcfg, forest, jnp.asarray(kinds),
                                          jnp.asarray(keys))
            n_update += int((kinds != 0).sum())
    if any_update:
        forest.trees.value.block_until_ready()
    else:
        f.block_until_ready()
    dt = time.perf_counter() - t0
    return {"ops_per_s": (n_search + n_update) / dt, "seconds": dt,
            "n_search": n_search, "n_update": n_update}


def run(shard_counts, batches, initial_size: int, total_ops: int,
        update_pct: float, height: int = 7):
    import jax

    rng = np.random.default_rng(7)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    rows = []
    baseline_dnodes = max(64, int(4 * vals.size / (2 ** (height - 1))))
    for batch in batches:
        base = run_deltatree(height, vals, KEY_MAX, update_pct, batch,
                             total_ops, max_dnodes=baseline_dnodes)
        for shards in shard_counts:
            perf = run_forest(shards, height, vals, update_pct, batch,
                              total_ops)
            row = {
                "bench": "forest_scale",
                "shards": shards,
                "batch": batch,
                "devices": jax.device_count(),
                "update_pct": update_pct,
                "initial_keys": int(vals.size),
                "ops_per_s": round(perf["ops_per_s"], 1),
                "baseline_ops_per_s": round(base["ops_per_s"], 1),
                "speedup": round(perf["ops_per_s"] / base["ops_per_s"], 3),
            }
            rows.append(row)
            print(json.dumps(row), flush=True)
    return rows


def main(quick=True):
    if quick:
        return run(shard_counts=(1, 2, 4), batches=(256, 1024),
                   initial_size=50_000, total_ops=8_000, update_pct=5.0)
    return run(shard_counts=(1, 2, 4, 8), batches=(256, 1024, 4096),
               initial_size=500_000, total_ops=100_000, update_pct=5.0)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(quick=not args.full)
