# One function per paper table/figure. Every benchmark runs its structures
# through the `repro.api.make_index` factory and prints one JSON row per
# result line (each row records `seed` + `backend` for reproducibility).
# Default is the quick profile (CPU-friendly); --full is the paper-scale
# sweep; --smoke runs everything at tiny sizes (CI bitrot guard);
# --backend narrows every benchmark to one registered backend; --seed
# reseeds every RNG.  All rows from one invocation are additionally
# consolidated into BENCH_<timestamp>.json at the repo root — every row
# stamped with its suite, backend, engine and maintenance policy — so the
# perf trajectory stays recorded across PRs.
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _in_x64_subprocess(module: str, quick: bool, seed: int,
                       backend: str | None, engine: str | None,
                       smoke: bool = False):
    """serve bench needs JAX_ENABLE_X64; run isolated.  Returns the rows
    parsed back off the child's stdout (one JSON object per line)."""
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env.setdefault("PYTHONPATH", "src")
    code = (f"from {module} import main; "
            f"main(quick={quick}, seed={seed}, backend={backend!r}, "
            f"engine={engine!r}, smoke={smoke})")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise RuntimeError(f"{module} failed")
    rows = []
    for line in out.stdout.splitlines():
        line = line.strip()
        if line.startswith("{"):
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return rows


def _consolidate(rows: list, args: dict) -> str:
    """Write BENCH_<timestamp>.json at the repo root: run metadata plus
    every row stamped with suite/backend/engine/maintenance.  Smoke runs
    get the gitignored ``BENCH_SMOKE_`` prefix — their numbers are
    meaningless and must not pollute the committed perf trajectory.

    The top-level ``meta`` block is this process's execution stamp
    (`benchmarks.common.exec_meta`); per-row stamps still win — the serve
    suite's rows come from an x64 subprocess whose mode differs."""
    from benchmarks.common import exec_meta

    stamped = []
    for row in rows:
        r = dict(row)
        r.setdefault("suite", r.get("bench", "unknown"))
        r.setdefault("backend", None)
        r.setdefault("engine", None)
        r.setdefault("maintenance", None if r.get("skipped") else "eager")
        stamped.append(r)
    ts = time.strftime("%Y%m%d_%H%M%S")
    prefix = "BENCH_SMOKE_" if args.get("smoke") else "BENCH_"
    path = os.path.join(REPO_ROOT, f"{prefix}{ts}.json")
    with open(path, "w") as f:
        json.dump({"timestamp": ts, "args": args, "meta": exec_meta(),
                   "rows": stamped}, f, indent=1)
    print(f"# consolidated {len(stamped)} rows -> {path}", flush=True)
    return path


def main() -> None:
    from benchmarks.common import add_common_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--compiled", action="store_true",
                    help="force compiled kernels (REPRO_PALLAS_INTERPRET=0 "
                         "for this process and every benchmark subprocess): "
                         "Pallas lowered on TPU, the XLA-compiled fused "
                         "mirrors elsewhere — no interpreter tax. Rows "
                         "stamp meta interpret=false; run_compiled.sh is "
                         "the full launch harness around this flag")
    ap.add_argument("--only", default=None,
                    help="fig11|fig12|table1|ub_sweep|serve|serve_trace"
                         "|forest|engines|maint")
    ap.add_argument("--maintenance", default=None,
                    help="maint suite: run only this policy")
    ap.add_argument("--trace-dir", default=None,
                    help="capture an xprof trace of the whole run into "
                         "this logdir (repro.obs.trace.capture; spans "
                         "need REPRO_TRACE=1 in the environment)")
    add_common_args(ap)
    args, _ = ap.parse_known_args()
    if args.compiled:
        # before any kernel-mode resolution or exec_meta stamp; inherited
        # by the serve/serve_trace x64 subprocesses via their env copy
        os.environ["REPRO_PALLAS_INTERPRET"] = "0"
    quick = not args.full
    seed, backend, engine = args.seed, args.backend, args.engine
    smoke = args.smoke

    from benchmarks import engine_compare, fig11_small_tree, fig12_big_tree
    from benchmarks import forest_scale, maint_sweep, scan_sweep
    from benchmarks import table1_transfers
    from benchmarks import ub_sweep

    todo = args.only.split(",") if args.only else [
        "table1", "ub_sweep", "fig11", "fig12", "serve", "serve_trace",
        "forest", "engines", "maint", "scan"]
    rows: list = []

    def add(suite, got):
        if not got:
            return
        if isinstance(got, dict):
            got = [got]
        for r in got:
            r = dict(r)
            r["suite"] = suite
            rows.append(r)

    if args.trace_dir:
        from repro.obs import trace as OT

        # asking for a trace dir IS the span opt-in: turn REPRO_TRACE on
        # for this process and every benchmark subprocess so the chrome-
        # trace timeline below has events even off-TPU (where the xprof
        # capture may have little to sample)
        os.environ.setdefault(OT.ENV, "1")
        cm = OT.capture(args.trace_dir)
    else:
        import contextlib

        cm = contextlib.nullcontext()

    common = dict(quick=quick, seed=seed, backend=backend, engine=engine,
                  smoke=smoke)
    with cm:
        if "table1" in todo:
            add("table1", table1_transfers.main(**common))
        if "ub_sweep" in todo:
            add("ub_sweep", ub_sweep.main(**common))
        if "fig11" in todo:
            add("fig11", fig11_small_tree.main(**common))
        if "fig12" in todo:
            add("fig12", fig12_big_tree.main(**common))
        if "serve" in todo:
            add("serve", _in_x64_subprocess("benchmarks.serve_paged", quick,
                                            seed, backend, engine, smoke))
        if "serve_trace" in todo:
            add("serve_trace", _in_x64_subprocess("benchmarks.serve_trace",
                                                  quick, seed, backend,
                                                  engine, smoke))
        if "forest" in todo:
            add("forest", forest_scale.main(quick=quick, seed=seed,
                                            engine=engine, smoke=smoke))
        if "engines" in todo:
            add("engines", engine_compare.main(quick=quick, seed=seed,
                                               backend=backend, smoke=smoke))
        if "maint" in todo:
            add("maint", maint_sweep.main(quick=quick, seed=seed,
                                          backend=backend, engine=engine,
                                          maintenance=args.maintenance,
                                          smoke=smoke))
        if "scan" in todo:
            add("scan", scan_sweep.main(quick=quick, seed=seed,
                                        backend=backend, engine=engine,
                                        smoke=smoke))
    if args.trace_dir:
        from repro.obs import trace as OT

        path = os.path.join(args.trace_dir, "chrome_trace.json")
        n = OT.write_chrome_trace(path)
        print(f"# chrome trace: {n} span events -> {path} "
              "(chrome://tracing or ui.perfetto.dev)", flush=True)
    _consolidate(rows, dict(full=args.full, smoke=smoke, seed=seed,
                            backend=backend, engine=engine,
                            only=args.only, compiled=args.compiled))


if __name__ == '__main__':
    main()
