# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV lines (assignment contract). Default is the quick profile (CPU-
# friendly); pass --full for the paper-scale sweep.
from __future__ import annotations

import argparse
import os
import subprocess
import sys


def _in_x64_subprocess(module: str, quick: bool):
    """serve bench needs JAX_ENABLE_X64; run isolated."""
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env.setdefault("PYTHONPATH", "src")
    code = (f"from {module} import main; main(quick={quick})")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise RuntimeError(f"{module} failed")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="fig11|fig12|table1|ub_sweep|serve|forest")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import fig11_small_tree, fig12_big_tree, table1_transfers
    from benchmarks import forest_scale, ub_sweep

    todo = args.only.split(",") if args.only else [
        "table1", "ub_sweep", "fig11", "fig12", "serve", "forest"]
    if "table1" in todo:
        table1_transfers.main(quick=quick)
    if "ub_sweep" in todo:
        ub_sweep.main(quick=quick)
    if "fig11" in todo:
        fig11_small_tree.main(quick=quick)
    if "fig12" in todo:
        fig12_big_tree.main(quick=quick)
    if "serve" in todo:
        _in_x64_subprocess("benchmarks.serve_paged", quick)
    if "forest" in todo:
        forest_scale.main(quick=quick)


if __name__ == '__main__':
    main()
