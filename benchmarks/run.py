# One function per paper table/figure. Every benchmark runs its structures
# through the `repro.api.make_index` factory and prints one JSON row per
# result line (each row records `seed` + `backend` for reproducibility).
# Default is the quick profile (CPU-friendly); --full is the paper-scale
# sweep; --backend narrows every benchmark to one registered backend;
# --seed reseeds every RNG.
from __future__ import annotations

import argparse
import os
import subprocess
import sys


def _in_x64_subprocess(module: str, quick: bool, seed: int,
                       backend: str | None, engine: str | None):
    """serve bench needs JAX_ENABLE_X64; run isolated."""
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env.setdefault("PYTHONPATH", "src")
    code = (f"from {module} import main; "
            f"main(quick={quick}, seed={seed}, backend={backend!r}, "
            f"engine={engine!r})")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise RuntimeError(f"{module} failed")


def main() -> None:
    from benchmarks.common import add_common_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="fig11|fig12|table1|ub_sweep|serve|forest|engines")
    add_common_args(ap)
    args, _ = ap.parse_known_args()
    quick = not args.full
    seed, backend, engine = args.seed, args.backend, args.engine

    from benchmarks import engine_compare, fig11_small_tree, fig12_big_tree
    from benchmarks import forest_scale, table1_transfers, ub_sweep

    todo = args.only.split(",") if args.only else [
        "table1", "ub_sweep", "fig11", "fig12", "serve", "forest", "engines"]
    if "table1" in todo:
        table1_transfers.main(quick=quick, seed=seed, backend=backend,
                              engine=engine)
    if "ub_sweep" in todo:
        ub_sweep.main(quick=quick, seed=seed, backend=backend, engine=engine)
    if "fig11" in todo:
        fig11_small_tree.main(quick=quick, seed=seed, backend=backend,
                              engine=engine)
    if "fig12" in todo:
        fig12_big_tree.main(quick=quick, seed=seed, backend=backend,
                            engine=engine)
    if "serve" in todo:
        _in_x64_subprocess("benchmarks.serve_paged", quick, seed, backend,
                           engine)
    if "forest" in todo:
        forest_scale.main(quick=quick, seed=seed, engine=engine)
    if "engines" in todo:
        engine_compare.main(quick=quick, seed=seed, backend=backend)


if __name__ == '__main__':
    main()
