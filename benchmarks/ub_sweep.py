"""§5 UB selection sweep: ΔNode size ∈ {31, 127, 1023, 8191} — the paper
finds one "page" (127) best on its CPU; on TPU the tradeoff is DMA size vs
tree hops (DESIGN.md §2, claim C4)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_deltatree
from repro.core import TreeConfig, bulk_build
from repro.core.transfers import delta_touch_fn, delta_hops_fn
from repro.core.baselines import count_block_transfers

KEY_MAX = 5_000_000
HEIGHTS = (5, 7, 10, 13)      # UB = 31, 127, 1023, 8191


def run(initial_size: int = 200_000, total_ops: int = 20_000,
        update_pct: float = 5.0):
    rng = np.random.default_rng(45)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    q = rng.integers(1, KEY_MAX, size=200).astype(np.int32)
    rows = []
    for h in HEIGHTS:
        ub = 2**h - 1
        dnodes_needed = max(64, int(4 * vals.size / 2 ** (h - 1)))
        cfg = TreeConfig(height=h, max_dnodes=dnodes_needed, buf_cap=32)
        t = bulk_build(cfg, vals)
        tf = delta_touch_fn(cfg, t)
        hops = delta_hops_fn(cfg, t)
        mean_hops = float(np.mean([hops(int(k)) for k in q]))
        b128 = count_block_transfers(tf, q, 128)
        perf = run_deltatree(h, vals, KEY_MAX, update_pct, 1024, total_ops,
                             max_dnodes=dnodes_needed)
        rows.append((ub, mean_hops, b128, perf["ops_per_s"]))
    return rows


def main(quick=True):
    rows = run(initial_size=100_000 if quick else 500_000,
               total_ops=10_000 if quick else 50_000)
    for ub, hops, b128, ops in rows:
        print(f"ub_sweep/UB{ub}/hops,{hops:.2f},dnode_transfers")
        print(f"ub_sweep/UB{ub}/blocks_B128,{b128:.2f},transfers")
        print(f"ub_sweep/UB{ub}/throughput,{1e6/ops:.3f},{ops:.0f} ops/s")
    return rows


if __name__ == "__main__":
    main(quick=False)
