"""§5 UB selection sweep: ΔNode size ∈ {31, 127, 1023, 8191} — the paper
finds one "page" (127) best on its CPU; on TPU the tradeoff is DMA size vs
tree hops (DESIGN.md §2, claim C4).  ``--backend forest`` sweeps the
per-shard ΔNode size of a DeltaForest instead (same heights)."""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    DEFAULT_SEED, add_common_args, backend_kwargs, emit, run_index,
)
from repro.api import make_index
from repro.core.baselines import count_block_transfers
from repro.core.transfers import delta_hops_fn

KEY_MAX = 5_000_000
HEIGHTS = (5, 7, 10, 13)      # UB = 31, 127, 1023, 8191


def run(initial_size: int = 200_000, total_ops: int = 20_000,
        update_pct: float = 5.0, seed: int = DEFAULT_SEED,
        backend: str | None = None, engine: str | None = None):
    backend = backend or "deltatree"
    if backend not in ("deltatree", "forest"):
        # ΔNode height is meaningless for flat structures — note and skip
        return [emit({"bench": "ub_sweep", "backend": backend,
                      "skipped": "no ΔNode height to sweep"})]
    rng = np.random.default_rng(seed)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    q = rng.integers(1, KEY_MAX, size=200).astype(np.int32)
    rows = []
    for h in HEIGHTS:
        kw = backend_kwargs(backend, vals.size, key_max=KEY_MAX,
                            total_ops=total_ops, height=h)
        row = {"bench": "ub_sweep", "ub": 2**h - 1}
        if backend == "deltatree":
            # transfer profile on the pre-filled tree (ideal-cache model)
            ix = make_index("deltatree", initial=vals, **kw)
            hops = delta_hops_fn(ix.cfg, ix.state)
            row["hops"] = round(float(np.mean([hops(int(k)) for k in q])), 2)
            row["blocks_b128"] = round(
                count_block_transfers(ix.touch_fn(), q, 128), 2)
        perf = run_index(backend, vals, KEY_MAX, update_pct, 1024, total_ops,
                         seed=seed, engine=engine, **kw)
        rows.append(emit({**row, **perf}))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    if smoke:
        return run(initial_size=5_000, total_ops=256, seed=seed,
                   backend=backend, engine=engine)
    return run(initial_size=100_000 if quick else 500_000,
               total_ops=10_000 if quick else 50_000,
               seed=seed, backend=backend, engine=engine)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         engine=args.engine, smoke=args.smoke)
