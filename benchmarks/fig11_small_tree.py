"""Fig. 11 reproduction: small tree (1,023 initial keys), throughput vs
update rate vs concurrency, ΔTree vs AVL/RB/SF analogs (pointer BST),
static vEB (VTMtree) and sorted array."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_baseline, run_deltatree
from repro.core import baselines as BL

KEY_MAX = 5_000_000          # paper: values in (0, 5e6]
INITIAL = 1023
UPDATE_RATES = (0, 1, 5, 10, 20, 100)   # paper: {0,1,3,5,10,20,100}
CONCURRENCY = (64, 256, 1024)           # SPMD batch width (thread analog)


def run(total_ops: int = 50_000, quick: bool = False):
    rng = np.random.default_rng(42)
    initial = np.unique(rng.integers(1, KEY_MAX, size=INITIAL).astype(np.int32))
    rows = []
    rates = UPDATE_RATES[:3] if quick else UPDATE_RATES
    concs = CONCURRENCY[1:2] if quick else CONCURRENCY
    for u in rates:
        for c in concs:
            r = run_deltatree(7, initial, KEY_MAX, u, c, total_ops,
                              max_dnodes=4096)
            rows.append(("deltatree_ub127", u, c, r["ops_per_s"]))
            for Bl in (BL.PointerBST, BL.SortedArray):
                r = run_baseline(Bl, initial, KEY_MAX, u, c, total_ops)
                rows.append((Bl.name, u, c, r["ops_per_s"]))
            if u == 0:  # static vEB cannot update in place (paper's point)
                r = run_baseline(BL.StaticVEB, initial, KEY_MAX, 0, c, total_ops)
                rows.append((BL.StaticVEB.name, u, c, r["ops_per_s"]))
    return rows


def main(quick=True):
    rows = run(quick=quick)
    for name, u, c, ops in rows:
        us = 1e6 / ops
        print(f"fig11/{name}/u{u}/c{c},{us:.3f},{ops:.0f}")
    return rows


if __name__ == "__main__":
    main(quick=False)
