"""Fig. 11 reproduction: small tree (1,023 initial keys), throughput vs
update rate vs concurrency, ΔTree vs AVL/RB/SF analogs (pointer BST),
static vEB (VTMtree) and sorted array — every structure through the same
`make_index` factory (`--backend` narrows to one)."""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    DEFAULT_SEED, add_common_args, backend_kwargs, emit, engine_supported,
    run_index,
)

KEY_MAX = 5_000_000          # paper: values in (0, 5e6]
INITIAL = 1023
UPDATE_RATES = (0, 1, 5, 10, 20, 100)   # paper: {0,1,3,5,10,20,100}
CONCURRENCY = (64, 256, 1024)           # SPMD batch width (thread analog)
DEFAULT_BACKENDS = ("deltatree", "pointer_bst", "sorted_array", "static_veb")


def run(total_ops: int = 50_000, quick: bool = False,
        seed: int = DEFAULT_SEED, backend: str | None = None,
        engine: str | None = None, smoke: bool = False):
    rng = np.random.default_rng(seed)
    initial = np.unique(rng.integers(1, KEY_MAX, size=INITIAL).astype(np.int32))
    rows = []
    rates = UPDATE_RATES[:3] if quick else UPDATE_RATES
    concs = CONCURRENCY[1:2] if quick else CONCURRENCY
    if smoke:
        rates, concs, total_ops = (0, 20), (64,), 192
    names = []
    for name in ((backend,) if backend else DEFAULT_BACKENDS):
        if engine_supported(name, engine):
            names.append(name)
        else:  # one skip row per backend, not per (u, c) point
            rows.append(emit({"bench": "fig11", "backend": name,
                              "engine": engine,
                              "skipped": "engine unsupported"}))
    for u in rates:
        for c in concs:
            for name in names:
                if name == "static_veb" and u > 0 and backend is None:
                    continue  # static vEB cannot update in place (paper's point)
                r = run_index(name, initial, KEY_MAX, u, c, total_ops,
                              seed=seed, engine=engine,
                              **backend_kwargs(name, initial.size,
                                               key_max=KEY_MAX,
                                               total_ops=total_ops))
                rows.append(emit({"bench": "fig11", **r}))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    return run(quick=quick, seed=seed, backend=backend, engine=engine,
               smoke=smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         engine=args.engine, smoke=args.smoke)
