"""Fig. 12 reproduction: big tree (2.5M initial keys — larger than cache),
throughput vs update rate vs concurrency."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_baseline, run_deltatree
from repro.core import baselines as BL

KEY_MAX = 5_000_000
INITIAL = 2_500_000
UPDATE_RATES = (0, 1, 10, 20, 100)
CONCURRENCY = (256, 1024)


def run(total_ops: int = 30_000, quick: bool = False,
        initial_size: int | None = None):
    rng = np.random.default_rng(43)
    n = initial_size or (200_000 if quick else INITIAL)
    initial = np.unique(rng.integers(1, KEY_MAX, size=n).astype(np.int32))
    rows = []
    rates = (0, 10) if quick else UPDATE_RATES
    concs = (1024,) if quick else CONCURRENCY
    for u in rates:
        for c in concs:
            need = max(8192, 1 << (4 * initial.size // 32).bit_length())
            r = run_deltatree(7, initial, KEY_MAX, u, c, total_ops,
                              max_dnodes=need)
            rows.append(("deltatree_ub127", u, c, r["ops_per_s"]))
            for Bl in (BL.PointerBST, BL.SortedArray):
                r = run_baseline(Bl, initial, KEY_MAX, u, c, total_ops)
                rows.append((Bl.name, u, c, r["ops_per_s"]))
            if u == 0:
                r = run_baseline(BL.StaticVEB, initial, KEY_MAX, 0, c,
                                 total_ops)
                rows.append((BL.StaticVEB.name, u, c, r["ops_per_s"]))
    return rows


def main(quick=True):
    rows = run(quick=quick)
    for name, u, c, ops in rows:
        us = 1e6 / ops
        print(f"fig12/{name}/u{u}/c{c},{us:.3f},{ops:.0f}")
    return rows


if __name__ == "__main__":
    main(quick=False)
