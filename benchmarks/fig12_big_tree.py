"""Fig. 12 reproduction: big tree (2.5M initial keys — larger than cache),
throughput vs update rate vs concurrency, all structures through
`make_index` (`--backend` narrows to one)."""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    DEFAULT_SEED, add_common_args, backend_kwargs, emit, engine_supported,
    run_index,
)

KEY_MAX = 5_000_000
INITIAL = 2_500_000
UPDATE_RATES = (0, 1, 10, 20, 100)
CONCURRENCY = (256, 1024)
DEFAULT_BACKENDS = ("deltatree", "pointer_bst", "sorted_array", "static_veb")


def run(total_ops: int = 30_000, quick: bool = False,
        initial_size: int | None = None, seed: int = DEFAULT_SEED,
        backend: str | None = None, engine: str | None = None,
        smoke: bool = False):
    rng = np.random.default_rng(seed)
    n = initial_size or (200_000 if quick else INITIAL)
    if smoke:
        n = 10_000
    initial = np.unique(rng.integers(1, KEY_MAX, size=n).astype(np.int32))
    rows = []
    rates = (0, 10) if quick else UPDATE_RATES
    concs = (1024,) if quick else CONCURRENCY
    if smoke:
        rates, concs, total_ops = (10,), (256,), 256
    names = []
    for name in ((backend,) if backend else DEFAULT_BACKENDS):
        if engine_supported(name, engine):
            names.append(name)
        else:  # one skip row per backend, not per (u, c) point
            rows.append(emit({"bench": "fig12", "backend": name,
                              "engine": engine,
                              "skipped": "engine unsupported"}))
    for u in rates:
        for c in concs:
            for name in names:
                if name == "static_veb" and u > 0 and backend is None:
                    continue
                r = run_index(name, initial, KEY_MAX, u, c, total_ops,
                              seed=seed, engine=engine,
                              **backend_kwargs(name, initial.size,
                                               key_max=KEY_MAX,
                                               total_ops=total_ops))
                rows.append(emit({"bench": "fig12", **r}))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    return run(quick=quick, seed=seed, backend=backend, engine=engine,
               smoke=smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         engine=args.engine, smoke=args.smoke)
