"""Range-scan throughput sweep: ordered bulk reads across backends.

The tentpole read-path claim (DESIGN.md §15): a locality-aware tree
should serve ordered range scans at array-like throughput while staying
updatable.  This sweep times the batched ``scan`` hook — ``K`` lanes per
dispatch, each emitting up to ``max_items`` (key, payload) pairs in key
order — for ``deltatree`` vs the ``sorted_array`` baseline (and
``forest`` via ``--backend``), across two range densities:

- ``sparse``: the window holds ~max_items/4 live keys — the scan is
  dominated by the successor walks between far-apart keys,
- ``dense``: the window holds ~4*max_items live keys — the emit cursor
  saturates and the row is truncated (``more``), the best case for the
  frontier's locality.

Each JSON row records ``density`` / ``max_items`` / ``scans_per_s`` /
``items_per_s`` plus the hop telemetry, and lockstep rows pin
``walk_launches = 1.0``: the lockstep scan driver is a single
``delta_scan`` launch per dispatch (``kernels.ops`` bumps the
``delta_scan.dispatch`` counter exactly once per traced call), the
scan-path analogue of the fused walk's single-launch guarantee.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    DEFAULT_SEED, add_common_args, backend_kwargs, dispatch_of, emit,
    engine_supported, resolved_q_tile,
)
from repro.api import make_index

KEY_MAX = 2_000_000
DEFAULT_BACKENDS = ("deltatree", "sorted_array")
# expected live keys inside one scanned window, as a multiple of
# max_items: sparse underfills the emit buffer, dense saturates it
DENSITY_FILL = {"sparse": 0.25, "dense": 4.0}


def _scan_row(backend: str, ix, vals: np.ndarray, density: str,
              max_items: int, batch: int, total_scans: int,
              seed: int) -> dict:
    """Time ``total_scans`` scans in ``batch``-lane dispatches against a
    pre-built index, all windows sized for ``density``."""
    rng = np.random.default_rng(seed + max_items)
    span_per_key = KEY_MAX / vals.size
    width = max(1, int(span_per_key * DENSITY_FILL[density] * max_items))

    spec = ix.spec
    scan = spec.backend.scan

    def one_step(count=False):
        nonlocal n_scans, emitted, truncated, hops_sum
        lo = rng.integers(1, max(2, KEY_MAX - width), size=batch)
        starts = jnp.asarray(lo - 1, jnp.int32)          # exclusive start
        his = jnp.asarray(np.minimum(lo + width, KEY_MAX), jnp.int32)
        keys, pays, n, hops, more = scan(spec.cfg, ix.state, starts, his,
                                         max_items)
        if count:  # host-side tallies only; device sync happens once below
            n_scans += batch
            se, st, sh = jnp.sum(n), jnp.sum(more), jnp.sum(hops)
            emitted = se if emitted is None else emitted + se
            truncated = st if truncated is None else truncated + st
            hops_sum = sh if hops_sum is None else hops_sum + sh
        return keys

    n_scans = 0
    emitted = truncated = hops_sum = None
    tc = time.perf_counter()
    for _ in range(2):                                   # warm the jit cache
        keys = one_step()
    jax.block_until_ready(keys)
    compile_seconds = time.perf_counter() - tc

    steps = max(total_scans // batch, 1)
    t0 = time.perf_counter()
    for _ in range(steps):
        keys = one_step(count=True)
    jax.block_until_ready(keys)
    dt = time.perf_counter() - t0
    emitted = int(emitted)
    row = {"bench": "scan_sweep", "backend": backend, "engine": ix.engine,
           "dispatch": dispatch_of(ix), "maintenance": ix.maintenance,
           "seed": seed, "density": density, "width": width,
           "max_items": max_items, "batch": batch, "n_scans": n_scans,
           "scans_per_s": round(n_scans / dt, 1),
           "items_per_s": round(emitted / dt, 1),
           "emitted_mean": round(emitted / n_scans, 2),
           "truncated_frac": round(int(truncated) / n_scans, 3),
           "hops_mean": round(int(hops_sum) / n_scans, 2),
           "seconds": round(dt, 4),
           "compile_seconds": round(compile_seconds, 4)}
    if ix.engine == "lockstep":
        # single-launch guarantee: the lockstep scan frontier is ONE
        # delta_scan dispatch per batch (engine._lockstep_scan), same
        # contract the compiled smoke asserts on
        row["walk_launches"] = 1.0
        row["q_tile"] = resolved_q_tile(ix)
    return row


def run(initial_size: int, total_scans: int, batch: int, k_list,
        seed: int = DEFAULT_SEED, backend: str | None = None,
        engine: str | None = None):
    rng = np.random.default_rng(seed)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    rows = []
    names = (backend,) if backend else DEFAULT_BACKENDS
    for name in names:
        kw = backend_kwargs(name, vals.size, key_max=KEY_MAX)
        engines: tuple = (None,)
        if name in ("deltatree", "forest"):
            engines = (engine,) if engine else ("scalar", "lockstep")
        for eng in engines:
            if not engine_supported(name, eng):
                rows.append(emit({"bench": "scan_sweep", "backend": name,
                                  "skipped": f"no {eng} engine"}))
                continue
            ix = make_index(name, initial=vals, engine=eng, **kw)
            if not ix.capability.range_scan:
                rows.append(emit({"bench": "scan_sweep", "backend": name,
                                  "skipped": "no range_scan capability"}))
                continue
            for density in ("sparse", "dense"):
                for k in k_list:
                    rows.append(emit(_scan_row(
                        name, ix, vals, density, k, batch, total_scans,
                        seed)))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    if smoke:
        return run(initial_size=2_000, total_scans=128, batch=64,
                   k_list=(8,), seed=seed, backend=backend, engine=engine)
    if quick:
        return run(initial_size=50_000, total_scans=2_048, batch=256,
                   k_list=(16, 64), seed=seed, backend=backend,
                   engine=engine)
    return run(initial_size=200_000, total_scans=8_192, batch=512,
               k_list=(16, 128), seed=seed, backend=backend, engine=engine)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         engine=args.engine, smoke=args.smoke)
