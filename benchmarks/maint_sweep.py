"""Maintenance-policy sweep: eager vs deferred vs budgeted × scalar vs
lockstep update descent × update ratios.

Pins down the two claims the repro.maintenance subsystem makes:

1. the *maintenance tax*: how much throughput an update-heavy batch
   recovers when Rebalance/Expand/Merge is deferred (amortized via
   ``flush_every``) or budgeted, instead of drained to fixpoint inside
   every step, and
2. the *lockstep update descent*: scalar-vs-lockstep row pairs on the same
   seeded workload (the lockstep row records ``speedup_vs_scalar``) — on
   CPU the kernel runs in interpret mode so the pair mostly pins parity
   cost; on TPU it measures the one-DMA-per-round claim on the update path.

Every JSON row records ``engine``, ``maintenance`` and ``q_tile`` (the
lockstep kernel tile — ``REPRO_PALLAS_QTILE``/``TreeConfig.q_tile``
override the 256 default).
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    DEFAULT_SEED, add_common_args, backend_kwargs, emit, engine_supported,
    run_index,
)

KEY_MAX = 2_000_000
ENGINES = ("scalar", "lockstep")
POLICIES = ("eager", "deferred", "budgeted:4")
DEFAULT_BACKENDS = ("deltatree", "forest")
FLUSH_EVERY = 16   # non-eager rows drain inside the timed loop


def run(initial_size: int, total_ops: int, batch: int, update_pcts,
        seed: int = DEFAULT_SEED, backend: str | None = None,
        engine: str | None = None, maintenance: str | None = None):
    rng = np.random.default_rng(seed)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    rows = []
    names = (backend,) if backend else DEFAULT_BACKENDS
    engines = (engine,) if engine else ENGINES
    policies = (maintenance,) if maintenance else POLICIES
    for name in names:
        kw = backend_kwargs(name, vals.size, key_max=KEY_MAX,
                            total_ops=total_ops)
        for pol in policies:
            for u in update_pcts:
                per_engine = {}
                for eng in engines:
                    if not engine_supported(name, eng):
                        rows.append(emit({
                            "bench": "maint_sweep", "backend": name,
                            "engine": eng, "maintenance": pol,
                            "skipped": "engine unsupported"}))
                        continue
                    r = run_index(
                        name, vals, KEY_MAX, u, batch, total_ops,
                        seed=seed, engine=eng, maintenance=pol,
                        flush_every=0 if pol == "eager" else FLUSH_EVERY,
                        **kw)
                    per_engine[eng] = r
                    row = {"bench": "maint_sweep", **r}
                    if eng == "lockstep" and "scalar" in per_engine:
                        row["speedup_vs_scalar"] = round(
                            r["ops_per_s"]
                            / per_engine["scalar"]["ops_per_s"], 3)
                    rows.append(emit(row))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         maintenance=None, smoke=False):
    if smoke:
        return run(initial_size=400, total_ops=128, batch=64,
                   update_pcts=(20.0,), seed=seed,
                   backend=backend or "deltatree", engine=engine,
                   maintenance=maintenance)
    if quick:
        return run(initial_size=20_000, total_ops=2_000, batch=256,
                   update_pcts=(2.0, 20.0), seed=seed, backend=backend,
                   engine=engine, maintenance=maintenance)
    return run(initial_size=200_000, total_ops=20_000, batch=256,
               update_pcts=(2.0, 20.0, 50.0), seed=seed, backend=backend,
               engine=engine, maintenance=maintenance)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--maintenance", default=None,
                    help="run only this policy (eager|deferred|budgeted:K; "
                         "default: sweep all three)")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         engine=args.engine, maintenance=args.maintenance, smoke=args.smoke)
