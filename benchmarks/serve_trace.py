"""Sustained mixed-arrival serve trace: continuous batching vs lockstep.

Replays one ``repro.serve.trace.synth_trace`` plan (same seed → same
arrivals everywhere) through four rows:

  serve_trace/lockstep      — the legacy loop (prefill at submit, rigid
                              lockstep decode, maintenance inline on the
                              decode path at the high-water mark);
  serve_trace/sched         — the continuous-batching scheduler on the
                              identical arrivals-only trace;
  serve_trace/sched_churn   — + mid-flight cancels and zipfian probe
                              traffic (op combining earns its keep);
  serve_trace/sched_churn_forest — churn over the sharded forest pager,
                              where the hoisted fused view serves
                              consecutive decode steps from cache.

Every scheduler row reports p50/p99 step latency, queue-depth high-water,
admission waits, combined ops, fused-view cache hits and worker drains —
and asserts the acceptance invariant that the decode path ran ZERO
inline structural maintenance (the worker owns every drain).

Run under JAX_ENABLE_X64=1 (packed map-mode values); benchmarks.run
spawns it so.
"""

from __future__ import annotations

import argparse

from benchmarks.common import DEFAULT_SEED, add_common_args, emit


def _model():
    import jax
    from repro.configs import get_smoke_config
    from repro.models.registry import api

    cfg = get_smoke_config("granite_8b")
    m = api(cfg)
    return cfg, m.init_params(jax.random.PRNGKey(0))


def _pager_cfg(backend: str, engine: str | None):
    from repro.serving import PagerConfig, ShardedPagerConfig

    kw = dict(num_pages=1024, page_size=4, max_seqs=256, max_blocks=64,
              tree_height=5, maintenance="deferred", maint_high_water=8)
    if backend == "forest":
        # the fused frontier (and so the hoisted view) needs the
        # lockstep engine unless the sweep pinned one explicitly
        return ShardedPagerConfig(num_shards=4,
                                  engine=engine or "lockstep", **kw)
    return PagerConfig(engine=engine or "scalar", **kw)


def _base_row(tag: str, eng, seed: int) -> dict:
    obs = eng.obs.asdict()
    s = eng.pager.stats
    return {"bench": f"serve_trace/{tag}",
            "backend": eng.pager.index.backend,
            "engine": eng.pager.index.engine,
            "maintenance": "deferred", "seed": seed,
            "p50_us": obs["p50_us"], "p99_us": obs["p99_us"],
            "decode_steps": obs["steps"], "pending_hwm": obs["pending_hwm"],
            "inline_maint": s["inline_maint"],
            "pager_searches": s["searches"],
            "hops_per_search": round(s["hops"] / max(s["searches"], 1), 2)}


def _run_lockstep(cfg, params, pc, plans, max_batch: int, seed: int) -> dict:
    from repro.serving.engine import LockstepServeEngine

    eng = LockstepServeEngine(cfg, params, pc, max_batch=max_batch)
    for plan in plans:
        for prompt, max_new in plan.arrivals:
            eng.submit(prompt, max_new=max_new)
        eng.step()
    for _ in range(500):                       # drain the long tail
        if not eng.step():
            break
    row = _base_row("lockstep", eng, seed)
    row.update(submitted=eng._next_id,
               finished=sum(r.done for r in eng.active.values()),
               inline_flushes=eng.obs.asdict()["flushes"])
    return row


def _run_sched(tag: str, cfg, params, pc, plans, max_live: int,
               seed: int) -> dict:
    from repro.distributed import forest as F
    from repro.serve import SchedulerConfig, ServeScheduler

    F.reset_fused_view_cache()
    sch = ServeScheduler(cfg, params, pc, SchedulerConfig(max_live=max_live))
    summary = sch.run_trace(plans)
    obs = sch.obs.asdict()
    w = sch.worker.stats()
    row = _base_row(tag, sch, seed)
    # acceptance: all structural maintenance ran on the worker path
    assert row["inline_maint"] == 0, row
    row.update(submitted=summary["submitted"],
               finished=summary["finished"], rejected=summary["rejected"],
               queue_hwm=obs["queue_hwm"], admitted=obs["admitted"],
               admit_wait=obs["admit_wait"], combined=obs["combined"],
               view_hits=obs["view_hits"], view_builds=obs["view_builds"],
               probe_queries=obs["probe_queries"],
               probe_hits=obs["probe_hits"],
               worker_drains=w["drains"], worker_rounds=w["rounds"])
    return row


def run(steps: int, seed: int = DEFAULT_SEED, backend: str | None = None,
        engine: str | None = None) -> list[dict]:
    from repro.serve import synth_trace

    if backend not in (None, "deltatree", "forest"):
        return [{"bench": "serve_trace", "backend": backend,
                 "skipped": "pager needs a map-mode (payload) backend"}]
    cfg, params = _model()
    calm = synth_trace(steps, seed=seed, prompt_lens=(3, 17),
                       max_new=(4, 12), vocab=cfg.vocab_size)
    churn = synth_trace(steps, seed=seed + 1, prompt_lens=(3, 17),
                        max_new=(4, 12), cancel_p=0.25,
                        probes_per_step=16, vocab=cfg.vocab_size)
    rows = []
    if backend in (None, "deltatree"):
        rows.append(_run_lockstep(cfg, params,
                                  _pager_cfg("deltatree", engine), calm,
                                  max_batch=6, seed=seed))
        rows.append(_run_sched("sched", cfg, params,
                               _pager_cfg("deltatree", engine), calm,
                               max_live=6, seed=seed))
        rows.append(_run_sched("sched_churn", cfg, params,
                               _pager_cfg("deltatree", engine), churn,
                               max_live=6, seed=seed))
    if backend in (None, "forest"):
        rows.append(_run_sched("sched_churn_forest", cfg, params,
                               _pager_cfg("forest", engine), churn,
                               max_live=6, seed=seed))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    steps = 5 if smoke else (14 if quick else 40)
    return [emit(r) for r in run(steps, seed=seed, backend=backend,
                                 engine=engine)]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         engine=args.engine, smoke=args.smoke)
