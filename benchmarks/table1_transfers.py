"""Table 1 reproduction: memory-transfer profile during 100% search.

The paper profiles cache misses with Valgrind; we count transfers exactly in
the ideal-cache model (DESIGN.md §2): elements touched ("load count" analog)
and distinct B-element blocks per search ("LLC miss" analog), for:
  - ΔTree UB=127 (dynamic vEB, the paper's best),
  - ΔTree UB=N (one giant ΔNode = leaf-oriented static vEB),
  - static vEB monolith (VTMtree: values at internal nodes),
  - pointer BST (Synchrobench tree analog), sorted array.
Tree pre-filled with 1,048,576 random keys in (0, 5e6] (paper's setup).
"""

from __future__ import annotations

import numpy as np

from repro.core import TreeConfig, bulk_build
from repro.core import baselines as BL
from repro.core.transfers import delta_touch_fn
from repro.core.baselines import count_block_transfers

KEY_MAX = 5_000_000
INITIAL = 1 << 20


def _mean_loads(touch_fn, keys) -> float:
    return float(np.mean([len(touch_fn(int(k))) for k in keys]))


def run(n_queries: int = 300, initial_size: int = INITIAL):
    rng = np.random.default_rng(44)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    q = rng.integers(1, KEY_MAX, size=n_queries).astype(np.int32)
    rows = []

    # ΔTree UB=127 (dynamic vEB)
    cfg = TreeConfig(height=7, max_dnodes=1 << 17, buf_cap=16)
    t = bulk_build(cfg, vals)
    tf = delta_touch_fn(cfg, t)
    rows.append(("deltatree_ub127", _mean_loads(tf, q),
                 count_block_transfers(tf, q, 16),
                 count_block_transfers(tf, q, 128)))

    # ΔTree UB=N: one ΔNode covering everything = leaf-oriented static vEB
    h_big = int(np.ceil(np.log2(vals.size))) + 2
    cfg_big = TreeConfig(height=h_big, max_dnodes=4, buf_cap=16)
    t_big = bulk_build(cfg_big, vals)
    tfb = delta_touch_fn(cfg_big, t_big)
    rows.append((f"deltatree_ubN(h={h_big})", _mean_loads(tfb, q),
                 count_block_transfers(tfb, q, 16),
                 count_block_transfers(tfb, q, 128)))

    for Bl in (BL.StaticVEB, BL.PointerBST, BL.SortedArray):
        st = Bl.build(vals)
        tf = Bl.touch_fn(st)
        rows.append((Bl.name, _mean_loads(tf, q),
                     count_block_transfers(tf, q, 16),
                     count_block_transfers(tf, q, 128)))
    return rows


def main(quick=True):
    rows = run(n_queries=150 if quick else 500,
               initial_size=(1 << 17) if quick else INITIAL)
    for name, loads, b16, b128 in rows:
        print(f"table1/{name}/loads,{loads:.2f},elements")
        print(f"table1/{name}/blocks_B16,{b16:.2f},transfers")
        print(f"table1/{name}/blocks_B128,{b128:.2f},transfers")
    return rows


if __name__ == "__main__":
    main(quick=False)
