"""Table 1 reproduction: memory-transfer profile during 100% search.

The paper profiles cache misses with Valgrind; we count transfers exactly in
the ideal-cache model (DESIGN.md §2): elements touched ("load count" analog)
and distinct B-element blocks per search ("LLC miss" analog), for every
registered backend that exposes a touch trace (`Index.touch_fn`):
  - ΔTree UB=127 (dynamic vEB, the paper's best),
  - ΔTree UB=N (one giant ΔNode = leaf-oriented static vEB),
  - static vEB monolith (VTMtree: values at internal nodes),
  - pointer BST (Synchrobench tree analog), sorted array.
Tree pre-filled with 1,048,576 random keys in (0, 5e6] (paper's setup).
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    DEFAULT_SEED, add_common_args, emit, engine_supported,
)
from repro.api import get_backend, make_index
from repro.core.baselines import count_block_transfers

KEY_MAX = 5_000_000
INITIAL = 1 << 20
DEFAULT_BACKENDS = ("deltatree", "static_veb", "pointer_bst", "sorted_array")


def _mean_loads(touch_fn, keys) -> float:
    return float(np.mean([len(touch_fn(int(k))) for k in keys]))


def _profile(label: str, ix, q, seed: int) -> dict:
    from repro.obs import trace as OT

    with OT.span(f"table1.{label}"):
        return _profile_row(label, ix, q, seed)


def _profile_row(label: str, ix, q, seed: int) -> dict:
    tf = ix.touch_fn()
    assert tf is not None, f"backend {ix.backend!r} exposes no touch trace"
    row = {"bench": "table1", "backend": label, "engine": ix.engine,
           "seed": seed,
           "loads": round(_mean_loads(tf, q), 2),
           "blocks_b16": round(count_block_transfers(tf, q, 16), 2),
           "blocks_b128": round(count_block_transfers(tf, q, 128), 2)}
    if ix.backend == "deltatree":
        # measured (device-side descent replay) vs analytical model at
        # B=16: the quiescent-tree contract is ratio == 1.0 exactly —
        # the compiled-smoke CI job asserts it on every committed row
        from repro.obs.transfers import compare_model

        cm = compare_model(ix.cfg, ix.state, q, block_sizes=(16,))[16]
        row.update(measured_transfers=round(cm["measured"], 2),
                   model_transfers=round(cm["model"], 2),
                   transfer_ratio=round(cm["ratio"], 4))
    return row


def run(n_queries: int = 300, initial_size: int = INITIAL,
        seed: int = DEFAULT_SEED, backend: str | None = None,
        engine: str | None = None):
    # the ideal-cache touch model is engine-independent (it replays the
    # walk host-side — both engines make exactly these transfers per
    # search), but ``engine`` is still validated + applied via make_index
    # so each row's "engine" field reports what the handle actually runs
    rng = np.random.default_rng(seed)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    q = rng.integers(1, KEY_MAX, size=n_queries).astype(np.int32)
    rows = []
    names = (backend,) if backend else DEFAULT_BACKENDS
    for name in names:
        if get_backend(name).touch is None:
            # e.g. forest: no flat-address touch trace — note and skip
            rows.append(emit({"bench": "table1", "backend": name,
                              "skipped": "backend exposes no touch trace"}))
            continue
        if not engine_supported(name, engine):
            rows.append(emit({"bench": "table1", "backend": name,
                              "engine": engine,
                              "skipped": "engine unsupported"}))
            continue
        kw = {}
        if name == "deltatree":
            kw = dict(height=7, max_dnodes=1 << 17, buf_cap=16)
        rows.append(emit(_profile(
            name, make_index(name, initial=vals, engine=engine, **kw),
            q, seed)))
    if backend is None:
        # ΔTree UB=N: one ΔNode covering everything = leaf-oriented static vEB
        h_big = int(np.ceil(np.log2(vals.size))) + 2
        ix_big = make_index("deltatree", initial=vals, height=h_big,
                            max_dnodes=4, buf_cap=16, engine=engine)
        rows.append(emit(_profile(
            f"deltatree_ubN(h={h_big})", ix_big, q, seed)))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    if smoke:
        return run(n_queries=20, initial_size=1 << 12, seed=seed,
                   backend=backend, engine=engine)
    return run(n_queries=150 if quick else 500,
               initial_size=(1 << 17) if quick else INITIAL,
               seed=seed, backend=backend, engine=engine)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         engine=args.engine, smoke=args.smoke)
