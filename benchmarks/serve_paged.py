"""Beyond-paper serving benchmark: Index-paged decode vs dense-cache decode
(per step wall time at smoke scale on CPU) + pager hot-path stats.

``--backend`` picks the pager's Index backend (``deltatree`` single arena
or ``forest`` sharded) through the same factory path the engine uses.

Run under JAX_ENABLE_X64=1 (map-mode packed values); benchmarks.run spawns
it so.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import DEFAULT_SEED, add_common_args, emit


def run(steps: int = 10, seed: int = DEFAULT_SEED,
        backend: str | None = None, engine: str | None = None):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models.registry import api
    from repro.serving import PagerConfig, ServeEngine, ShardedPagerConfig

    backend = backend or "deltatree"
    if backend not in ("deltatree", "forest"):
        # the pager needs a map-mode index; only the tree backends pack
        # payloads — note and skip instead of failing the whole sweep
        return {"bench": "serve_paged", "backend": backend,
                "skipped": "pager needs a map-mode (payload) backend"}
    cfg = get_smoke_config("granite_8b")
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    pager_kw = dict(num_pages=256, page_size=8, max_seqs=32, max_blocks=128,
                    tree_height=5, engine=engine or "scalar")
    if backend == "forest":
        pc = ShardedPagerConfig(num_shards=4, **pager_kw)
    else:
        assert backend == "deltatree", f"no pager mapping for {backend!r}"
        pc = PagerConfig(**pager_kw)
    eng = ServeEngine(cfg, params, pc, max_batch=8)
    assert eng.pager.index.backend == backend
    for n in (12, 20, 7, 30, 16, 9, 24, 11):
        eng.submit(rng.integers(1, cfg.vocab_size, size=n).astype(np.int32),
                   max_new=steps + 2)
    eng.step()  # warm
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.step()
    dt = (time.perf_counter() - t0) / steps

    # dense baseline: batch-8 decode_step
    caches = m.init_caches(8, 64)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (8, 40)), jnp.int32)
    _, caches = m.prefill(params, toks, caches)
    ln = jnp.full((8,), 40, jnp.int32)
    tok = toks[:, -1:]
    lg, caches = m.decode_step(params, tok, caches, ln)  # warm
    t0 = time.perf_counter()
    for _ in range(steps):
        lg, caches = m.decode_step(params, tok, caches, ln)
    jax.block_until_ready(lg)
    dense = (time.perf_counter() - t0) / steps
    s = eng.pager.stats
    obs = eng.obs.asdict()  # ServeStats: latency reservoir + flush log
    return {"bench": "serve_paged", "backend": backend,
            "engine": eng.pager.index.engine, "seed": seed,
            "paged_step_us": round(dt * 1e6), "dense_step_us": round(dense * 1e6),
            "p50_us": obs["p50_us"], "p99_us": obs["p99_us"],
            "decode_steps": obs["steps"], "flushes": obs["flushes"],
            "pending_hwm": obs["pending_hwm"],
            "pager_searches": s["searches"], "pager_inserts": s["inserts"],
            "pager_deletes": s["deletes"],
            "hops_per_search": round(s["hops"] / max(s["searches"], 1), 2)}


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    return emit(run(steps=2 if smoke else (5 if quick else 20), seed=seed,
                    backend=backend, engine=engine))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         engine=args.engine, smoke=args.smoke)
