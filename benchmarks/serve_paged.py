"""Beyond-paper serving benchmark: ΔTree-paged decode vs dense-cache decode
(per step wall time at smoke scale on CPU) + pager hot-path stats.

Run under JAX_ENABLE_X64=1 (map-mode ΔTree); benchmarks.run spawns it so.
"""

from __future__ import annotations

import time

import numpy as np


def run(steps: int = 10):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models.registry import api
    from repro.serving import PagerConfig, ServeEngine

    cfg = get_smoke_config("granite_8b")
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    pc = PagerConfig(num_pages=256, page_size=8, max_seqs=32, max_blocks=128,
                     tree_height=5)
    eng = ServeEngine(cfg, params, pc, max_batch=8)
    for n in (12, 20, 7, 30, 16, 9, 24, 11):
        eng.submit(rng.integers(1, cfg.vocab_size, size=n).astype(np.int32),
                   max_new=steps + 2)
    eng.step()  # warm
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.step()
    dt = (time.perf_counter() - t0) / steps

    # dense baseline: batch-8 decode_step
    caches = m.init_caches(8, 64)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (8, 40)), jnp.int32)
    _, caches = m.prefill(params, toks, caches)
    ln = jnp.full((8,), 40, jnp.int32)
    tok = toks[:, -1:]
    lg, caches = m.decode_step(params, tok, caches, ln)  # warm
    t0 = time.perf_counter()
    for i in range(steps):
        lg, caches = m.decode_step(params, tok, caches, ln)
    jax.block_until_ready(lg)
    dense = (time.perf_counter() - t0) / steps
    return {"paged_step_s": dt, "dense_step_s": dense,
            "pager": dict(eng.pager.stats)}


def main(quick=True):
    r = run(steps=5 if quick else 20)
    print(f"serve/paged_step,{r['paged_step_s']*1e6:.0f},us_per_step")
    print(f"serve/dense_step,{r['dense_step_s']*1e6:.0f},us_per_step")
    s = r["pager"]
    print(f"serve/pager_searches,{s['searches']},"
          f"hops_per_search={s['hops']/max(s['searches'],1):.2f}")
    return r


if __name__ == "__main__":
    main(quick=False)
