"""SearchEngine comparison: scalar (vmap-of-while_loop reference) vs
lockstep (frontier rounds driving the Pallas vEB walk kernel) on the same
search-dominant workload — the paper's headline read path, now tracked per
engine so the perf trajectory of the lockstep path is visible run over run.

For every engine-capable backend (``deltatree``, ``forest``) and batch
width, the identical seeded workload runs through ``run_index`` once per
engine; each per-engine JSON row records ``engine`` (and ``dispatch``),
and the lockstep rows additionally record ``speedup_vs_scalar``.  The
forest backend gets a third leg: lockstep under the dense per-shard vmap
dispatch (``fused=False``), so the default fused row also records
``speedup_vs_vmap`` — the cross-shard frontier's own win.  Every backend
additionally pins a lockstep *per-round-driver* leg (``walk_fused=False``
— one kernel launch per frontier round, ``walk="per-round"``); the
default fused-walk row records ``speedup_vs_perround`` next to its
``walk_launches=1``, so the single-launch fusion's own win stays visible
run over run.  In interpret mode the lockstep engine pays the Pallas
interpreter tax — the rows still pin parity cost; compiled
(``REPRO_PALLAS_INTERPRET=0`` / ``benchmarks/run.py --compiled``) the
same rows measure the paper's locality claim for real.
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    DEFAULT_SEED, add_common_args, backend_kwargs, emit, engine_supported,
    run_index,
)

KEY_MAX = 2_000_000
DEFAULT_BACKENDS = ("deltatree", "forest")


def run(initial_size: int, total_ops: int, batches, update_pct: float,
        seed: int = DEFAULT_SEED, backend: str | None = None):
    rng = np.random.default_rng(seed)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    rows = []
    names = (backend,) if backend else DEFAULT_BACKENDS
    for name in names:
        if not engine_supported(name, "lockstep"):
            rows.append(emit({"bench": "engine_compare", "backend": name,
                              "skipped": "no lockstep engine"}))
            continue
        kw = backend_kwargs(name, vals.size, key_max=KEY_MAX,
                            total_ops=total_ops)
        for batch in batches:
            scalar_r = run_index(name, vals, KEY_MAX, update_pct, batch,
                                 total_ops, seed=seed, engine="scalar", **kw)
            rows.append(emit({"bench": "engine_compare", **scalar_r}))
            vmap_r = None
            if name == "forest":
                # pin the dense vmap dispatch alongside the (default,
                # fused) lockstep forest row, so the dispatch-level win
                # is tracked next to the engine-level one
                vmap_r = run_index(name, vals, KEY_MAX, update_pct, batch,
                                   total_ops, seed=seed, engine="lockstep",
                                   fused=False, **kw)
                rows.append(emit({
                    "bench": "engine_compare", **vmap_r,
                    "speedup_vs_scalar": round(
                        vmap_r["ops_per_s"] / scalar_r["ops_per_s"], 3)}))
            perround_r = run_index(name, vals, KEY_MAX, update_pct, batch,
                                   total_ops, seed=seed, engine="lockstep",
                                   walk_fused=False, **kw)
            rows.append(emit({
                "bench": "engine_compare", **perround_r,
                "speedup_vs_scalar": round(
                    perround_r["ops_per_s"] / scalar_r["ops_per_s"], 3)}))
            lock_r = run_index(name, vals, KEY_MAX, update_pct, batch,
                               total_ops, seed=seed, engine="lockstep", **kw)
            row = {"bench": "engine_compare", **lock_r,
                   "speedup_vs_scalar": round(
                       lock_r["ops_per_s"] / scalar_r["ops_per_s"], 3),
                   "speedup_vs_perround": round(
                       lock_r["ops_per_s"] / perround_r["ops_per_s"], 3)}
            if vmap_r is not None:
                row["speedup_vs_vmap"] = round(
                    lock_r["ops_per_s"] / vmap_r["ops_per_s"], 3)
            rows.append(emit(row))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    del engine  # this benchmark sweeps both engines by construction
    if smoke:
        return run(initial_size=2_000, total_ops=256, batches=(128,),
                   update_pct=2.0, seed=seed, backend=backend or "deltatree")
    # two legs: the historical 2% mixed point, plus a pure-read point —
    # the read path is what the engine choice (and the committed
    # ``engine="auto"`` table, core.engine.AUTO_TABLE) is actually about
    if quick:
        return (run(initial_size=20_000, total_ops=2_000, batches=(256,),
                    update_pct=2.0, seed=seed, backend=backend)
                + run(initial_size=50_000, total_ops=16_000, batches=(256,),
                      update_pct=0.0, seed=seed, backend=backend))
    return (run(initial_size=200_000, total_ops=20_000, batches=(256, 1024),
                update_pct=2.0, seed=seed, backend=backend)
            + run(initial_size=200_000, total_ops=40_000,
                  batches=(256, 1024), update_pct=0.0, seed=seed,
                  backend=backend))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         smoke=args.smoke)
