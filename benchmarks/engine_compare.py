"""SearchEngine comparison: scalar (vmap-of-while_loop reference) vs
lockstep (frontier rounds driving the Pallas vEB walk kernel) on the same
search-dominant workload — the paper's headline read path, now tracked per
engine so the perf trajectory of the lockstep path is visible run over run.

For every engine-capable backend (``deltatree``, ``forest``) and batch
width, the identical seeded workload runs through ``run_index`` once per
engine; each per-engine JSON row records ``engine``, and the lockstep row
additionally records ``speedup_vs_scalar``.  On CPU the lockstep engine
pays the Pallas interpreter tax — the row pair still pins down result
parity cost; on TPU (compiled kernel, one contiguous row DMA per query per
round) the same rows measure the paper's locality claim.
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import (
    DEFAULT_SEED, add_common_args, backend_kwargs, emit, engine_supported,
    run_index,
)

KEY_MAX = 2_000_000
ENGINES = ("scalar", "lockstep")
DEFAULT_BACKENDS = ("deltatree", "forest")


def run(initial_size: int, total_ops: int, batches, update_pct: float,
        seed: int = DEFAULT_SEED, backend: str | None = None):
    rng = np.random.default_rng(seed)
    vals = np.unique(rng.integers(1, KEY_MAX, size=initial_size)
                     .astype(np.int32))
    rows = []
    names = (backend,) if backend else DEFAULT_BACKENDS
    for name in names:
        if not engine_supported(name, "lockstep"):
            rows.append(emit({"bench": "engine_compare", "backend": name,
                              "skipped": "no lockstep engine"}))
            continue
        kw = backend_kwargs(name, vals.size, key_max=KEY_MAX,
                            total_ops=total_ops)
        for batch in batches:
            per_engine = {}
            for eng in ENGINES:
                r = run_index(name, vals, KEY_MAX, update_pct, batch,
                              total_ops, seed=seed, engine=eng, **kw)
                per_engine[eng] = r
                row = {"bench": "engine_compare", **r}
                if eng == "lockstep":
                    row["speedup_vs_scalar"] = round(
                        r["ops_per_s"] / per_engine["scalar"]["ops_per_s"], 3)
                rows.append(emit(row))
    return rows


def main(quick=True, seed=DEFAULT_SEED, backend=None, engine=None,
         smoke=False):
    del engine  # this benchmark sweeps both engines by construction
    if smoke:
        return run(initial_size=2_000, total_ops=256, batches=(128,),
                   update_pct=2.0, seed=seed, backend=backend or "deltatree")
    if quick:
        return run(initial_size=20_000, total_ops=2_000, batches=(256,),
                   update_pct=2.0, seed=seed, backend=backend)
    return run(initial_size=200_000, total_ops=20_000, batches=(256, 1024),
               update_pct=2.0, seed=seed, backend=backend)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_common_args(ap)
    args = ap.parse_args()
    main(quick=not args.full, seed=args.seed, backend=args.backend,
         smoke=args.smoke)
