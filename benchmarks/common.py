"""Shared benchmark utilities: timed op-mix runner over the Index API.

Maps the paper's experiment protocol (§5) to the batched-SPMD world:
- concurrency = batch width of one SPMD step (the paper's thread count),
- update rate u%: each batch mixes u% insert/delete (50/50) with (100-u)%
  searches; searches run vectorized on the snapshot (wait-free), updates
  apply in batch order,
- performance = ops/second over `total_ops` with the jit warm.

Every structure runs through the same ``make_index`` factory — a benchmark
names a backend string plus a SearchEngine name, never a concrete
implementation.  All RNGs derive from one ``--seed`` flag
(``add_common_args``), and every emitted JSON row records ``seed`` +
``backend`` + ``engine`` so perf rows are reproducible.  ``--engine``
narrows the read path (``scalar`` reference walk vs ``lockstep`` Pallas
vEB walk); backends that don't support the requested engine are skipped
with an explicit row rather than silently falling back.
"""

from __future__ import annotations

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import OpBatch, make_index, supported_engines

DEFAULT_SEED = 0

# Backends whose update kernel rebuilds per op (O(cap) sequential work):
# compact each step's update rows into one fixed UPDATE_CHUNK-wide
# sub-batch (padded with OP_SEARCH no-ops, so shapes stay static).
CHUNKED_BACKENDS = {"sorted_array", "pointer_bst", "static_veb"}
UPDATE_CHUNK = 64

# Backends whose configs carry the static ``collect_stats`` knob:
# run_index turns it on by default so every perf row carries its hop /
# round / router telemetry (repro.obs) alongside the timing.
STATS_BACKENDS = {"deltatree", "forest"}


@functools.lru_cache(maxsize=1)
def exec_meta() -> dict:
    """Execution-mode stamp merged into every emitted row: numbers from a
    CPU-interpret run and a TPU-compiled run must never be comparable
    silently.  Cached per process — the serve bench's x64 subprocess
    stamps its own rows with its own (x64=True) view."""
    from repro.kernels.ops import default_interpret

    return {
        "device_kind": jax.devices()[0].device_kind,
        "interpret": bool(default_interpret()),
        "x64": bool(jax.config.jax_enable_x64),
        "jax_version": jax.__version__,
    }


def add_common_args(ap) -> None:
    """--seed / --backend / --engine / --smoke flags shared by every
    benchmark CLI."""
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED,
                    help="root seed for every RNG (recorded in JSON rows)")
    ap.add_argument("--backend", default=None,
                    help="run only this registered Index backend "
                         "(default: the benchmark's historical set)")
    ap.add_argument("--engine", default=None,
                    help="read-path SearchEngine (scalar|lockstep; default "
                         "scalar). Recorded in every JSON row; backends "
                         "without the engine are skipped explicitly")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: exercise every code path in seconds "
                         "(CI bitrot guard), numbers meaningless")


def resolved_q_tile(ix) -> int:
    """The lockstep kernel tile this Index would run with (cfg override,
    else the env/autotune/default chain) — recorded in benchmark JSON
    rows."""
    from repro.api.index import cfg_attr
    from repro.kernels.ops import default_q_tile

    qt = cfg_attr(ix.cfg, "q_tile")
    if qt:
        return int(qt)
    return default_q_tile(cfg_attr(ix.cfg, "height"),
                          cfg_attr(ix.cfg, "payload_bits") or 0)


def engine_supported(backend: str, engine: str | None) -> bool:
    """True when ``backend`` can run its reads under ``engine``
    (``"auto"`` is checked against what it would resolve to)."""
    if engine is None:
        return True
    if engine == "auto":
        from repro.core.engine import resolve_engine

        engine = resolve_engine(engine, backend)
    return engine in supported_engines(backend)


def dispatch_of(ix) -> str | None:
    """How this Index's sharded reads dispatch: "fused" (one cross-shard
    frontier per device), "vmap" (dense per-shard lanes), or None for
    single-arena backends — recorded in benchmark JSON rows."""
    if not ix.capability.sharded:
        return None
    return "fused" if ix.capability.fused_forest else "vmap"


def emit(row: dict) -> dict:
    """One machine-parsable JSON row per result line, stamped with the
    process's execution mode (`exec_meta`; row keys win on collision)."""
    row = {**exec_meta(), **row}
    print(json.dumps(row), flush=True)
    return row


def mixed_kinds(rng, k: int, update_pct: float) -> np.ndarray:
    u = rng.random(k) < (update_pct / 100.0)
    ins = rng.random(k) < 0.5
    kinds = np.where(u, np.where(ins, 1, 2), 0).astype(np.int32)
    return kinds


def backend_kwargs(backend: str, n_keys: int, *, key_max: int,
                   total_ops: int = 0, height: int = 7,
                   num_shards: int = 4) -> dict:
    """make_index config for a benchmark-scale instance of ``backend``.

    Sizing accounts for workload growth: up to total_ops/2 inserts can land
    on fresh keys, so arenas/capacities are provisioned for n + total/2.
    """
    n_eff = n_keys + total_ops // 2
    if backend == "deltatree":
        return dict(height=height, buf_cap=32, max_rounds=256,
                    max_dnodes=max(256, int(6 * n_eff / 2 ** (height - 1))))
    if backend == "forest":
        per_shard = max(64, int(8 * n_eff / num_shards / 2 ** (height - 1)))
        return dict(num_shards=num_shards, key_max=key_max, height=height,
                    buf_cap=32, max_rounds=256, max_dnodes=per_shard)
    if backend in ("sorted_array", "pointer_bst"):
        return dict(cap=2 * n_keys + total_ops + 16)
    return {}


def _chunk_updates(kinds: np.ndarray, keys: np.ndarray,
                   idx: np.ndarray) -> OpBatch:
    """Compact the update rows at ``idx`` into a fixed-width OpBatch (padded
    with OP_SEARCH rows, which insert_delete treats as no-ops)."""
    ck = np.zeros(UPDATE_CHUNK, np.int32)
    cv = np.zeros(UPDATE_CHUNK, np.int32)
    ck[: idx.size] = kinds[idx]
    cv[: idx.size] = keys[idx]
    return OpBatch.mixed(ck, cv)


def run_index(backend: str, initial: np.ndarray, key_hi: int,
              update_pct: float, batch: int, total_ops: int,
              seed: int = DEFAULT_SEED, engine: str | None = None,
              maintenance: str | None = None, flush_every: int = 0,
              **make_kw) -> dict:
    """Timed mixed workload against one backend through the Index handle.

    ``engine`` selects the read-path SearchEngine, ``maintenance`` the
    scheduler policy (both validated by ``make_index``; None = backend
    defaults).  ``flush_every`` > 0 drains deferred/budgeted maintenance
    every N steps *inside the timed loop* (the serving amortization
    pattern), so non-eager rows pay their structural work honestly.

    Warmup (compile) runs fully off the steady-state clock — blocked to
    completion and reported separately as ``compile_seconds`` — so
    ``ops_per_s`` is a pure steady-state number.  Stats-capable backends
    (`STATS_BACKENDS`) collect ``repro.obs`` read telemetry by default
    (merged device-side across the counted loop; one host sync at the
    end), giving every perf row its hop / round / router columns."""
    from repro.obs import trace as OT

    # one row = one measurement: REPRO_TRACE span counters must not leak
    # across rows in a sweep (the chrome-trace event ring keeps the
    # whole run's timeline and is left alone)
    OT.reset_counters()
    if backend in STATS_BACKENDS:
        make_kw.setdefault("collect_stats", True)
    ix = make_index(backend, initial=initial, engine=engine,
                    maintenance=maintenance, **make_kw)
    collect = bool(getattr(ix, "collect_stats", False))
    rng = np.random.default_rng(seed)
    chunked = backend in CHUNKED_BACKENDS
    any_update = update_pct > 0
    # walk_launches: kernel launches per search dispatch under the
    # lockstep engine — 1 for the fused single-launch driver, the step's
    # frontier round count for the per-round driver (one veb_walk_rows
    # launch per round; the round count is device data, accumulated
    # alongside the stats merge so the loop still never syncs the host).
    from repro.api.index import cfg_attr

    lockstep = ix.engine == "lockstep"
    fused_walk = lockstep and bool(cfg_attr(ix.cfg, "walk_fused", True))

    def one_step(ix, count=False):
        nonlocal n_search, n_update, sacc, racc, wl_acc
        kinds = mixed_kinds(rng, batch, update_pct)
        keys = rng.integers(1, key_hi, size=batch).astype(np.int32)
        # fixed shapes: searches on the whole batch (wait-free snapshot);
        # updates ride a whole fixed-shape batch too, with OP_SEARCH rows
        # as no-ops — avoids per-step recompiles from dynamic sub-batches
        res = ix.search(jnp.asarray(keys))
        found = res[0]
        if collect and count:
            # device-side accumulation (merge): no host sync mid-loop
            rs = res[-1]
            sacc = rs.search if sacc is None else sacc.merge(rs.search)
            if rs.router is not None:
                racc = rs.router if racc is None else racc.merge(rs.router)
            if lockstep:
                step_launches = (jnp.int32(1) if fused_walk
                                 else rs.search.rounds)
                wl_acc = (step_launches if wl_acc is None
                          else wl_acc + step_launches)
        n_upd_step = 0
        if any_update:
            uidx = np.flatnonzero(kinds != 0)
            if chunked:
                uidx = uidx[:UPDATE_CHUNK]
                ub = _chunk_updates(kinds, keys, uidx)
            else:
                ub = OpBatch.mixed(kinds, keys)
            ix, _ = ix.insert_delete(ub)
            n_upd_step = int(uidx.size)
        if count:  # host-side only — never syncs the device mid-loop
            n_search += int((kinds == 0).sum())
            n_update += n_upd_step
        return ix, found

    n_search = n_update = 0
    sacc = racc = wl_acc = None
    # warmup compile — two iterations: a sharded backend's first update
    # output carries mesh shardings the host-built input didn't, so the
    # second call retraces once; after that the jit cache is steady.
    # Blocked and timed separately (``compile_seconds``) so no async
    # warmup work leaks into the steady-state clock.
    tc = time.perf_counter()
    # host-side spans (nullcontext unless REPRO_TRACE): the warmup and
    # steady-state loops are the rows of the --trace-dir chrome timeline
    with OT.span(f"bench.{backend}.compile"):
        for _ in range(2):
            ix, found = one_step(ix)
        if flush_every:  # warm the flush compile too, off the clock
            ix, _ = ix.flush()
        jax.block_until_ready(
            [x for x in jax.tree.leaves(ix.state)
             if hasattr(x, "block_until_ready")])
        found.block_until_ready()
    compile_seconds = time.perf_counter() - tc
    n_search = n_update = 0

    steps = max(total_ops // batch, 1)
    t0 = time.perf_counter()
    with OT.span(f"bench.{backend}.steady"):
        for step in range(steps):
            ix, found = one_step(ix, count=True)
            if flush_every and (step + 1) % flush_every == 0:
                ix, _ = ix.flush()
        if flush_every:
            # drain the trailing window on the clock — otherwise short
            # sweeps (steps < flush_every) would time non-eager policies
            # with zero structural work and flatter them vs eager
            ix, _ = ix.flush()
        jax.block_until_ready(
            [x for x in jax.tree.leaves(ix.state)
             if hasattr(x, "block_until_ready")])
        found.block_until_ready()
    dt = time.perf_counter() - t0
    row = {"backend": backend, "engine": ix.engine,
           "dispatch": dispatch_of(ix),
           "walk": (("fused" if fused_walk else "per-round")
                    if lockstep else None),
           "maintenance": ix.maintenance, "q_tile": resolved_q_tile(ix),
           "flush_every": flush_every,
           "seed": seed, "update_pct": update_pct, "batch": batch,
           "ops_per_s": round((n_search + n_update) / dt, 1),
           "seconds": round(dt, 4),
           "compile_seconds": round(compile_seconds, 4),
           "n_search": n_search, "n_update": n_update}
    if sacc is not None:  # the one host sync, after the clock stopped
        sd = sacc.asdict()
        row.update(hops_mean=sd["hops_mean"], hops_max=sd["hops_max"],
                   rounds=sd["rounds"], buffer_hits=sd["buffer_hits"],
                   hops_hist=sd["hops_hist"])
    if wl_acc is not None:
        row["walk_launches"] = round(float(wl_acc) / steps, 2)
    if racc is not None:
        rd = racc.asdict()
        row.update(shard_lanes=rd["lanes"], shard_skew=rd["skew"],
                   clamped=rd["clamped"])
    return row
