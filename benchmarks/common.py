"""Shared benchmark utilities: timed op-mix runner for ΔTree + baselines.

Maps the paper's experiment protocol (§5) to the batched-SPMD world:
- concurrency = batch width of one SPMD step (the paper's thread count),
- update rate u%: each batch mixes u% insert/delete (50/50) with (100-u)%
  searches; searches run vectorized on the snapshot (wait-free), updates
  apply in batch order,
- performance = ops/second over `total_ops` with the jit warm.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import TreeConfig, bulk_build, search_jit, update_batch
from repro.core import baselines as BL


def mixed_kinds(rng, k: int, update_pct: float) -> np.ndarray:
    u = rng.random(k) < (update_pct / 100.0)
    ins = rng.random(k) < 0.5
    kinds = np.where(u, np.where(ins, 1, 2), 0).astype(np.int32)
    return kinds


def run_deltatree(height: int, initial: np.ndarray, key_max: int,
                  update_pct: float, batch: int, total_ops: int,
                  max_dnodes: int, seed: int = 0) -> dict:
    cfg = TreeConfig(height=height, max_dnodes=max_dnodes, buf_cap=32,
                     max_rounds=256)
    tree = bulk_build(cfg, initial)
    rng = np.random.default_rng(seed)
    # warmup compile
    kinds = mixed_kinds(rng, batch, update_pct)
    keys = rng.integers(1, key_max, size=batch).astype(np.int32)
    f, _ = search_jit(cfg, tree, jnp.asarray(keys)); f.block_until_ready()
    if update_pct > 0:
        tree, r, _ = update_batch(cfg, tree, jnp.asarray(kinds), jnp.asarray(keys))
        r.block_until_ready()

    steps = max(total_ops // batch, 1)
    n_search = n_update = 0
    any_update = update_pct > 0
    t0 = time.perf_counter()
    for _ in range(steps):
        kinds = mixed_kinds(rng, batch, update_pct)
        keys = rng.integers(1, key_max, size=batch).astype(np.int32)
        # fixed shapes: searches on the whole batch (wait-free snapshot);
        # updates ride the whole batch too with OP_SEARCH rows as no-ops —
        # avoids per-step recompiles from dynamic sub-batch sizes
        f, _ = search_jit(cfg, tree, jnp.asarray(keys))
        n_search += int((kinds == 0).sum())
        if any_update:
            tree, r, _ = update_batch(cfg, tree, jnp.asarray(kinds),
                                      jnp.asarray(keys))
            n_update += int((kinds != 0).sum())
    if any_update:
        tree.value.block_until_ready()
    else:
        f.block_until_ready()
    dt = time.perf_counter() - t0
    return {"ops_per_s": (n_search + n_update) / dt, "seconds": dt,
            "n_search": n_search, "n_update": n_update}


def run_baseline(BLcls, initial: np.ndarray, key_max: int, update_pct: float,
                 batch: int, total_ops: int, seed: int = 0) -> dict:
    st = BLcls.build(initial, cap=2 * len(initial) + total_ops + 16) \
        if BLcls in (BL.SortedArray, BL.PointerBST) else BLcls.build(initial)
    rng = np.random.default_rng(seed)
    keys = rng.integers(1, key_max, size=batch).astype(np.int32)
    f = BLcls.search(st, jnp.asarray(keys)); f.block_until_ready()
    has_update = hasattr(BLcls, "update")
    steps = max(total_ops // batch, 1)
    n_search = n_update = 0
    up = update_pct if has_update else 0
    t0 = time.perf_counter()
    for _ in range(steps):
        kinds = mixed_kinds(rng, batch, up)
        keys = rng.integers(1, key_max, size=batch).astype(np.int32)
        f = BLcls.search(st, jnp.asarray(keys))
        n_search += int((kinds == 0).sum())
        if up > 0 and (kinds != 0).any():
            umask = kinds != 0
            st, r = BLcls.update(st, jnp.asarray(kinds[umask][:64]),
                                 jnp.asarray(keys[umask][:64]))
            n_update += int(min(umask.sum(), 64))
    jnp.zeros(1).block_until_ready()
    dt = time.perf_counter() - t0
    return {"ops_per_s": (n_search + n_update) / dt, "seconds": dt,
            "n_search": n_search, "n_update": n_update}
