"""repro.maintenance — policy-driven maintenance scheduler (DESIGN.md §7).

The paper's concurrency claim is that Insert/Delete are non-blocking and
only *occasionally* blocked by structural maintenance (Rebalance / Expand /
Merge).  This subsystem makes that schedulable: ``update_batch`` applies
ops and then hands the flagged ΔNodes to the scheduler, whose policy
decides how much structural work runs *now* versus being carried forward:

- ``eager``       — drain every flagged ΔNode to fixpoint inside the update
                    step (the pre-subsystem semantics; bit-identical).
- ``deferred``    — updates only append/mark; maintenance runs on an
                    explicit ``flush(tree)`` (or when a full buffer blocks
                    an op — correctness always wins over deferral).
- ``budgeted:k``  — at most ``k`` ΔNode repairs per update batch,
                    prioritized by buffer occupancy; residual
                    ``ins_flag``/``del_flag`` work carries forward.

Every update returns a ``MaintenanceStats`` telemetry pytree (rounds,
rebuilds, expands, merges, buffered-pending count) alongside the per-op
results.  Under non-eager policies invariant I5 ("every buffer empty after
``update_batch``") is relaxed to I5': every buffered value's root descent
lands in the ΔNode holding it, which is exactly what keeps wait-free
searches (and, with the buffered-floor fold in ``repro.core.engine``,
successor queries) correct over pending items.
"""

from repro.maintenance.policy import (
    KINDS,
    MaintenancePolicy,
    parse_policy,
)
from repro.maintenance.stats import MaintenanceStats
from repro.maintenance.scheduler import flush, pending_count, run_update

__all__ = [
    "KINDS",
    "MaintenancePolicy",
    "MaintenanceStats",
    "parse_policy",
    "flush",
    "pending_count",
    "run_update",
]
