"""Maintenance policies — the static half of the scheduler.

A policy is hashable and lives inside ``TreeConfig`` (as its string form),
so jitted update steps specialize on it exactly like they specialize on
height or engine.  The string forms accepted by ``parse_policy`` (and by
``make_index(maintenance=...)``):

    "eager"        drain to fixpoint inside every update step (default)
    "deferred"     updates only append/mark; maintenance on flush()
    "budgeted:K"   at most K ΔNode repairs per update batch (K >= 1)
"""

from __future__ import annotations

import dataclasses

KINDS = ("eager", "deferred", "budgeted")


@dataclasses.dataclass(frozen=True)
class MaintenancePolicy:
    """Parsed maintenance policy (hashable; closed over by jitted fns).

    kind:   one of ``KINDS``.
    budget: voluntary ΔNode repairs per update batch (budgeted only;
            0 for eager — unlimited by construction — and deferred).
    """

    kind: str = "eager"
    budget: int = 0

    @property
    def eager(self) -> bool:
        return self.kind == "eager"

    def __str__(self) -> str:
        if self.kind == "budgeted":
            return f"budgeted:{self.budget}"
        return self.kind


def parse_policy(spec: "str | MaintenancePolicy") -> MaintenancePolicy:
    """Parse ``"eager" | "deferred" | "budgeted:K"`` (idempotent on an
    already-parsed policy).  Raises ``ValueError`` on anything else."""
    if isinstance(spec, MaintenancePolicy):
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"maintenance policy must be a string, got {spec!r}")
    name, sep, arg = spec.partition(":")
    name = name.strip()
    if name == "budgeted":
        try:
            budget = int(arg)
        except ValueError:
            raise ValueError(
                f"budgeted policy needs an integer budget, got {spec!r}"
            ) from None
        if budget < 1:
            raise ValueError(f"budgeted policy needs budget >= 1, got {spec!r}")
        return MaintenancePolicy(kind="budgeted", budget=budget)
    if sep or name not in ("eager", "deferred"):
        raise ValueError(
            f"unknown maintenance policy {spec!r}; expected one of "
            f"'eager', 'deferred', 'budgeted:K'")
    return MaintenancePolicy(kind=name)
