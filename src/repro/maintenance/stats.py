"""MaintenanceStats — per-update telemetry pytree.

Returned (alongside the tree and per-op results) by every
``update_batch`` / forest ``update_batch`` / ``Index.update`` call, and by
``flush``.  All fields are int32 scalars (per-shard stats stack to (S,)
under the forest dispatch and are reduced by ``MaintenanceStats.reduce``).

Deprecation shim: the pre-subsystem contract returned a bare ``rounds``
scalar as the third tuple element.  ``int(stats)`` (and ``__index__``)
still yield ``rounds`` with a ``DeprecationWarning``, so host-side call
sites written against the old 3-tuple keep working unchanged.
"""

from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp


class MaintenanceStats(NamedTuple):
    """Why and how much maintenance ran during one update step."""

    rounds: jax.Array    # () int32 — scheduler rounds taken
    rebuilds: jax.Array  # () int32 — Rebalance mirror-swaps
    expands: jax.Array   # () int32 — child ΔNodes allocated by Expand
    merges: jax.Array    # () int32 — successful Merge splices
    pending: jax.Array   # () int32 — buffered items carried forward (I5')

    @classmethod
    def zero(cls) -> "MaintenanceStats":
        z = jnp.int32(0)
        return cls(rounds=z, rebuilds=z, expands=z, merges=z, pending=z)

    @classmethod
    def reduce(cls, stacked: "MaintenanceStats") -> "MaintenanceStats":
        """Aggregate per-shard (S,) stats: rounds is the critical path
        (max over shards — shards run concurrently), work counters sum."""
        return cls(
            rounds=jnp.max(stacked.rounds),
            rebuilds=jnp.sum(stacked.rebuilds),
            expands=jnp.sum(stacked.expands),
            merges=jnp.sum(stacked.merges),
            pending=jnp.sum(stacked.pending),
        )

    def asdict(self) -> dict:
        """Host-side plain-int view (for JSON benchmark rows / logging)."""
        return {k: int(v) for k, v in self._asdict().items()}

    # ---- deprecation shim: the old third tuple element was ``rounds`` ----

    def __int__(self) -> int:
        warnings.warn(
            "update_batch now returns MaintenanceStats as its third "
            "element; use stats.rounds instead of treating it as the "
            "round count",
            DeprecationWarning,
            stacklevel=2,
        )
        return int(self.rounds)

    __index__ = __int__
