"""Backward-compatible re-export: ``MaintenanceStats`` lives in
``repro.obs.stats`` now (the home of every counter pytree — the obs
subsystem generalized this module's pattern into SearchStats /
RouterStats / ServeStats).  Both historical import paths keep working
unchanged:

    from repro.maintenance import MaintenanceStats
    from repro.maintenance.stats import MaintenanceStats
"""

from repro.obs.stats import MaintenanceStats

__all__ = ["MaintenanceStats"]
