"""MaintenanceScheduler — the update-step round loop, extracted from
``deltatree.update_batch_impl`` and made policy-driven.

One round = (op phase) + (maintenance phase).  The op phase is shared by
every policy: one *frontier* position pass for the whole pending batch
(``kernels.ops.delta_walk`` under the lockstep engine, a vmapped scalar
descent otherwise), the vectorized non-conflicting fastpath, then the
budgeted sequential leftovers in batch order.  The maintenance phase is
what the policy controls:

- ``eager``:     process every flagged ΔNode, round after round, until the
                 fixpoint (bit-identical to the pre-subsystem semantics —
                 same phase order, same per-phase budget, same round count).
- ``deferred``:  no voluntary maintenance.  *Forced* repairs still run when
                 a full buffer blocks a pending op (the paper's
                 "occasionally blocked by maintenance") or when a repair
                 left I5'-violating residual items behind.
- ``budgeted:k``: up to ``k`` voluntary repairs per update batch, highest
                 buffer occupancy first (then Merge candidates); forced
                 repairs are always allowed on top — correctness over
                 deferral.

Invariant I5' (non-eager policies): every buffered value's root descent
lands in the ΔNode whose buffer holds it, so the wait-free read path
(final-ΔNode buffer probe in ``deltatree.searchnode``) keeps finding
pending items.  An Expand that fails to move an item into a full child
("keep") violates I5' — such nodes are tracked as *residual* and force-
drained (together with every full buffer, which is what blocks a keep)
before the step returns.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import deltatree as DT
from repro.maintenance.policy import MaintenancePolicy, parse_policy
from repro.maintenance.stats import MaintenanceStats
from repro.obs import trace as TR

_Work = tuple  # (rebuilds, expands, merges, reclaimed) int32 scalars


def _zero_work() -> _Work:
    z = jnp.int32(0)
    return (z, z, z, z)


def pending_count(cfg, t) -> jax.Array:
    """Buffered items still awaiting maintenance (the I5' carry)."""
    return jnp.sum(jnp.where(t.alive, t.bcount, 0)).astype(jnp.int32)


# --------------------------------------------------------------------------
# frontier positions — the lockstep update descent (ROADMAP item)
# --------------------------------------------------------------------------


def _positions(cfg, t, q):
    """(dn, b) leaf positions for every packed query in ``q``.

    Under the lockstep engine this is ONE ``delta_walk`` frontier pass for
    the whole batch (each round gathers every active query's ΔNode row with
    one contiguous DMA) — the same kernel invocation the read path uses
    (``core.engine._lockstep_walk``, so kernel/tile plumbing cannot
    drift); otherwise the vmapped scalar ``_descend``.  Both return the
    identical positions — the engine-parity suite pins this.
    """
    if cfg.engine == "lockstep":
        from repro.core import engine as E

        _, lb, dn, _, _ = E._lockstep_walk(cfg, t, q)
        return dn, lb
    dns, bs, _ = jax.vmap(lambda qq: DT._descend(cfg, t, qq, t.root, 1))(q)
    return dns, bs


# --------------------------------------------------------------------------
# op phase (policy-independent)
# --------------------------------------------------------------------------


def _ops_phase(cfg, t, results, pending, kinds, keys, payloads, budget):
    """One round's op applications: frontier positions -> vectorized
    fastpath -> budgeted sequential leftovers in batch order.

    Under the lockstep engine the round's positions also seed the
    sequential ops as descent *hints*: within an op phase the structure
    only grows downward (grow/place at leaves; routers and child links
    untouched), so restarting ``_descend`` from the round-start endpoint
    reaches the true endpoint — the scatter half stays scalar, the
    position-finding half is the one kernel pass.

    Returns (t, results, pending, dns): the round-start positions are
    handed back so the relaxed policies' ``forced_mask`` can identify the
    ΔNodes blocking still-pending ops without a second frontier walk
    (valid for buffer-blocked ops — bottom positions don't restructure
    within an op phase; a conflict loser's stale position at worst defers
    its forced repair one round, and the round loop retries it anyway).
    """

    def run(args):
        t, results, pending = args
        q = jax.vmap(cfg.qpack)(keys)
        dns, bs = _positions(cfg, t, q)
        if cfg.parallel_updates:
            t, results, pending = DT._parallel_fastpath(
                cfg, t, kinds, keys, payloads, results, pending, dns, bs)

        def seq_phase(args):
            t, results, pending = args
            k = keys.shape[0]
            pend_ids = jnp.nonzero(pending, size=budget, fill_value=-1)[0]

            def op_body(j, s):
                t, results, pending = s
                i = pend_ids[j]

                def run_op(args):
                    t, results, pending = args
                    ii = jnp.maximum(i, 0)
                    # batch order is the linearization: an op must wait
                    # while an *earlier* op on the same key is still
                    # pending (e.g. an insert blocked on a full buffer),
                    # else a later delete would miss its predecessor
                    blocked = jnp.any(pending & (keys == keys[ii])
                                      & (jnp.arange(k) < ii))
                    if cfg.engine == "lockstep":
                        dn0, b0 = dns[ii], bs[ii]
                    else:
                        dn0 = b0 = None

                    def ins(t):
                        return DT._insert_op(cfg, t, keys[ii], payloads[ii],
                                             dn0, b0)

                    def dele(t):
                        return DT._delete_op(cfg, t, keys[ii], dn0, b0)

                    def do(args):
                        t, results, pending = args
                        tt, ok, pend = jax.lax.cond(
                            kinds[ii] == DT.OP_INSERT, ins, dele, t)
                        return (tt, results.at[ii].set(ok),
                                pending.at[ii].set(pend))

                    return jax.lax.cond(blocked, lambda a: a, do,
                                        (t, results, pending))

                return jax.lax.cond(i >= 0, run_op, lambda a: a,
                                    (t, results, pending))

            return jax.lax.fori_loop(0, budget, op_body,
                                     (t, results, pending))

        t, results, pending = jax.lax.cond(
            jnp.any(pending), seq_phase, lambda a: a, (t, results, pending))
        return t, results, pending, dns

    def skip(args):
        t, results, pending = args
        # nothing pending: positions are unused downstream (forced_mask
        # only reads them where ``pending`` is True)
        return t, results, pending, jnp.zeros(keys.shape, jnp.int32)

    return jax.lax.cond(jnp.any(pending), run, skip,
                        (t, results, pending))


# --------------------------------------------------------------------------
# maintenance sweeps (shared by every policy)
# --------------------------------------------------------------------------


def _ins_sweep(cfg, t, work, mask, budget):
    """Process up to ``budget`` ins-flagged ΔNodes from ``mask`` (Rebalance
    or Expand).  Returns (t, work, processed-mask)."""
    m = cfg.max_dnodes
    ids = jnp.nonzero(mask, size=budget, fill_value=-1)[0]

    def body(j, s):
        t, work = s
        dn = ids[j]

        def run(s):
            t, work = s
            tt, rebuilds, expands = DT._process_ins(cfg, t, dn)
            return tt, (work[0] + rebuilds, work[1] + expands, work[2],
                        work[3])

        return jax.lax.cond(dn >= 0, run, lambda s: s, s)

    t, work = jax.lax.fori_loop(0, budget, body, (t, work))
    pmask = jnp.zeros((m,), bool).at[
        jnp.where(ids >= 0, ids, m)].set(True, mode="drop")
    return t, work, pmask


def _del_sweep(cfg, t, work, mask, budget):
    """Process up to ``budget`` Merge candidates from ``mask``."""
    ids = jnp.nonzero(mask, size=budget, fill_value=-1)[0]

    def body(j, s):
        t, work = s
        dn = ids[j]

        def run(s):
            t, work = s
            tt, merged = DT._process_del(cfg, t, dn)
            # freed arena slots = freelist growth across the splice
            return tt, (work[0], work[1], work[2] + merged,
                        work[3] + (tt.free_top - t.free_top))

        return jax.lax.cond(dn >= 0, run, lambda s: s, s)

    return jax.lax.fori_loop(0, budget, body, (t, work))


def _maint_phases(cfg, t, work, budget):
    """One eager maintenance pass: every ins-flagged ΔNode (Rebalance /
    Expand), then every Merge candidate, each under its own any-flagged
    cond.  Shared verbatim by `_run_eager`'s round body and `flush`'s —
    the "deferred batch + flush == eager, bit for bit" guarantee is
    structural, not copy-maintained."""
    t, work = jax.lax.cond(
        jnp.any(t.ins_flag & t.alive),
        lambda a: _ins_sweep(cfg, a[0], a[1],
                             a[0].ins_flag & a[0].alive, budget)[:2],
        lambda a: a, (t, work))
    t, work = jax.lax.cond(
        jnp.any(t.del_flag & t.alive),
        lambda a: _del_sweep(cfg, a[0], a[1],
                             a[0].del_flag & a[0].alive, budget),
        lambda a: a, (t, work))
    return t, work


# --------------------------------------------------------------------------
# eager — the pre-subsystem fixpoint loop, bit for bit
# --------------------------------------------------------------------------


def _run_eager(cfg, t, kinds, keys, payloads, results, pending, budget):
    def round_cond(s):
        t, _, pending, rounds, _ = s
        busy = jnp.any(pending) | jnp.any(t.ins_flag & t.alive) | jnp.any(
            t.del_flag & t.alive
        )
        return busy & (rounds < cfg.max_rounds)

    def round_body(s):
        t, results, pending, rounds, work = s
        with TR.annotate("maint.ops"):
            t, results, pending, _ = _ops_phase(cfg, t, results, pending,
                                                kinds, keys, payloads, budget)
        with TR.annotate("maint.sweep"):
            t, work = _maint_phases(cfg, t, work, budget)
        return t, results, pending, rounds + 1, work

    t, results, pending, rounds, work = jax.lax.while_loop(
        round_cond, round_body,
        (t, results, pending, jnp.int32(0), _zero_work()))
    return t, results, rounds, work


# --------------------------------------------------------------------------
# deferred / budgeted — carry flags forward, force only what blocks
# --------------------------------------------------------------------------


def _run_relaxed(cfg, policy: MaintenancePolicy, t, kinds, keys, payloads,
                 results, pending, budget):
    m = cfg.max_dnodes
    vol = policy.budget if policy.kind == "budgeted" else 0
    vol_k = min(vol, m) if vol else 0
    low_water = max(1, m // 8)  # freelist pressure threshold (slots)

    def forced_mask(t, pending, residual, dns):
        """ΔNodes that must be repaired now: targets of *blocked* pending
        ops (full target buffer — an op merely carried past the per-round
        sequential budget retries next round without maintenance),
        residual (I5'-violating) nodes, and — while residual exists —
        every full buffer (a keep's blocker is a full child buffer).
        ``dns`` are the round's op-phase positions (no second walk)."""
        blocked = pending & (t.bcount[jnp.clip(dns, 0, m - 1)]
                             >= cfg.buf_cap)
        mask = jnp.zeros((m,), bool).at[
            jnp.where(blocked, dns, m)].set(True, mode="drop")
        full = t.bcount >= cfg.buf_cap
        mask = mask | residual | (jnp.any(residual) & full)
        return mask & t.ins_flag & t.alive

    def voluntary_phase(args):
        """Budgeted-only: top-occupancy Rebalance/Expand repairs, then
        Merge candidates, sharing one per-batch repair budget."""
        t, work, repairs, residual = args
        occ = jnp.where(t.ins_flag & t.alive, t.bcount, -1)
        vals, ids = jax.lax.top_k(occ, vol_k)

        def ins_body(j, s):
            t, work, repairs, residual = s

            def run(s):
                t, work, repairs, residual = s
                tt, rb, ex = DT._process_ins(cfg, t, ids[j])
                # an Expand that "kept" items (full child) left dn in an
                # I5'-violating state — mark residual so the forced sweep
                # drains it before the step returns, same as forced repairs
                residual = residual.at[ids[j]].set(tt.bcount[ids[j]] > 0)
                return (tt, (work[0] + rb, work[1] + ex, work[2], work[3]),
                        repairs + 1, residual)

            return jax.lax.cond((vals[j] >= 0) & (repairs < vol), run,
                                lambda s: s, s)

        t, work, repairs, residual = jax.lax.fori_loop(
            0, vol_k, ins_body, (t, work, repairs, residual))
        # Merge-candidate selection.  Normally candidates run in arena
        # order (the historical ``nonzero`` order).  When the freelist
        # drops below the low-water mark, rank by the reclaimable-arena
        # estimate instead: candidates whose splice will return a child
        # slot to the freelist (live sibling, no children, drained
        # buffer) run first, so a starved allocator recovers slots
        # before the budget is spent on no-op merges.
        idx = jnp.arange(m, dtype=jnp.int32)
        cand = t.del_flag & t.alive
        sib_ok = t.child[jnp.maximum(t.parent, 0), t.pslot ^ 1] >= 0
        reclaim = ((t.parent >= 0) & sib_ok & (t.nchild == 0)
                   & (t.bcount == 0))
        pressure = t.free_top < low_water
        rank = jnp.where(cand,
                         idx + jnp.where(pressure & ~reclaim, m, 0),
                         2 * m)
        order = jnp.argsort(rank)[:vol_k].astype(jnp.int32)
        del_ids = jnp.where(rank[order] < 2 * m, order, -1)

        def del_body(j, s):
            t, work, repairs, residual = s
            dn = del_ids[j]
            # merging under a parent with buffered items would re-route
            # those items' descents into the merged child (I5' violation
            # that eager's fixpoint self-heals but a budget would strand) —
            # defer the merge until the parent drains
            p = t.parent[jnp.maximum(dn, 0)]
            parent_clear = t.bcount[jnp.maximum(p, 0)] == 0

            def run(s):
                t, work, repairs, residual = s
                tt, mg = DT._process_del(cfg, t, dn)
                return (tt, (work[0], work[1], work[2] + mg,
                             work[3] + (tt.free_top - t.free_top)),
                        repairs + 1, residual)

            return jax.lax.cond(
                (dn >= 0) & (repairs < vol) & parent_clear, run,
                lambda s: s, s)

        return jax.lax.fori_loop(0, vol_k, del_body,
                                 (t, work, repairs, residual))

    def round_cond(s):
        t, _, pending, rounds, work, repairs, residual = s
        busy = jnp.any(pending) | jnp.any(residual & t.alive)
        if vol:
            flagged = (t.ins_flag | t.del_flag) & t.alive
            busy = busy | ((repairs < vol) & jnp.any(flagged))
        return busy & (rounds < cfg.max_rounds)

    def round_body(s):
        t, results, pending, rounds, work, repairs, residual = s
        with TR.annotate("maint.ops"):
            t, results, pending, dns = _ops_phase(cfg, t, results, pending,
                                                  kinds, keys, payloads,
                                                  budget)
        if vol:
            t, work, repairs, residual = jax.lax.cond(
                (repairs < vol) & jnp.any((t.ins_flag | t.del_flag)
                                          & t.alive),
                voluntary_phase, lambda a: a, (t, work, repairs, residual))
        fmask = forced_mask(t, pending, residual, dns)

        def forced(args):
            t, work, residual = args
            with TR.annotate("maint.sweep"):
                t, work, pmask = _ins_sweep(cfg, t, work, fmask, budget)
            residual = (residual & ~pmask) | (pmask & (t.bcount > 0)
                                              & t.alive)
            return t, work, residual

        t, work, residual = jax.lax.cond(
            jnp.any(fmask), forced, lambda a: a, (t, work, residual))
        return t, results, pending, rounds + 1, work, repairs, residual

    t, results, pending, rounds, work, _, _ = jax.lax.while_loop(
        round_cond, round_body,
        (t, results, pending, jnp.int32(0), _zero_work(), jnp.int32(0),
         jnp.zeros((m,), bool)))
    return t, results, rounds, work


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------


def run_update(cfg, t, kinds, keys, payloads=None):
    """Apply one update batch under ``cfg.maintenance_policy``.

    Returns (tree, results[K] bool, MaintenanceStats) — the body behind
    ``deltatree.update_batch_impl``.
    """
    policy = parse_policy(cfg.maintenance)
    k = keys.shape[0]
    if payloads is None:
        payloads = jnp.zeros((k,), jnp.int32)
    results = jnp.zeros((k,), jnp.bool_)
    pending = kinds != DT.OP_SEARCH
    budget = min(k, 64)  # sequential work per round (leftovers re-round)

    if policy.eager:
        t, results, rounds, work = _run_eager(
            cfg, t, kinds, keys, payloads, results, pending, budget)
    else:
        t, results, rounds, work = _run_relaxed(
            cfg, policy, t, kinds, keys, payloads, results, pending, budget)
    stats = MaintenanceStats(
        rounds=rounds, rebuilds=work[0], expands=work[1], merges=work[2],
        pending=pending_count(cfg, t), reclaimed=work[3])
    return t, results, stats


def flush(cfg, t, budget: int = 64):
    """Drain every flagged ΔNode to the maintenance fixpoint (restores I5).

    The maintenance-only rounds are structured exactly like the eager
    loop's (same phase order, same per-phase ``budget``): a deferred batch
    followed by ``flush(budget=min(K, 64))`` reproduces the eager tree bit
    for bit whenever no op was force-blocked mid-batch.
    Returns (tree, MaintenanceStats).
    """

    def round_cond(s):
        t, rounds, _ = s
        busy = jnp.any(t.ins_flag & t.alive) | jnp.any(t.del_flag & t.alive)
        return busy & (rounds < cfg.max_rounds)

    def round_body(s):
        t, rounds, work = s
        with TR.annotate("maint.sweep"):
            t, work = _maint_phases(cfg, t, work, budget)
        return t, rounds + 1, work

    t, rounds, work = jax.lax.while_loop(
        round_cond, round_body, (t, jnp.int32(0), _zero_work()))
    stats = MaintenanceStats(
        rounds=rounds, rebuilds=work[0], expands=work[1], merges=work[2],
        pending=pending_count(cfg, t), reclaimed=work[3])
    return t, stats
