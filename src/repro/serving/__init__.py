from repro.serving.pager import DeltaPager, PagerConfig, make_pager
from repro.serving.engine import ServeEngine
from repro.serving.sharded_pager import ShardedDeltaPager, ShardedPagerConfig

__all__ = [
    "DeltaPager",
    "PagerConfig",
    "ServeEngine",
    "ShardedDeltaPager",
    "ShardedPagerConfig",
    "make_pager",
]
