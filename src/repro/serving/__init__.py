"""Serving package: ΔTree-paged KV cache + serve engines.

The engine names resolve lazily: ``repro.serving.engine`` pulls in the
continuous-batching scheduler (`repro.serve`), which itself imports the
pager from this package — eager re-export here would close that loop
mid-initialization.  Pager names stay eager (leaf modules).
"""

from repro.serving.pager import DeltaPager, PagerConfig, make_pager
from repro.serving.sharded_pager import ShardedDeltaPager, ShardedPagerConfig

__all__ = [
    "DeltaPager",
    "LockstepServeEngine",
    "PagerConfig",
    "ServeEngine",
    "ShardedDeltaPager",
    "ShardedPagerConfig",
    "make_pager",
]

_LAZY = ("ServeEngine", "LockstepServeEngine")


def __getattr__(name: str):
    if name in _LAZY:
        from repro.serving import engine

        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
