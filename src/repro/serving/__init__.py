from repro.serving.pager import DeltaPager, PagerConfig
from repro.serving.engine import ServeEngine

__all__ = ["DeltaPager", "PagerConfig", "ServeEngine"]
