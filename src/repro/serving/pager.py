"""ΔTree-backed KV-cache pager: the paper's structure on the serving hot path.

The (seq_id, logical_block) → physical_page mapping is a ΔTree in map mode
(key = seq_id * max_blocks + block + 1; payload = page id).  Every decode
step resolves block tables with a wait-free batched SEARCH; page allocation
is a batched INSERT; sequence teardown is a batched DELETE (+ Merge keeps
the index compact).  This is exactly the paper's claimed workload mix —
search-dominant with occasional updates — so the serving benchmark doubles
as a ΔTree macro-benchmark.

Requires 64-bit mode (packed int64 values): callers must run with
JAX_ENABLE_X64=1 or `jax.config.update("jax_enable_x64", True)`.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import (
    OP_DELETE,
    OP_INSERT,
    TreeConfig,
    empty,
    lookup_jit,
    update_batch,
)


@dataclasses.dataclass(frozen=True)
class PagerConfig:
    num_pages: int = 4096
    page_size: int = 16
    max_seqs: int = 256
    max_blocks: int = 1024        # logical blocks per sequence
    tree_height: int = 7          # UB=127 ΔNodes (paper's best)

    @property
    def payload_bits(self) -> int:
        return max(int(np.ceil(np.log2(self.num_pages))), 1)

    @property
    def tree_config(self) -> TreeConfig:
        # arena: every page mapped -> ~num_pages keys; half-dense ΔNodes
        need = max(64, int(4 * self.num_pages / (2 ** (self.tree_height - 1))))
        return TreeConfig(
            height=self.tree_height,
            max_dnodes=need,
            buf_cap=64,
            payload_bits=self.payload_bits,
        )


class DeltaPager:
    """Host-driven pager; tree ops are jitted batched ΔTree steps.

    The index is pluggable through four hooks (`_make_index`, `_key`,
    `_lookup`, `_update`) — `ShardedDeltaPager` overrides them to swap the
    single arena for a DeltaForest without touching the pager protocol.
    """

    def __init__(self, cfg: PagerConfig):
        self.cfg = cfg
        self._make_index()
        self.free_pages = list(range(cfg.num_pages - 1, -1, -1))
        self.seq_blocks: dict[int, int] = {}   # seq -> allocated blocks
        self.stats = {"searches": 0, "inserts": 0, "deletes": 0, "hops": 0}

    # ---- index hooks (overridden by ShardedDeltaPager) ----
    def _make_index(self) -> None:
        self.tcfg = self.cfg.tree_config
        self.tree = empty(self.tcfg)

    def _key(self, seq_id, block) -> np.ndarray:
        return (np.asarray(seq_id, np.int64) * self.cfg.max_blocks
                + np.asarray(block, np.int64) + 1).astype(np.int32)

    def _lookup(self, keys: np.ndarray):
        """(found, payload, hops) for a key batch (wait-free search)."""
        return lookup_jit(self.tcfg, self.tree, jnp.asarray(keys))

    def _update(self, kinds: np.ndarray, keys: np.ndarray,
                payloads: np.ndarray):
        """Apply a batched insert/delete step; returns per-op results."""
        self.tree, res, _ = update_batch(
            self.tcfg, self.tree, jnp.asarray(kinds), jnp.asarray(keys),
            jnp.asarray(payloads),
        )
        assert not bool(self.tree.alloc_fail), "ΔTree arena exhausted"
        return res

    # ---- mutations ----
    def allocate(self, seq_id: int, n_blocks: int) -> list[int]:
        """Allocate pages for logical blocks [cur, cur + n_blocks)."""
        start = self.seq_blocks.get(seq_id, 0)
        assert len(self.free_pages) >= n_blocks, "pager OOM"
        pages = [self.free_pages.pop() for _ in range(n_blocks)]
        keys = self._key(seq_id, np.arange(start, start + n_blocks))
        kinds = np.full(len(pages), OP_INSERT, np.int32)
        res = self._update(kinds, keys, np.asarray(pages, np.int32))
        assert bool(np.asarray(res).all()), "duplicate block allocation"
        self.seq_blocks[seq_id] = start + n_blocks
        self.stats["inserts"] += n_blocks
        return pages

    def free_seq(self, seq_id: int) -> None:
        n = self.seq_blocks.pop(seq_id, 0)
        if n == 0:
            return
        keys = self._key(seq_id, np.arange(n))
        found, pages, _ = self._lookup(keys)
        assert bool(np.asarray(found).all())
        kinds = np.full(n, OP_DELETE, np.int32)
        res = self._update(kinds, keys, np.zeros(n, np.int32))
        assert bool(np.asarray(res).all())
        self.free_pages.extend(int(p) for p in np.asarray(pages))
        self.stats["deletes"] += n

    # ---- the decode-step hot path ----
    def block_tables(self, seq_ids, max_blocks: int) -> np.ndarray:
        """(B, max_blocks) physical page table via wait-free ΔTree search."""
        seq_ids = np.asarray(seq_ids)
        b = len(seq_ids)
        keys = self._key(
            np.repeat(seq_ids, max_blocks),
            np.tile(np.arange(max_blocks), b),
        )
        found, pages, hops = self._lookup(keys)
        self.stats["searches"] += len(keys)
        self.stats["hops"] += int(np.asarray(hops).sum())
        table = np.where(np.asarray(found), np.asarray(pages), -1)
        return table.reshape(b, max_blocks).astype(np.int32)
