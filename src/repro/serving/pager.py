"""Index-backed KV-cache pager: the paper's structure on the serving hot path.

The (seq_id, logical_block) → physical_page mapping is any map-capable
``repro.api.Index`` (key = seq_id * max_blocks + block + 1; payload = page
id).  Every decode step resolves block tables with a wait-free batched
lookup; page allocation is a batched insert; sequence teardown is a batched
delete (+ Merge keeps a ΔTree index compact).  This is exactly the paper's
claimed workload mix — search-dominant with occasional updates — so the
serving benchmark doubles as a ΔTree macro-benchmark.

The default index is ``make_index("deltatree", cfg=cfg.tree_config)``;
``ShardedDeltaPager`` defaults to the forest backend and band-interleaves
the key encoding.  Any handle with ``Capability.map_mode`` can be injected
via the ``index=`` argument — the pager protocol never touches backend
internals.  ``PagerConfig.engine`` picks the SearchEngine the block-table
lookups run under (``"lockstep"`` = the Pallas vEB walk on the decode hot
path); ``PagerConfig.maintenance`` the index maintenance policy (with
``"deferred"`` + ``maint_high_water=N`` the serve scheduler's
MaintenanceWorker drains structural maintenance whenever N items are
buffered — ``flush_every`` is the deprecated stride-based trigger); both
thread through ``tree_config`` / ``forest_config`` into the default index.

Two mutation surfaces: the *immediate* protocol (``allocate`` /
``free_seq`` — one index update per call, the lockstep engine's path)
and the *staged* protocol (``stage_allocate`` / ``stage_free`` /
``apply_staged`` — host bookkeeping now, one combined index update per
scheduler step, with the same-key elimination pass of
``repro.serve.combine`` run over the whole staged batch).  Page
accounting (free list, per-seq block counts) is identical under both;
only the index-update batching differs.

Requires 64-bit mode (packed int64 values): callers must run with
JAX_ENABLE_X64=1 or `jax.config.update("jax_enable_x64", True)`.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np

from repro.api import Index, OpBatch, make_index
from repro.api.opbatch import OP_DELETE, OP_INSERT
from repro.core.deltatree import TreeConfig
from repro.obs import trace as TR


@dataclasses.dataclass(frozen=True)
class PagerConfig:
    num_pages: int = 4096
    page_size: int = 16
    max_seqs: int = 256
    max_blocks: int = 1024        # logical blocks per sequence
    tree_height: int = 7          # UB=127 ΔNodes (paper's best)
    engine: str = "scalar"        # SearchEngine for block-table lookups
    maintenance: str = "eager"    # index maintenance policy (repro.maintenance)
    maint_high_water: int = 0     # drain maintenance when this many items
    #                               sit buffered (MaintenanceStats.pending);
    #                               0 = no high-water trigger.  Only useful
    #                               with a non-eager policy — amortizes
    #                               Rebalance/Expand/Merge off the decode
    #                               path (serve.MaintenanceWorker / the
    #                               engines' step barrier)
    flush_every: int = 0          # DEPRECATED: flush() every N decode steps
    #                               regardless of how much work is actually
    #                               buffered; use maint_high_water.  Still
    #                               honored (with a DeprecationWarning) so
    #                               existing configs keep their behavior

    def __post_init__(self):
        if self.flush_every:
            warnings.warn(
                "PagerConfig.flush_every is deprecated: the fixed stride "
                "flushes on the decode path no matter how little work is "
                "buffered; set maint_high_water=N to drain when N items "
                "are pending instead",
                DeprecationWarning,
                stacklevel=3,
            )

    @property
    def payload_bits(self) -> int:
        return max(int(np.ceil(np.log2(self.num_pages))), 1)

    @property
    def tree_config(self) -> TreeConfig:
        # arena: every page mapped -> ~num_pages keys; half-dense ΔNodes
        need = max(64, int(4 * self.num_pages / (2 ** (self.tree_height - 1))))
        return TreeConfig(
            height=self.tree_height,
            max_dnodes=need,
            buf_cap=64,
            payload_bits=self.payload_bits,
            engine=self.engine,
            maintenance=self.maintenance,
        )

    def make_index(self) -> Index:
        """Default index for this config (single-arena ΔTree, map mode)."""
        return make_index("deltatree", cfg=self.tree_config)


class DeltaPager:
    """Host-driven pager over any map-capable Index handle.

    The key encoding (`_key`) is the only other extension point —
    `ShardedDeltaPager` overrides it (and the default index) to fan the
    block-table index out over a DeltaForest without touching the pager
    protocol.
    """

    def __init__(self, cfg: PagerConfig, index: Index | None = None):
        self.cfg = cfg
        self.index = index if index is not None else cfg.make_index()
        assert self.index.capability.map_mode, (
            f"pager needs a map-mode index, got {self.index!r} with "
            f"{self.index.capability}")
        self.free_pages = list(range(cfg.num_pages - 1, -1, -1))
        self.seq_blocks: dict[int, int] = {}   # seq -> allocated blocks
        self.pending = 0   # buffered items awaiting maintenance (I5' carry)
        self._staged: list[tuple[int, int, int]] = []  # (kind, key, payload)
        self._staged_pages: dict[int, list[int]] = {}  # seq -> pages (staged)
        self.stats = {"searches": 0, "inserts": 0, "deletes": 0, "hops": 0,
                      "flushes": 0, "maint_rebuilds": 0, "maint_expands": 0,
                      "maint_merges": 0, "combined": 0, "inline_maint": 0}
        # most recent ReadStats from a stats-collecting index (None when
        # the index doesn't collect) — the metrics-export snapshot source
        self.last_read_stats = None

    # ---- key encoding (overridden by ShardedDeltaPager) ----
    def _key(self, seq_id, block) -> np.ndarray:
        return (np.asarray(seq_id, np.int64) * self.cfg.max_blocks
                + np.asarray(block, np.int64) + 1).astype(np.int32)

    # ---- index protocol ----
    def _lookup(self, keys: np.ndarray):
        """(found, payload, hops) for a key batch (wait-free lookup).
        Tolerates a stats-collecting index (the trailing ReadStats is
        kept as ``last_read_stats`` for metrics export, not returned)."""
        out = self.index.lookup(jnp.asarray(keys))
        if len(out) > 3:
            self.last_read_stats = out[3]
        return out[0], out[1], out[2]

    def _update(self, kinds: np.ndarray, keys: np.ndarray,
                payloads: np.ndarray):
        """Apply a batched insert/delete step; returns per-op results.
        ``stats["inline_maint"]`` accumulates the structural maintenance
        (Rebalance + Expand + Merge) these update batches paid *on* the
        decode path — the number a background-worker policy drives to
        zero (the drained work shows up in ``maint_*`` instead)."""
        self.index, res, mstats = self.index.update(
            OpBatch.mixed(kinds, keys, payloads))
        if mstats is not None:
            self.pending = int(mstats.pending)
            self.stats["inline_maint"] += (
                int(mstats.rebuilds) + int(mstats.expands)
                + int(mstats.merges))
        assert not self.index.alloc_failed(), "pager index arena exhausted"
        return res

    # ---- mutations ----
    def allocate(self, seq_id: int, n_blocks: int) -> list[int]:
        """Allocate pages for logical blocks [cur, cur + n_blocks)."""
        start = self.seq_blocks.get(seq_id, 0)
        assert len(self.free_pages) >= n_blocks, "pager OOM"
        pages = [self.free_pages.pop() for _ in range(n_blocks)]
        keys = self._key(seq_id, np.arange(start, start + n_blocks))
        kinds = np.full(len(pages), OP_INSERT, np.int32)
        res = self._update(kinds, keys, np.asarray(pages, np.int32))
        assert bool(np.asarray(res).all()), "duplicate block allocation"
        self.seq_blocks[seq_id] = start + n_blocks
        self.stats["inserts"] += n_blocks
        return pages

    def free_seq(self, seq_id: int) -> None:
        n = self.seq_blocks.pop(seq_id, 0)
        if n == 0:
            return
        keys = self._key(seq_id, np.arange(n))
        found, pages, _ = self._lookup(keys)
        assert bool(np.asarray(found).all())
        kinds = np.full(n, OP_DELETE, np.int32)
        res = self._update(kinds, keys, np.zeros(n, np.int32))
        assert bool(np.asarray(res).all())
        self.free_pages.extend(int(p) for p in np.asarray(pages))
        self.stats["deletes"] += n

    # ---- staged mutations (the serve scheduler's protocol) ----

    def stage_allocate(self, seq_id: int, n_blocks: int) -> list[int]:
        """``allocate`` split in two: page accounting now (free-list pop,
        block-count bump — the scheduler needs the page ids to scatter
        prefill K/V), index inserts staged for the step's one combined
        ``apply_staged`` batch."""
        start = self.seq_blocks.get(seq_id, 0)
        assert len(self.free_pages) >= n_blocks, "pager OOM"
        pages = [self.free_pages.pop() for _ in range(n_blocks)]
        keys = self._key(seq_id, np.arange(start, start + n_blocks))
        self._staged.extend(
            (OP_INSERT, int(k), int(p)) for k, p in zip(keys, pages))
        self._staged_pages.setdefault(seq_id, []).extend(pages)
        self.seq_blocks[seq_id] = start + n_blocks
        self.stats["inserts"] += n_blocks
        return pages

    def stage_free(self, seq_id: int) -> None:
        """``free_seq`` for staged sequences: pages return to the free
        list now (host accounting — a same-step admission may recycle
        them under different keys), index deletes ride the next
        ``apply_staged`` batch.  No lookup needed: the staged protocol
        tracks each sequence's pages host-side, so freeing works even
        while the sequence's own inserts are still staged (in which case
        the combine pass annihilates the pair)."""
        n = self.seq_blocks.pop(seq_id, 0)
        if n == 0:
            return
        pages = self._staged_pages.pop(seq_id)
        assert len(pages) == n, (seq_id, len(pages), n)
        keys = self._key(seq_id, np.arange(n))
        self._staged.extend((OP_DELETE, int(k), 0) for k in keys)
        self.free_pages.extend(pages)
        self.stats["deletes"] += n

    def apply_staged(self) -> dict:
        """Apply all staged ops as ONE combined index update: the
        same-key elimination pass (`repro.serve.combine.combine_ops`)
        runs over the whole batch first, then a single ``_update`` —
        batch order preserved, so this is a valid linearization of the
        staged sequence.  Returns {"applied", "combined", "inline_maint"}
        for the step's obs row."""
        from repro.serve.combine import combine_ops

        if not self._staged:
            return {"applied": 0, "combined": 0, "inline_maint": 0}
        kinds, keys, pays = (np.asarray(c) for c in zip(*self._staged))
        self._staged.clear()
        kinds, keys, pays, combined = combine_ops(kinds, keys, pays)
        self.stats["combined"] += combined
        inline0 = self.stats["inline_maint"]
        if len(kinds):
            res = self._update(kinds.astype(np.int32), keys.astype(np.int32),
                               pays.astype(np.int32))
            assert bool(np.asarray(res).all()), \
                "staged batch violated the pager discipline"
        return {"applied": int(len(kinds)), "combined": combined,
                "inline_maint": self.stats["inline_maint"] - inline0}

    def flush(self):
        """Drain the index's pending maintenance (no-op under "eager").

        The serve scheduler's MaintenanceWorker calls this when
        ``pending`` crosses ``cfg.maint_high_water`` (the legacy engine:
        every ``cfg.flush_every`` decode steps) — the background hook
        that amortizes structural maintenance across serving steps
        instead of paying it inside allocate/free.
        Returns the MaintenanceStats (or None)."""
        self.index, mstats = self.index.flush()
        if mstats is not None:
            self.pending = int(mstats.pending)
            self.stats["flushes"] += 1
            self.stats["maint_rebuilds"] += int(mstats.rebuilds)
            self.stats["maint_expands"] += int(mstats.expands)
            self.stats["maint_merges"] += int(mstats.merges)
        return mstats

    # ---- the decode-step hot path ----
    def block_tables(self, seq_ids, max_blocks: int) -> np.ndarray:
        """(B, max_blocks) physical page table via wait-free Index lookup."""
        seq_ids = np.asarray(seq_ids)
        b = len(seq_ids)
        keys = self._key(
            np.repeat(seq_ids, max_blocks),
            np.tile(np.arange(max_blocks), b),
        )
        with TR.span("pager.block_tables"):
            found, pages, hops = self._lookup(keys)
        self.stats["searches"] += len(keys)
        self.stats["hops"] += int(np.asarray(hops).sum())
        table = np.where(np.asarray(found), np.asarray(pages), -1)
        return table.reshape(b, max_blocks).astype(np.int32)


def make_pager(cfg: PagerConfig, index: Index | None = None) -> DeltaPager:
    """Pager for a config: ShardedPagerConfig gets the band-interleaved
    ShardedDeltaPager, anything else the plain DeltaPager.  ``index``
    overrides the config's default backend (any map-capable handle)."""
    from repro.serving.sharded_pager import ShardedDeltaPager, ShardedPagerConfig

    if isinstance(cfg, ShardedPagerConfig):
        return ShardedDeltaPager(cfg, index)
    return DeltaPager(cfg, index)
