"""Sharded pager: the (seq_id, block) map fanned out over a DeltaForest.

Same protocol as `DeltaPager` (allocate / free_seq / block_tables) — this is
a subclass that swaps the default Index backend and the key encoding,
nothing else.  The serving engine assigns seq ids *sequentially*, so
sharding their natural key encoding by range would pile every live sequence
into shard 0; instead the key encoding band-interleaves sequences:

    shard  = seq_id mod S                    (round-robin across shards)
    key    = shard * band + (seq_id div S) * max_blocks + block + 1
    band   = ceil(max_seqs / S) * max_blocks (one shard's contiguous range)

Each shard owns one contiguous key band — exactly the forest's equi-width
partition over [1, S*band] — while consecutive seq ids land on different
shards, so the per-step block-table resolution fans out across devices and
per-shard load stays balanced for any window of active sequences.

Requires 64-bit mode (packed int64 values), like `DeltaPager`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.api import Index, make_index
from repro.distributed.forest import ForestConfig
from repro.serving.pager import DeltaPager, PagerConfig


@dataclasses.dataclass(frozen=True)
class ShardedPagerConfig(PagerConfig):
    num_shards: int = 4

    @property
    def seqs_per_shard(self) -> int:
        return -(-self.max_seqs // self.num_shards)

    @property
    def band(self) -> int:
        """Width of one shard's contiguous key range."""
        return self.seqs_per_shard * self.max_blocks

    @property
    def forest_config(self) -> ForestConfig:
        # per-shard arena: round-robin seq placement keeps shards balanced,
        # so ~num_pages/S mapped keys each; 8x half-dense headroom (2x the
        # single-tree pager's) absorbs moderate imbalance
        per_shard = max(
            64, int(8 * self.num_pages / self.num_shards
                    / (2 ** (self.tree_height - 1))))
        tcfg = dataclasses.replace(self.tree_config, max_dnodes=per_shard)
        return ForestConfig(
            num_shards=self.num_shards,
            tree=tcfg,
            key_min=1,
            key_max=self.num_shards * self.band,
        )

    def make_index(self) -> Index:
        # equi-width over [1, S*band] == the band boundaries by construction
        return make_index("forest", cfg=self.forest_config)


class ShardedDeltaPager(DeltaPager):
    """Drop-in `DeltaPager` whose default index is a DeltaForest."""

    cfg: ShardedPagerConfig

    def _key(self, seq_id, block) -> np.ndarray:
        seq_id = np.asarray(seq_id, np.int64)
        # beyond S*seqs_per_shard the band encoding stops being injective —
        # fail loudly instead of silently colliding across bands
        assert (seq_id < self.cfg.num_shards * self.cfg.seqs_per_shard).all(), \
            "seq_id exceeds max_seqs capacity of the sharded pager"
        shard = seq_id % self.cfg.num_shards
        lane = seq_id // self.cfg.num_shards
        return (shard * self.cfg.band + lane * self.cfg.max_blocks
                + np.asarray(block, np.int64) + 1).astype(np.int32)
