"""Serving engines over the ΔTree-paged KV cache.

``ServeEngine`` — the public name tests/benchmarks construct — is now a
thin compat shim over the continuous-batching scheduler
(`repro.serve.scheduler.ServeScheduler`): same constructor signature
(``max_batch`` maps to the scheduler's live-lane count), same
``submit/step/active`` surface, strictly more behavior (admission
control, slot recycling, combined staged updates, background
maintenance).

``LockstepServeEngine`` is the pre-scheduler loop, kept verbatim as the
parity oracle: it steps all live requests in rigid lockstep, applies
every pager mutation immediately, and drains maintenance *on* the decode
path — either on the deprecated ``flush_every`` stride or when
``PagerConfig.maint_high_water`` items sit buffered.  The static-trace
parity test holds the scheduler bit-identical to it under no-churn +
eager maintenance.

Both engines share the exact same model-side machinery
(`repro.serve.decode`): dense prefill scattered into pages, then per
step one `delta_paged_attention` pass over the pager-resolved block
tables (wait-free batched search — the paper's hot path).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.api import Index
from repro.models.config import ModelConfig
from repro.obs import trace as OT
from repro.obs.stats import ServeStats
from repro.serve import decode as D
from repro.serve.scheduler import SchedulerConfig, ServeScheduler
from repro.serving.pager import DeltaPager, PagerConfig, make_pager


class ServeEngine(ServeScheduler):
    """Compat shim: the legacy constructor over the new scheduler.

    ``max_batch`` becomes ``SchedulerConfig.max_live`` — the bounded
    decode-lane count the admission queue fills.  Everything else
    (admission control bounds, combining, the maintenance high-water)
    comes from the pager config / scheduler defaults."""

    def __init__(self, cfg: ModelConfig, params, pager_cfg: PagerConfig,
                 max_batch: int = 8, *, index: Index | None = None,
                 pager: DeltaPager | None = None):
        super().__init__(cfg, params, pager_cfg,
                         SchedulerConfig(max_live=max_batch),
                         index=index, pager=pager)
        self.max_batch = max_batch


@dataclasses.dataclass
class Request:
    seq_id: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class LockstepServeEngine:
    """The legacy loop: submit prefills immediately, every step decodes
    all live requests (capped at ``max_batch``), mutations hit the index
    one call at a time, maintenance drains inline."""

    def __init__(self, cfg: ModelConfig, params, pager_cfg: PagerConfig,
                 max_batch: int = 8, *, index: Index | None = None,
                 pager: DeltaPager | None = None):
        """``index`` may be any map-capable Index handle (deltatree, forest,
        or a future backend) — the engine never branches on the backend;
        ``pager`` injects a fully custom pager protocol."""
        assert cfg.family in ("dense", "moe", "vlm"), cfg.family
        assert not cfg.mla, "engine supports GQA caches"
        self.cfg = cfg
        self.params = params
        self.pager = pager if pager is not None else make_pager(pager_cfg, index)
        pager_cfg = self.pager.cfg
        self.ps = pager_cfg.page_size
        self.max_batch = max_batch
        L, NP = cfg.num_layers, pager_cfg.num_pages
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.dtype)
        self.k_pages = jnp.zeros((L, NP, self.ps, kvh, hd), dt)
        self.v_pages = jnp.zeros((L, NP, self.ps, kvh, hd), dt)
        self.active: dict[int, Request] = {}
        self.lengths: dict[int, int] = {}
        self._next_id = 0
        self._steps = 0   # decode steps taken (drives the inline flush)
        self.obs = ServeStats.zero()   # decode-latency reservoir + flush log

    # ------------------------------------------------------------- submit ---

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> int:
        sid = self._next_id
        self._next_id += 1
        req = Request(sid, np.asarray(prompt, np.int32), max_new)
        n_blocks = -(-len(req.prompt) // self.ps)
        pages = self.pager.allocate(sid, n_blocks)
        self.k_pages, self.v_pages, s, tok = D.prefill_to_pages(
            self.cfg, self.params, self.ps, self.k_pages, self.v_pages,
            req.prompt, pages)
        self.lengths[sid] = s
        req.out.append(tok)
        self.active[sid] = req
        return sid

    # --------------------------------------------------------------- step ---

    def step(self) -> dict[int, int]:
        """One decode step for all active sequences; returns {seq: token}.

        Every non-empty step records one sample into ``self.obs`` (the
        decode-latency reservoir + flush log + pending high-water) — the
        serve benchmark's p50/p99 come straight from it."""
        t0 = time.perf_counter()
        with OT.span("serve.step"):
            out, flushed = self._step()
        if out:
            self.obs = self.obs.record(time.perf_counter() - t0,
                                       pending=self.pager.pending,
                                       flushed=flushed)
        return out

    def _step(self):
        cfg = self.cfg
        sids = [s for s, r in self.active.items() if not r.done][: self.max_batch]
        if not sids:
            return {}, False
        # grow pages where the next token crosses a page boundary
        for sid in sids:
            needed = self.lengths[sid] // self.ps + 1
            have = self.pager.seq_blocks[sid]
            if needed > have:
                self.pager.allocate(sid, needed - have)

        lens = np.asarray([self.lengths[s] for s in sids], np.int32)
        maxp = int(max(lens)) // self.ps + 1
        bt = self.pager.block_tables(sids, maxp)          # ΔTree hot path
        tokens = jnp.asarray([[self.active[s].out[-1]] for s in sids], jnp.int32)

        logits, self.k_pages, self.v_pages = D.paged_decode_step(
            self.params, cfg, D.layer_params(cfg, self.params), tokens,
            self.k_pages, self.v_pages, jnp.asarray(bt), jnp.asarray(lens),
            self.ps,
        )
        for sid in sids:
            self.lengths[sid] += 1
        self._steps += 1
        # inline maintenance: with a non-eager pager policy, updates
        # (allocate/free) only append/mark and the structural work drains
        # here — on the pending high-water mark (preferred) or the
        # deprecated fixed stride.  Both fields are explicit PagerConfig
        # surface now, no duck-typed getattr probe.
        hw = self.pager.cfg.maint_high_water
        fe = self.pager.cfg.flush_every
        flushed = bool((hw and self.pager.pending >= hw)
                       or (fe and self._steps % fe == 0))
        if flushed:
            self.pager.flush()
        out = {}
        for bi, sid in enumerate(sids):
            tok = int(jnp.argmax(logits[bi, 0]))
            req = self.active[sid]
            req.out.append(tok)
            out[sid] = tok
            if len(req.out) >= req.max_new:
                req.done = True
                self.finish(sid)
        return out, flushed

    def finish(self, sid: int):
        self.pager.free_seq(sid)
        self.lengths.pop(sid, None)
