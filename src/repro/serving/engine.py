"""Batched serving engine: continuous batching over a ΔTree-paged KV cache.

Supports the GQA decoder families (dense / moe / vlm backbones).  Layer
K/V live in page pools (L, NP, PS, KVH, HD); every decode step:
  1. resolves each active sequence's block table via the ΔTree pager
     (wait-free batched search — the paper's hot path),
  2. runs `delta_paged_attention` per layer (Pallas kernel, compiled on
     TPU, interpret mode elsewhere — `kernels.ops.default_interpret`),
  3. appends the new K/V into the tail page slot, allocating a fresh page
     (ΔTree insert) when a sequence crosses a page boundary.

Finished sequences free their pages (ΔTree delete → Merge compaction).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers.attention import attn_out, qkv_proj
from repro.models.layers.basic import (
    embed_apply,
    logits_apply,
    mlp_apply,
    rmsnorm_apply,
)
from repro.models.layers.moe import moe_apply
from repro.kernels.delta_paged_attention import paged_decode_attention
from repro.api import Index
from repro.obs import trace as OT
from repro.obs.stats import ServeStats
from repro.serving.pager import DeltaPager, PagerConfig, make_pager


@dataclasses.dataclass
class Request:
    seq_id: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, pager_cfg: PagerConfig,
                 max_batch: int = 8, *, index: Index | None = None,
                 pager: DeltaPager | None = None):
        """``index`` may be any map-capable Index handle (deltatree, forest,
        or a future backend) — the engine never branches on the backend;
        ``pager`` injects a fully custom pager protocol."""
        assert cfg.family in ("dense", "moe", "vlm"), cfg.family
        assert not cfg.mla, "engine supports GQA caches"
        self.cfg = cfg
        self.params = params
        self.pager = pager if pager is not None else make_pager(pager_cfg, index)
        pager_cfg = self.pager.cfg
        self.ps = pager_cfg.page_size
        self.max_batch = max_batch
        L, NP = cfg.num_layers, pager_cfg.num_pages
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.dtype)
        self.k_pages = jnp.zeros((L, NP, self.ps, kvh, hd), dt)
        self.v_pages = jnp.zeros((L, NP, self.ps, kvh, hd), dt)
        self.active: dict[int, Request] = {}
        self.lengths: dict[int, int] = {}
        self._next_id = 0
        self._steps = 0   # decode steps taken (drives the background flush)
        self.obs = ServeStats.zero()   # decode-latency reservoir + flush log

    # ------------------------------------------------------------- submit ---

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> int:
        sid = self._next_id
        self._next_id += 1
        req = Request(sid, np.asarray(prompt, np.int32), max_new)
        n_blocks = -(-len(req.prompt) // self.ps)
        pages = self.pager.allocate(sid, n_blocks)
        self._prefill(req, pages)
        self.active[sid] = req
        return sid

    def _layer_params(self):
        """Unstack scan-stacked params into per-layer list."""
        cfg = self.cfg
        n_pro, period, reps = T._layout(cfg)
        out = list(self.params["prologue"])
        for r in range(reps):
            for j in range(period):
                out.append(jax.tree.map(lambda x: x[r], self.params["slots"][j]))
        return out

    def _prefill(self, req: Request, pages: list[int]):
        """Dense prefill, then scatter K/V into the allocated pages."""
        cfg = self.cfg
        toks = jnp.asarray(req.prompt)[None]
        s = toks.shape[1]
        caches = T.init_caches(cfg, 1, -(-s // self.ps) * self.ps)
        logits, caches = T.prefill(self.params, cfg, toks, caches)
        # flatten slot caches to per-layer order
        n_pro, period, reps = T._layout(cfg)
        layer_caches = list(caches["prologue"])
        for r in range(reps):
            for j in range(period):
                layer_caches.append(
                    jax.tree.map(lambda x: x[r], caches["slots"][j]))
        for li, c in enumerate(layer_caches):
            k = c["k"][0]  # (Smax, KVH, HD)
            v = c["v"][0]
            for bi, page in enumerate(pages):
                sl = slice(bi * self.ps, (bi + 1) * self.ps)
                self.k_pages = self.k_pages.at[li, page].set(k[sl])
                self.v_pages = self.v_pages.at[li, page].set(v[sl])
        self.lengths[req.seq_id] = s
        req.out.append(int(jnp.argmax(logits[0, -1])))

    # --------------------------------------------------------------- step ---

    def step(self) -> dict[int, int]:
        """One decode step for all active sequences; returns {seq: token}.

        Every non-empty step records one sample into ``self.obs`` (the
        decode-latency reservoir + flush log + pending high-water) — the
        serve benchmark's p50/p99 come straight from it."""
        t0 = time.perf_counter()
        with OT.span("serve.step"):
            out, flushed = self._step()
        if out:
            self.obs = self.obs.record(time.perf_counter() - t0,
                                       pending=self.pager.pending,
                                       flushed=flushed)
        return out

    def _step(self):
        cfg = self.cfg
        sids = [s for s, r in self.active.items() if not r.done][: self.max_batch]
        if not sids:
            return {}, False
        # grow pages where the next token crosses a page boundary
        for sid in sids:
            if self.lengths[sid] % self.ps == 0 and self.lengths[sid] > 0:
                pass  # boundary handled below via need-alloc check
            needed = self.lengths[sid] // self.ps + 1
            have = self.pager.seq_blocks[sid]
            if needed > have:
                self.pager.allocate(sid, needed - have)

        lens = np.asarray([self.lengths[s] for s in sids], np.int32)
        maxp = int(max(lens)) // self.ps + 1
        bt = self.pager.block_tables(sids, maxp)          # ΔTree hot path
        tokens = jnp.asarray([[self.active[s].out[-1]] for s in sids], jnp.int32)

        logits, self.k_pages, self.v_pages = _paged_decode_step(
            self.params, cfg, self._layer_params(), tokens,
            self.k_pages, self.v_pages, jnp.asarray(bt), jnp.asarray(lens),
            self.ps,
        )
        for sid in sids:
            self.lengths[sid] += 1
        self._steps += 1
        # background maintenance: with a non-eager pager policy, updates
        # (allocate/free) only append/mark and the structural work drains
        # here, amortized across decode steps instead of blocking a batch
        fe = getattr(self.pager.cfg, "flush_every", 0)
        flushed = bool(fe and self._steps % fe == 0)
        if flushed:
            self.pager.flush()
        out = {}
        for bi, sid in enumerate(sids):
            tok = int(jnp.argmax(logits[bi, 0]))
            req = self.active[sid]
            req.out.append(tok)
            out[sid] = tok
            if len(req.out) >= req.max_new:
                req.done = True
                self.finish(sid)
        return out, flushed

    def finish(self, sid: int):
        self.pager.free_seq(sid)
        self.lengths.pop(sid, None)


def _paged_decode_step(params, cfg: ModelConfig, layer_params, tokens,
                       k_pages, v_pages, block_tables, lengths, page_size):
    """One decode step over paged caches: per layer, scatter the new token's
    K/V into each sequence's tail page slot, then run the Pallas paged
    decode-attention kernel over the block table."""
    x = embed_apply(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = lengths[:, None].astype(jnp.int32)
    b = tokens.shape[0]
    rows = jnp.arange(b)
    tail_page = block_tables[rows, lengths // page_size]
    tail_off = lengths % page_size
    for li, lp in enumerate(layer_params):
        kinds = (cfg.layer_kind(li), cfg.ffn_kind(li))
        h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
        q, k, v = qkv_proj(lp["mixer"], cfg, h, positions)
        k_pages = k_pages.at[li, tail_page, tail_off].set(
            k[:, 0].astype(k_pages.dtype))
        v_pages = v_pages.at[li, tail_page, tail_off].set(
            v[:, 0].astype(v_pages.dtype))
        o = paged_decode_attention(
            q[:, 0], k_pages[li], v_pages[li], block_tables, lengths + 1)
        x = x + attn_out(lp["mixer"], o[:, None])
        if "ffn" in lp:
            h2 = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
            if kinds[1] == "moe":
                x = x + moe_apply(lp["ffn"], cfg, h2)
            else:
                x = x + mlp_apply(lp["ffn"], h2)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = logits_apply(params["embed"], x, cfg.logits_softcap)
    return logits, k_pages, v_pages
