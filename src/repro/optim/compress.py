"""Int8 gradient/delta compression for the slow cross-pod hop.

Per-tensor symmetric int8 quantization with an f32 scale.  Used by the
DiLoCo-style cross-pod sync in train.py: the inner SPMD all-reduce stays
full-precision intra-pod; the (infrequent) cross-pod parameter-delta
exchange is compressed 4× (bf16→int8 would be 2×; vs f32 master it is 4×).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_pmean(tree, axis_name: str):
    """int8-compressed mean over a mesh axis (use inside shard_map).

    Quantize locally, all-gather the int8 payload (the wire format stays
    int8 — 4× less inter-pod traffic than f32, 2× less than bf16), then
    dequantize each shard with its own scale and average locally.  Exact
    w.r.t. the per-shard quantization (no scale mixing).
    """
    n = jax.lax.psum(1, axis_name)

    def one(x):
        q, s = quantize_int8(x)
        qs = jax.lax.all_gather(q, axis_name)            # (n, ...) int8 wire
        ss = jax.lax.all_gather(s, axis_name)            # (n,) f32 (tiny)
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
        return (deq.sum(axis=0) / n).astype(x.dtype)

    return jax.tree.map(one, tree)
