"""AdamW with global-norm clipping and cosine schedule (from scratch).

Moment dtype is configurable: bf16 moments halve optimizer HBM (needed to
fit the 398B arch on a 256-chip pod at 16 GB/chip; DESIGN.md §7) — f32 for
small runs.  States shard exactly like their params (jit out_shardings).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"   # "bfloat16" for the huge archs
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(cfg: AdamWConfig, params):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(sdt),
            v32.astype(sdt),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
