from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.optim.compress import dequantize_int8, quantize_int8

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
    "quantize_int8", "dequantize_int8",
]
