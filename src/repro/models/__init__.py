"""Model substrate: configs, layers, transformer / enc-dec assemblies."""
