"""Decoder-only LM: scan-over-layers with heterogeneous pattern periods.

The layer stack is grouped into `period = cfg.pattern_period` slots (dense:
1; jamba: 8 — 7 SSD + 1 attn with alternating MoE).  Params for slot j are
stacked over the `reps = L // period` repetitions and applied with
`lax.scan`, keeping the HLO compact enough to compile 80-layer models on a
512-device dry-run mesh.  `cfg.remat` wraps each scan body in
jax.checkpoint (policy: nothing saveable — §Perf iterates on this).

Three entry points per the assignment's shapes:
  forward_train   (train_4k)      tokens -> logits
  prefill         (prefill_32k)   tokens -> (logits_last, caches)
  decode_step     (decode_32k / long_500k)  token + caches -> (logits, caches)

VLM family: `vision_embeds` (precomputed patch embeddings — frontend stub)
are concatenated in front of the token embeddings.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.config import ModelConfig
from repro.models.scan_utils import scan_or_unroll
from repro.models.layers.basic import (
    embed_apply,
    init_embedding,
    init_rmsnorm,
    logits_apply,
    rmsnorm_apply,
)
from repro.parallel.ax import constrain


def _remat_policy(cfg: ModelConfig):
    """Checkpoint policy: 'nothing' = min memory / max recompute;
    'dots' = save matmul outputs (no backward recompute of the big GEMMs)
    — §Perf trade-off knob."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


# ----------------------------------------------------------------- params ---


def _layout(cfg: ModelConfig):
    """(n_prologue, period, reps): prologue layers (e.g. DeepSeek's leading
    dense-FFN layer) are applied unscanned; the rest scan over the pattern."""
    period = cfg.pattern_period
    n_pro = cfg.dense_layers
    assert (cfg.num_layers - n_pro) % period == 0, (cfg.num_layers, n_pro, period)
    return n_pro, period, (cfg.num_layers - n_pro) // period


def init_params(key, cfg: ModelConfig):
    cfg.validate()
    n_pro, period, reps = _layout(cfg)
    k_embed, k_final, *k_layers = jax.random.split(key, 2 + cfg.num_layers)

    prologue = [B.init_block(k_layers[i], cfg, i) for i in range(n_pro)]
    slots = []
    for j in range(period):
        per_rep = [
            B.init_block(k_layers[n_pro + r * period + j], cfg,
                         n_pro + r * period + j)
            for r in range(reps)
        ]
        slots.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))

    return {
        "embed": init_embedding(
            k_embed, cfg.vocab_size, cfg.d_model, jnp.dtype(cfg.param_dtype),
            tie=cfg.tie_embeddings,
        ),
        "prologue": prologue,
        "slots": slots,
        "final_norm": init_rmsnorm(cfg.d_model, jnp.dtype(cfg.param_dtype)),
    }


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def _slot_kinds(cfg: ModelConfig):
    n_pro, period, reps = _layout(cfg)
    kinds = [B.block_kinds(cfg, n_pro + j) for j in range(period)]
    for j in range(period):  # pattern must be uniform across reps
        for r in range(1, reps):
            assert B.block_kinds(cfg, n_pro + r * period + j) == kinds[j]
    return kinds


# ---------------------------------------------------------------- forward ---


def _embed_inputs(params, cfg: ModelConfig, tokens, vision_embeds=None):
    x = embed_apply(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        assert vision_embeds is not None
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return constrain(x, "batch", "seq", "embed"), positions


def forward_train(params, cfg: ModelConfig, tokens, vision_embeds=None):
    """tokens: (B, S_text) -> logits (B, S_total, V)."""
    x, positions = _embed_inputs(params, cfg, tokens, vision_embeds)
    kinds = _slot_kinds(cfg)

    for i, lp in enumerate(params["prologue"]):
        x = B.block_train(lp, cfg, B.block_kinds(cfg, i), x, positions)

    period = cfg.pattern_period

    def body(x, slot_params):
        for j, kp in enumerate(slot_params):
            blk = lambda kp, x, j=j: B.block_train(kp, cfg, kinds[j], x,
                                                   positions)
            if cfg.remat and period > 1:
                # nested per-layer remat: bounds the backward live set to
                # ONE layer of a multi-layer pattern (jamba's 8-layer
                # super-block otherwise keeps 7 SSD layers' intermediates
                # alive; §Perf iteration)
                blk = jax.checkpoint(blk, policy=_remat_policy(cfg))
            x = blk(kp, x)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, _ = scan_or_unroll(body, x, params["slots"], cfg.unroll)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return logits_apply(params["embed"], x, cfg.logits_softcap)


def xent(logits, labels):
    """Cross entropy friendly to vocab-sharded logits: logsumexp (partial
    reduce + tiny all-reduce) and a one-hot contraction instead of a gather
    across vocab shards.  The one-hot rides in bf16 (0/1 exact) — halves the
    largest loss-side tensor's bytes (§Perf iteration)."""
    logits = constrain(logits, "batch", "seq", "vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.bfloat16)
    lab = jnp.einsum("bsv,bsv->bs", logits.astype(jnp.bfloat16), onehot,
                     preferred_element_type=jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    return ((lse - lab) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token cross entropy. batch: {tokens, labels[, vision_embeds]}."""
    logits = forward_train(
        params, cfg, batch["tokens"], batch.get("vision_embeds")
    )
    labels = batch["labels"]
    if cfg.family == "vlm":  # vision prefix carries no labels
        logits = logits[:, -labels.shape[1]:]
    return xent(logits, labels)


# ------------------------------------------------------------------ cache ---


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """{'prologue': [...], 'slots': [stacked (reps, ...) per slot]}."""
    n_pro, period, reps = _layout(cfg)
    kinds = _slot_kinds(cfg)
    dtype = jnp.dtype(cfg.dtype)

    def stack(tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (reps,) + x.shape), tree)

    return {
        "prologue": [
            B.init_block_cache(cfg, B.block_kinds(cfg, i), batch, max_len, dtype)
            for i in range(n_pro)
        ],
        "slots": [
            stack(B.init_block_cache(cfg, kinds[j], batch, max_len, dtype))
            for j in range(period)
        ],
    }


def prefill(params, cfg: ModelConfig, tokens, caches, vision_embeds=None):
    """Fill caches[...][:, :S]; returns (last-position logits, caches)."""
    x, positions = _embed_inputs(params, cfg, tokens, vision_embeds)
    kinds = _slot_kinds(cfg)

    pro_caches = []
    for i, (lp, kc) in enumerate(zip(params["prologue"], caches["prologue"])):
        x, nc = B.block_prefill(lp, cfg, B.block_kinds(cfg, i), x, positions, kc)
        pro_caches.append(nc)

    def body(x, slot):
        slot_params, slot_cache = slot
        new_caches = []
        for j, (kp, kc) in enumerate(zip(slot_params, slot_cache)):
            x, nc = B.block_prefill(kp, cfg, kinds[j], x, positions, kc)
            new_caches.append(nc)
        return x, new_caches

    if cfg.remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, new_caches = scan_or_unroll(body, x, (params["slots"], caches["slots"]), cfg.unroll)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = logits_apply(params["embed"], x[:, -1:], cfg.logits_softcap)
    return logits, {"prologue": pro_caches, "slots": new_caches}


def decode_step(params, cfg: ModelConfig, token, caches, length):
    """token: (B,1) int32; length: (B,) cached tokens. -> (logits, caches)."""
    x = embed_apply(params["embed"], token).astype(jnp.dtype(cfg.dtype))
    positions = length[:, None].astype(jnp.int32)
    kinds = _slot_kinds(cfg)

    pro_caches = []
    for i, (lp, kc) in enumerate(zip(params["prologue"], caches["prologue"])):
        x, nc = B.block_decode(lp, cfg, B.block_kinds(cfg, i), x, positions, kc,
                               length)
        pro_caches.append(nc)

    def body(x, slot):
        slot_params, slot_cache = slot
        new_caches = []
        for j, (kp, kc) in enumerate(zip(slot_params, slot_cache)):
            x, nc = B.block_decode(kp, cfg, kinds[j], x, positions, kc, length)
            new_caches.append(nc)
        return x, new_caches

    x, new_caches = scan_or_unroll(body, x, (params["slots"], caches["slots"]), cfg.unroll)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = logits_apply(params["embed"], x, cfg.logits_softcap)
    return logits, {"prologue": pro_caches, "slots": new_caches}
