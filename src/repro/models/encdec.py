"""Encoder-decoder backbone (Whisper-style; audio family).

The conv frontend is a STUB per the assignment: `input_specs` provides
precomputed frame embeddings (B, T_enc, d_model); the encoder is a
bidirectional transformer over them, the decoder adds cross-attention.
(RoPE is used for positions in place of Whisper's learned embeddings —
backbone-level fidelity; noted in DESIGN.md.)

Decode-time caches: per decoder layer, self-attn K/V (growing) plus
cross-attn K/V (computed once from the encoder output at prefill).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.scan_utils import scan_or_unroll
from repro.models.layers.attention import (
    attention_naive,
    attn_out,
    decode_attention,
    flash_attention,
    init_attention,
    qkv_proj,
)
from repro.models.layers.basic import (
    embed_apply,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    logits_apply,
    mlp_apply,
    rmsnorm_apply,
)


def _attn(params, cfg, x, positions, causal, rope=True):
    q, k, v = qkv_proj(params, cfg, x, positions, rope=rope)
    if x.shape[1] > cfg.flash_threshold:
        o = flash_attention(q, k, v, causal=causal, q_chunk=cfg.attn_chunk,
                            kv_chunk=cfg.attn_chunk)
    else:
        o = attention_naive(q, k, v, causal=causal)
    return attn_out(params, o)


def _cross_kv(params, cfg, enc_out):
    b, t, _ = enc_out.shape
    k = jnp.einsum("btd,de->bte", enc_out, params["wk"])
    v = jnp.einsum("btd,de->bte", enc_out, params["wv"])
    if cfg.qkv_bias:
        k, v = k + params["bk"], v + params["bv"]
    k = k.reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def _cross_attn(params, cfg, x, k, v):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    o = attention_naive(q, k, v, causal=False)
    return attn_out(params, o)


# ----------------------------------------------------------------- params ---


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 2 + 2 * cfg.encoder_layers + 3 * cfg.num_layers)
    ki = iter(keys)
    enc_layers = []
    for _ in range(cfg.encoder_layers):
        enc_layers.append({
            "norm1": init_rmsnorm(cfg.d_model, dtype),
            "attn": init_attention(next(ki), cfg),
            "norm2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(next(ki), cfg.d_model, cfg.d_ff, dtype),
        })
    dec_layers = []
    for _ in range(cfg.num_layers):
        dec_layers.append({
            "norm1": init_rmsnorm(cfg.d_model, dtype),
            "self_attn": init_attention(next(ki), cfg),
            "norm_x": init_rmsnorm(cfg.d_model, dtype),
            "cross_attn": init_attention(next(ki), cfg),
            "norm2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(next(ki), cfg.d_model, cfg.d_ff, dtype),
        })
    stack = lambda ls: jax.tree.map(lambda *xs: jnp.stack(xs), *ls)
    return {
        "embed": init_embedding(next(ki), cfg.vocab_size, cfg.d_model, dtype),
        "encoder": stack(enc_layers),
        "enc_norm": init_rmsnorm(cfg.d_model, dtype),
        "decoder": stack(dec_layers),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }


# ---------------------------------------------------------------- encoder ---


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T_enc, D) stub embeddings -> encoder output."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(x, lp):
        h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
        x = x + _attn(lp["attn"], cfg, h, positions, causal=False)
        h = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = scan_or_unroll(body, x, params["encoder"], cfg.unroll)
    return rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------- decoder ---


def _dec_block_train(lp, cfg, x, positions, enc_out):
    h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
    x = x + _attn(lp["self_attn"], cfg, h, positions, causal=True)
    h = rmsnorm_apply(lp["norm_x"], x, cfg.norm_eps)
    ck, cv = _cross_kv(lp["cross_attn"], cfg, enc_out)
    x = x + _cross_attn(lp["cross_attn"], cfg, h, ck, cv)
    h = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h)


def forward_train(params, cfg: ModelConfig, tokens, frames):
    enc_out = encode(params, cfg, frames)
    x = embed_apply(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, lp):
        return _dec_block_train(lp, cfg, x, positions, enc_out), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = scan_or_unroll(body, x, params["decoder"], cfg.unroll)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return logits_apply(params["embed"], x, cfg.logits_softcap)


def loss_fn(params, cfg: ModelConfig, batch):
    from repro.models.transformer import xent
    logits = forward_train(params, cfg, batch["tokens"], batch["frames"])
    return xent(logits, batch["labels"])


# ------------------------------------------------------- prefill / decode ---


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    l, kvh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    t = cfg.encoder_seq
    return {
        "k": jnp.zeros((l, batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((l, batch, max_len, kvh, hd), dtype),
        "ck": jnp.zeros((l, batch, t, kvh, hd), dtype),
        "cv": jnp.zeros((l, batch, t, kvh, hd), dtype),
    }


def prefill(params, cfg: ModelConfig, tokens, frames, caches):
    enc_out = encode(params, cfg, frames)
    x = embed_apply(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, lp):
        h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
        q, k, v = qkv_proj(lp["self_attn"], cfg, h, positions)
        if s > cfg.flash_threshold:
            o = flash_attention(q, k, v, causal=True, q_chunk=cfg.attn_chunk,
                                kv_chunk=cfg.attn_chunk)
        else:
            o = attention_naive(q, k, v, causal=True)
        x = x + attn_out(lp["self_attn"], o)
        h = rmsnorm_apply(lp["norm_x"], x, cfg.norm_eps)
        ck, cv = _cross_kv(lp["cross_attn"], cfg, enc_out)
        x = x + _cross_attn(lp["cross_attn"], cfg, h, ck, cv)
        h = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h)
        return x, (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = scan_or_unroll(body, x, params["decoder"], cfg.unroll)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = logits_apply(params["embed"], x[:, -1:], cfg.logits_softcap)
    caches = {
        "k": caches["k"].at[:, :, :s].set(ks.astype(caches["k"].dtype)),
        "v": caches["v"].at[:, :, :s].set(vs.astype(caches["v"].dtype)),
        "ck": cks.astype(caches["ck"].dtype),
        "cv": cvs.astype(caches["cv"].dtype),
    }
    return logits, caches


def decode_step(params, cfg: ModelConfig, token, caches, length):
    x = embed_apply(params["embed"], token).astype(jnp.dtype(cfg.dtype))
    b = x.shape[0]
    positions = length[:, None].astype(jnp.int32)
    rows = jnp.arange(b)

    def body(x, slot):
        lp, kc, vc, ck, cv = slot
        h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
        q, k, v = qkv_proj(lp["self_attn"], cfg, h, positions)
        kc = kc.at[rows, length].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[rows, length].set(v[:, 0].astype(vc.dtype))
        x = x + attn_out(lp["self_attn"], decode_attention(q, kc, vc, length + 1))
        h = rmsnorm_apply(lp["norm_x"], x, cfg.norm_eps)
        x = x + _cross_attn(lp["cross_attn"], cfg, h, ck, cv)
        h = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h)
        return x, (kc, vc)

    x, (ks, vs) = scan_or_unroll(
        body, x, (params["decoder"], caches["k"], caches["v"],
                  caches["ck"], caches["cv"]), cfg.unroll)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = logits_apply(params["embed"], x, cfg.logits_softcap)
    return logits, {"k": ks, "v": vs, "ck": caches["ck"], "cv": caches["cv"]}
