"""lax.scan with an unroll escape hatch.

cost_analysis() counts a while-loop body ONCE regardless of trip count, so
the dry-run's shallow depth probes (launch/dryrun.py) set cfg.unroll=True to
get exact FLOP/byte counts; production configs keep lax.scan for compact
HLO."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_or_unroll(body, init, xs, unroll: bool):
    if not unroll:
        return jax.lax.scan(body, init, xs)
    reps = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for r in range(reps):
        xr = jax.tree.map(lambda a: a[r], xs)
        carry, y = body(carry, xr)
        ys.append(y)
    if ys and any(l is not None for l in jax.tree.leaves(ys[0])):
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys
