"""Model configuration covering all assigned architecture families.

One frozen dataclass parameterizes dense / MoE / MLA / SSM / hybrid /
enc-dec / VLM-stub transformers.  Family semantics:

  dense   — attention + MLP every layer
  moe     — attention + (shared+routed top-k) MoE every `moe_every` layers
  ssm     — Mamba2/SSD blocks only (attention-free)
  hybrid  — Jamba-style: 1 attention layer per `attn_every` layers, MoE every
            `moe_every` layers, SSD otherwise
  vlm     — dense decoder LM; `vision_tokens` precomputed patch embeddings
            are concatenated in front of the token embeddings (frontend STUB
            per assignment — `input_specs` provides the embeddings)
  audio   — enc-dec (Whisper): encoder over precomputed frame embeddings
            (conv frontend STUB), decoder with cross-attention
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention
    qkv_bias: bool = False
    rope_theta: float = 1e4
    attn_chunk: int = 1024         # flash chunk (train/prefill)
    flash_threshold: int = 2048    # use chunked flash above this seq len

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0
    moe_every: int = 1             # MoE on layers where (i % moe_every)==moe_offset
    moe_offset: int = 0
    moe_d_ff: int = 0
    moe_dispatch_blocks: int = 0   # block-local dispatch (= data shards); 0 = global
    dense_layers: int = 0          # leading dense-MLP layers (DeepSeek-V2: 1)
    capacity_factor: float = 1.25

    # MLA (DeepSeek-V2)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM / hybrid
    attn_every: int = 0            # hybrid: attention on layers (i % attn_every)==attn_offset
    attn_offset: int = 0
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # enc-dec (audio)
    encoder_layers: int = 0
    encoder_seq: int = 0           # whisper-base: 1500 frames
    cross_attention: bool = False

    # vlm
    vision_tokens: int = 0

    # numerics / structure
    dtype: str = "bfloat16"        # activation dtype
    param_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (checkpoint policy)
    decode_uniform_length: bool = False  # batch-uniform decode: DUS cache update
    logits_softcap: float = 0.0
    unroll: bool = False           # python-unroll layer scans (dry-run probes)
    ssd_vectorized: bool = False   # vectorize SSD chunks (probes: exact flops)

    @property
    def d_inner(self) -> int:      # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' — the mixer of layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            return "attn" if (i % self.attn_every) == self.attn_offset else "ssm"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """'mlp' | 'moe' — the FFN of layer i."""
        if self.moe_experts and i >= self.dense_layers and (
            i % self.moe_every
        ) == self.moe_offset:
            return "moe"
        return "mlp"

    @property
    def pattern_period(self) -> int:
        """Length of the repeating layer pattern (for scan-stacking)."""
        import math
        p = 1
        if self.family == "hybrid":
            p = math.lcm(p, self.attn_every)
        if self.moe_experts:
            p = math.lcm(p, self.moe_every)
        return p

    def validate(self) -> None:
        assert self.num_heads % self.num_kv_heads == 0
        if self.family in ("hybrid",):
            assert self.attn_every > 0
            assert self.num_layers % self.pattern_period == 0, (
                self.num_layers, self.pattern_period
            )
        if self.moe_experts:
            assert self.moe_top_k > 0 and self.moe_d_ff > 0
        if self.family == "audio":
            assert self.encoder_layers > 0 and self.cross_attention
        if self.family != "ssm" and not self.mla:
            pass  # head_dim free-standing (e.g. Nemo: 128 with d_model/H=160)
