"""Basic layers: RMSNorm/LayerNorm, RoPE, (Swi)GLU MLP, embeddings.

Pure-functional convention used across the model zoo:
  init_*(key, ...) -> params (nested dict of arrays, cfg.param_dtype)
  *_apply(params, x, ...) -> y   (norm math in f32, matmuls in x.dtype)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _normal(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)


# ----------------------------------------------------------------- norms ---


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------------ RoPE ---


def rope_apply(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:  # (..., S, H, D): broadcast over heads
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLP ---


def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _normal(k1, (d_model, d_ff), d_model, dtype),
        "w_up": _normal(k2, (d_model, d_ff), d_model, dtype),
        "w_down": _normal(k3, (d_ff, d_model), d_ff, dtype),
    }


def mlp_apply(params, x):
    """SwiGLU (LLaMA-style)."""
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def init_gelu_mlp(key, d_model, d_ff, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": _normal(k1, (d_model, d_ff), d_model, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": _normal(k2, (d_ff, d_model), d_ff, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp_apply(params, x):
    h = jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"]) + params["b_out"]


# ------------------------------------------------------------- embedding ---


def init_embedding(key, vocab, d_model, dtype, tie=False):
    k1, k2 = jax.random.split(key)
    p = {"tok": _normal(k1, (vocab, d_model), d_model, dtype)}
    if not tie:
        p["head"] = _normal(k2, (d_model, vocab), d_model, dtype)
    return p


def embed_apply(params, tokens):
    return params["tok"][tokens]


def logits_apply(params, x, softcap: float = 0.0):
    if "head" in params:
        logits = jnp.einsum("...d,dv->...v", x, params["head"])
    else:
        logits = jnp.einsum("...d,vd->...v", x, params["tok"])
    logits = logits.astype(jnp.float32)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits
