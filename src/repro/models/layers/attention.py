"""GQA attention: naive (tests), chunked-flash (train/prefill), decode.

Chunked flash = online-softmax over KV chunks (lax.scan) per Q chunk.  Two
schedules:
  - `block_skip=False`: lax.map over Q chunks, every KV chunk computed and
    masked — one compact scan body (small HLO), 2× causal FLOPs waste.
  - `block_skip=True` : python loop over Q chunks, each scanning only the
    causally-visible KV prefix — halves causal FLOPs at the cost of a per-
    chunk HLO body.  (§Perf iterates on this trade-off.)
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers.basic import _normal, rope_apply

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, d_model=None):
    d = d_model or cfg.d_model
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _normal(k1, (d, h * hd), d, dtype),
        "wk": _normal(k2, (d, kvh * hd), d, dtype),
        "wv": _normal(k3, (d, kvh * hd), d, dtype),
        "wo": _normal(k4, (h * hd, d), h * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    return p


def qkv_proj(params, cfg: ModelConfig, x, positions, rope: bool = True):
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if rope:
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)
    return q, k, v


# ------------------------------------------------------------------ naive ---


def attention_naive(q, k, v, causal: bool, q_offset: int = 0):
    """q: (B,Sq,H,Dqk), k: (B,Skv,KVH,Dqk), v: (B,Skv,KVH,Dv)."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qf = q.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(skv)[None, :]
        mask = qpos >= kpos
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------- chunked flash ---


def _flash_qchunk(qc, k, v, q_pos0, kv_chunk, causal):
    """Online softmax for one Q chunk over all KV chunks via lax.scan.

    qc: (B, QC, KVH, G, D) f32-scaled; k/v: (B, Skv, KVH, D).
    q_pos0: absolute position of qc[0] (int32 scalar or python int).
    """
    b, qcn, kvh, g, d = qc.shape
    skv = k.shape[1]
    nkv = skv // kv_chunk
    kr = k.reshape(b, nkv, kv_chunk, kvh, -1)
    vr = v.reshape(b, nkv, kv_chunk, kvh, -1)

    def body(carry, inp):
        m, l, acc = carry
        ki, vi, kvi = inp
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qc, ki.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        if causal:
            qpos = q_pos0 + jnp.arange(qcn)
            kpos = kvi * kv_chunk + jnp.arange(kv_chunk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, qcn), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, qcn), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, qcn, v.shape[-1]), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kr.swapaxes(0, 1), vr.swapaxes(0, 1), jnp.arange(nkv)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KVH,G,QC,D)
    return out.transpose(0, 3, 1, 2, 4)           # (B,QC,KVH,G,D)


def flash_attention(q, k, v, causal: bool = True, q_chunk: int = 1024,
                    kv_chunk: int = 1024, block_skip: bool = True):
    """Chunked online-softmax attention. q: (B,Sq,H,Dqk), k: (B,Skv,KVH,Dqk),
    v: (B,Skv,KVH,Dv) — Dv may differ from Dqk (MLA)."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk or skv % kv_chunk:
        return attention_naive(q, k, v, causal)
    nq = sq // q_chunk
    qs = (q.reshape(b, nq, q_chunk, kvh, g, d).astype(jnp.float32)
          / np.sqrt(d))

    if block_skip and causal and sq == skv:
        outs = []
        for qi in range(nq):  # static python loop — per-chunk KV prefix
            kv_end = (qi + 1) * q_chunk
            o = _flash_qchunk(
                qs[:, qi], k[:, :kv_end], v[:, :kv_end],
                qi * q_chunk, kv_chunk, causal=True,
            )
            outs.append(o)
        out = jnp.stack(outs, axis=1)
    else:
        def per_chunk(args):
            qi, qc = args
            return _flash_qchunk(qc, k, v, qi * q_chunk, kv_chunk, causal)

        out = jax.lax.map(per_chunk, (jnp.arange(nq), qs.swapaxes(0, 1)))
        out = out.swapaxes(0, 1)  # (B, nq, QC, KVH, G, Dv)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ----------------------------------------------------------------- decode ---


def decode_attention(q, k_cache, v_cache, length):
    """Single-token decode vs a dense cache.

    q: (B,1,H,D); caches: (B,S,KVH,D); length: (B,) valid prefix lengths.

    The caches stay in their storage dtype inside the dots (f32 accumulation
    via preferred_element_type) — materializing an f32 copy of a multi-GB
    cache dominated decode HLO bytes before this (§Perf iteration)."""
    b, _, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qf = q.reshape(b, kvh, g, d).astype(k_cache.dtype)
    sc = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache,
                    preferred_element_type=jnp.float32) / np.sqrt(d)
    mask = jnp.arange(s)[None, :] < length[:, None]
    sc = jnp.where(mask[:, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


# ------------------------------------------------------------ full blocks ---


def attn_train(params, cfg: ModelConfig, x, positions, causal=True):
    b, s, _ = x.shape
    q, k, v = qkv_proj(params, cfg, x, positions)
    if s > cfg.flash_threshold:
        o = flash_attention(q, k, v, causal=causal, q_chunk=cfg.attn_chunk,
                            kv_chunk=cfg.attn_chunk)
    else:
        o = attention_naive(q, k, v, causal=causal)
    return attn_out(params, o)


def attn_out(params, o_bshd):
    b, s = o_bshd.shape[:2]
    return jnp.einsum("bse,ed->bsd", o_bshd.reshape(b, s, -1), params["wo"])
