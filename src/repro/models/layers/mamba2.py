"""Mamba-2 / SSD (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: within-chunk quadratic form (the "attention-like"
dual) + inter-chunk state recurrence via lax.scan.  `ssd_ref` is the naive
sequential recurrence used as the test oracle.  Single-token decode keeps a
(B, H, P, N) state and a (B, w-1, conv_dim) conv cache.

Block layout follows Mamba-2: in_proj → [z | x | B | C | dt], causal
depthwise conv over [x|B|C], SiLU, SSD, gated RMSNorm, out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers.basic import _normal

LOG_EPS = -80.0


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cd = conv_dim(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "w_in": _normal(ks[0], (d, 2 * di + 2 * n + h), d, dtype),
        "conv_w": _normal(ks[1], (cfg.conv_width, cd), cfg.conv_width, dtype),
        "conv_b": jnp.zeros((cd,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),         # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "w_out": _normal(ks[2], (di, d), di, dtype),
    }


def _split_in(cfg: ModelConfig, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n :]
    return z, xbc, dt


def _causal_conv(params, xbc, cache=None):
    """Depthwise causal conv over time. xbc: (B,S,C). cache: (B,w-1,C)."""
    w = params["conv_w"].shape[0]
    if cache is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = cache.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * params["conv_w"][i][None, None]
        for i in range(w)
    )
    out = out + params["conv_b"]
    new_cache = xp[:, -(w - 1):, :] if w > 1 else None
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype), new_cache


def _gated_out(params, cfg: ModelConfig, y, z, x_dtype):
    """y * silu(z) -> grouped RMSNorm -> out_proj."""
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + cfg.norm_eps) * params["gate_norm"].astype(jnp.float32)
    return jnp.einsum("bsi,id->bsd", g.astype(x_dtype), params["w_out"])


def ssd_chunked(cfg: ModelConfig, xh, b_, c_, dt, a_log, d_skip, state0=None):
    """Chunked SSD scan.

    xh: (B,S,H,P); b_/c_: (B,S,N); dt: (B,S,H) post-softplus; returns
    (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s0, h, p = xh.shape
    n = b_.shape[-1]
    q = min(cfg.ssm_chunk, s0)
    # pad to a chunk multiple with dt=0 (decay=1, zero input: state-exact)
    s = -(-s0 // q) * q
    if s != s0:
        pad = [(0, 0), (0, s - s0)]
        xh = jnp.pad(xh, pad + [(0, 0), (0, 0)])
        b_ = jnp.pad(b_, pad + [(0, 0)])
        c_ = jnp.pad(c_, pad + [(0, 0)])
        dt = jnp.pad(dt, pad + [(0, 0)])
    nc = s // q

    a = -jnp.exp(a_log)                                   # (H,)
    loga = (dt * a[None, None]).astype(jnp.float32)       # (B,S,H) = log decay
    xc = xh.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    bc = b_.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c_.reshape(bsz, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    lac = loga.reshape(bsz, nc, q, h)
    tri = jnp.tril(jnp.ones((q, q), bool))

    init_state = (jnp.zeros((bsz, h, n, p), jnp.float32) if state0 is None
                  else state0.transpose(0, 1, 3, 2).astype(jnp.float32))

    if cfg.ssd_vectorized:
        # Fully vectorized over chunks: exact cost_analysis flop counting for
        # the dry-run probes (a lax.scan body is only counted once).  Not
        # used at runtime — the (B,nc,Q,Q,H) tensor is chunk-scan-bounded in
        # the production path below.
        lcum = jnp.cumsum(lac, axis=2)                    # (B,nc,Q,H)
        ltot = lcum[:, :, -1]
        cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)
        ldiff = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]
        decay = jnp.exp(jnp.where(tri[None, None, :, :, None], ldiff, LOG_EPS))
        m = cb[..., None] * decay * dtc[:, :, None, :, :]
        y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xc)
        t = jnp.exp(lcum[:, :, -1:, :] - lcum) * dtc
        chunk_in = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", t, bc, xc)

        def state_body(s_prev, inp):
            ci, lt = inp
            return s_prev * jnp.exp(lt)[:, :, None, None] + ci, s_prev

        s_last, s_before = jax.lax.scan(
            state_body, init_state,
            (chunk_in.swapaxes(0, 1), ltot.swapaxes(0, 1)))
        s_before = s_before.swapaxes(0, 1)
        y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cc, jnp.exp(lcum),
                             s_before)
        y = y_intra + y_inter + d_skip[None, None, :, None] * xc
        y = y.reshape(bsz, s, h, p)[:, :s0]
        return y, s_last.transpose(0, 1, 3, 2)

    def scan_body(s_prev, inp):
        # one chunk: intra quadratic + inter from carried state.  Keeping the
        # (B,Q,Q,H) tensors inside the scan bounds live memory to one chunk.
        xck, bck, cck, dtk, lak = inp
        lcum = jnp.cumsum(lak, axis=1)                    # (B,Q,H) inclusive
        ltot = lcum[:, -1]                                # (B,H)
        # M[i,j] = (C_i . B_j) * exp(L_i - L_j) * dt_j, j <= i
        cb = jnp.einsum("bin,bjn->bij", cck, bck)         # (B,Q,Q)
        ldiff = lcum[:, :, None, :] - lcum[:, None, :, :]  # (B,Q,Q,H)
        decay = jnp.exp(jnp.where(tri[None, :, :, None], ldiff, LOG_EPS))
        m = cb[..., None] * decay * dtk[:, None, :, :]    # (B,Q,Q,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, xck)
        # inter: C_i . (exp(L_i) * S_prev)
        y_inter = jnp.einsum("bin,bih,bhnp->bihp", cck, jnp.exp(lcum), s_prev)
        # state update
        t = jnp.exp(ltot[:, None] - lcum) * dtk           # (B,Q,H)
        chunk_in = jnp.einsum("bjh,bjn,bjhp->bhnp", t, bck, xck)
        s_new = s_prev * jnp.exp(ltot)[:, :, None, None] + chunk_in
        return s_new, y_intra + y_inter

    s_last, ys = jax.lax.scan(
        scan_body, init_state,
        (xc.swapaxes(0, 1), bc.swapaxes(0, 1), cc.swapaxes(0, 1),
         dtc.swapaxes(0, 1), lac.swapaxes(0, 1)),
    )
    y = ys.swapaxes(0, 1) + d_skip[None, None, :, None] * xc
    y = y.reshape(bsz, s, h, p)[:, :s0]
    return y, s_last.transpose(0, 1, 3, 2)                # (B,H,P,N)


def ssd_ref(cfg: ModelConfig, xh, b_, c_, dt, a_log, d_skip):
    """Naive sequential recurrence (test oracle)."""
    bsz, s, h, p = xh.shape
    n = b_.shape[-1]
    a = -jnp.exp(a_log)

    def step(state, inp):
        x_t, b_t, c_t, dt_t = inp
        decay = jnp.exp(dt_t * a)                          # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt_t, b_t, x_t)
        state = state * decay[:, :, None, None] + upd
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y_t

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (
        xh.swapaxes(0, 1).astype(jnp.float32),
        b_.swapaxes(0, 1).astype(jnp.float32),
        c_.swapaxes(0, 1).astype(jnp.float32),
        dt.swapaxes(0, 1).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, s0, xs)
    y = ys.swapaxes(0, 1) + d_skip[None, None, :, None] * xh.astype(jnp.float32)
    return y


def _pre_ssd(params, cfg: ModelConfig, x, conv_cache=None):
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xbc, dt_raw = _split_in(cfg, proj)
    xbc, new_conv = _causal_conv(params, xbc, conv_cache)
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xin = xbc[..., :di].reshape(*x.shape[:2], h, p)
    b_ = xbc[..., di : di + n]
    c_ = xbc[..., di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    return z, xin, b_, c_, dt, new_conv


def mamba2_train(params, cfg: ModelConfig, x):
    """x: (B,S,D) -> (B,S,D)."""
    z, xin, b_, c_, dt, _ = _pre_ssd(params, cfg, x)
    y, _ = ssd_chunked(cfg, xin, b_, c_, dt, params["a_log"], params["d_skip"])
    y = y.reshape(*x.shape[:2], cfg.d_inner).astype(x.dtype)
    return _gated_out(params, cfg, y, z, x.dtype)


def mamba2_prefill(params, cfg: ModelConfig, x):
    """Returns (y, ssd_state (B,H,P,N), conv_cache (B,w-1,CD))."""
    z, xin, b_, c_, dt, conv_cache = _pre_ssd(params, cfg, x)
    y, state = ssd_chunked(cfg, xin, b_, c_, dt, params["a_log"], params["d_skip"])
    y = y.reshape(*x.shape[:2], cfg.d_inner).astype(x.dtype)
    return _gated_out(params, cfg, y, z, x.dtype), state, conv_cache


def mamba2_decode(params, cfg: ModelConfig, x, state, conv_cache):
    """Single-token step. x: (B,1,D); state: (B,H,P,N); conv: (B,w-1,CD)."""
    z, xin, b_, c_, dt, new_conv = _pre_ssd(params, cfg, x, conv_cache)
    a = -jnp.exp(params["a_log"])
    dt1 = dt[:, 0]                                        # (B,H)
    decay = jnp.exp(dt1 * a)                              # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt1, b_[:, 0].astype(jnp.float32),
                     xin[:, 0].astype(jnp.float32))
    state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c_[:, 0].astype(jnp.float32))
    y = y + params["d_skip"][None, :, None] * xin[:, 0].astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, cfg.d_inner).astype(x.dtype)
    return _gated_out(params, cfg, y, z, x.dtype), state, new_conv
