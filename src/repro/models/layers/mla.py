"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a per-token latent `c_kv` (kv_lora_rank) plus one shared
RoPE key (qk_rope_dim).  Decode caches ONLY (c_kv, k_rope) — 576 elements
per token for DS-V2 vs 2*H*D for vanilla MHA — and absorbs the up-projection
matrices into the query / output path (the "weight absorption" trick), so
decode attention runs entirely in latent space.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers.basic import _normal, init_rmsnorm, rmsnorm_apply, rope_apply

NEG_INF = -1e30


def init_mla(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    qn, qr, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvl, ql = cfg.kv_lora_rank, cfg.q_lora_rank
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "wkv_a": _normal(ks[0], (d, kvl + qr), d, dtype),
        "kv_norm": init_rmsnorm(kvl, dtype),
        "wkv_b": _normal(ks[1], (kvl, h * (qn + vh)), kvl, dtype),
        "wo": _normal(ks[2], (h * vh, d), h * vh, dtype),
    }
    if ql > 0:
        p["wq_a"] = _normal(ks[3], (d, ql), d, dtype)
        p["q_norm"] = init_rmsnorm(ql, dtype)
        p["wq_b"] = _normal(ks[4], (ql, h * (qn + qr)), ql, dtype)
    else:
        p["wq"] = _normal(ks[5], (d, h * (qn + qr)), d, dtype)
    return p


def _queries(params, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    h, qn, qr = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank > 0:
        cq = rmsnorm_apply(params["q_norm"],
                           jnp.einsum("bsd,dr->bsr", x, params["wq_a"]),
                           cfg.norm_eps)
        q = jnp.einsum("bsr,re->bse", cq, params["wq_b"])
    else:
        q = jnp.einsum("bsd,de->bse", x, params["wq"])
    q = q.reshape(b, s, h, qn + qr)
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = rope_apply(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(params, cfg: ModelConfig, x, positions):
    kvl, qr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = jnp.einsum("bsd,de->bse", x, params["wkv_a"])
    c_kv = rmsnorm_apply(params["kv_norm"], kv[..., :kvl], cfg.norm_eps)
    k_rope = rope_apply(kv[..., kvl:], positions, cfg.rope_theta)  # (B,S,qr)
    return c_kv, k_rope


def mla_train(params, cfg: ModelConfig, x, positions, causal=True):
    """Training/prefill form.  Short sequences use the materialized S×S
    softmax; long sequences fold the shared RoPE key into per-head keys
    (q' = [q_nope|q_rope], k' = [k_nope|k_rope⊗1_H]) and run the chunked
    online-softmax flash path — O(S·chunk) live memory instead of the
    O(H·S²) score blow-up (§Perf hillclimb #1; EXPERIMENTS.md)."""
    b, s, _ = x.shape
    h, qn, qr, vh = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    kvb = jnp.einsum("bsr,re->bse", c_kv, params["wkv_b"]).reshape(b, s, h, qn + vh)
    k_nope, v = kvb[..., :qn], kvb[..., qn:]

    if s > cfg.flash_threshold:
        from repro.models.layers.attention import flash_attention
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)          # (B,S,H,qn+qr)
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, qr))],
            axis=-1)
        o = flash_attention(qq, kk, v, causal=causal,
                            q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
        o = o.reshape(b, s, h * vh)
        return jnp.einsum("bse,ed->bsd", o, params["wo"])

    scale = 1.0 / np.sqrt(qn + qr)
    sc = (
        jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                   k_nope.astype(jnp.float32))
        + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                     k_rope.astype(jnp.float32))
    ) * scale
    if causal:
        mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
        sc = jnp.where(mask[None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    o = o.reshape(b, s, h * vh).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, params["wo"])


def mla_prefill(params, cfg: ModelConfig, x, positions):
    """Prefill: returns output + latent cache (c_kv, k_rope)."""
    y = mla_train(params, cfg, x, positions, causal=True)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    return y, c_kv, k_rope


def mla_decode(params, cfg: ModelConfig, x, positions, ckv_cache, krope_cache,
               length):
    """Absorbed decode: attention entirely in latent space.

    x: (B,1,D); caches: (B,S,kvl), (B,S,qr); length: (B,).
    """
    b, _, _ = x.shape
    h, qn, qr, vh = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    smax = ckv_cache.shape[1]

    q_nope, q_rope = _queries(params, cfg, x, positions)      # (B,1,H,*)
    c_kv_new, k_rope_new = _latents(params, cfg, x, positions)
    # write this token's latent at position `length` (scatter — in-place
    # under buffer donation)
    rows = jnp.arange(b)
    ckv_cache = ckv_cache.at[rows, length].set(
        c_kv_new[:, 0].astype(ckv_cache.dtype))
    krope_cache = krope_cache.at[rows, length].set(
        k_rope_new[:, 0].astype(krope_cache.dtype))

    wkv_b = params["wkv_b"].reshape(kvl, h, qn + vh)
    w_uk = wkv_b[..., :qn]                                    # (kvl, H, qn)
    w_uv = wkv_b[..., qn:]                                    # (kvl, H, vh)

    # absorb W_uk into q: q_lat (B,H,kvl)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / np.sqrt(qn + qr)
    sc = (
        jnp.einsum("bhr,bsr->bhs", q_lat, ckv_cache.astype(jnp.float32))
        + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                     krope_cache.astype(jnp.float32))
    ) * scale
    mask = jnp.arange(smax)[None] <= length[:, None]          # include self
    sc = jnp.where(mask[:, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(b, 1, h * vh).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", o, params["wo"])
    return y, ckv_cache, krope_cache
