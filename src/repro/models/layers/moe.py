"""Mixture-of-Experts FFN: shared experts + routed top-k with sort-based
dispatch (MegaBlocks-style grouped GEMM, capacity-bounded).

Dispatch is static-shape and EP-shardable: the (E, C, D) expert batch is the
tensor whose leading axis shards across the `model` mesh axis; under SPMD
the gather/scatter become all-to-alls (token → expert shuffle).
Capacity-dropped tokens fall through to the shared experts / residual path
(standard GShard behavior).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.ax import constrain

from repro.models.config import ModelConfig
from repro.models.layers.basic import _normal, init_mlp, mlp_apply


def init_moe(key, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": _normal(ks[0], (d, e), d, jnp.float32),
        "w_gate": _normal(ks[1], (e, d, f), d, dtype),
        "w_up": _normal(ks[2], (e, d, f), d, dtype),
        "w_down": _normal(ks[3], (e, f, d), f, dtype),
    }
    if cfg.moe_shared > 0:
        p["shared"] = init_mlp(ks[4], d, cfg.moe_d_ff * cfg.moe_shared, dtype)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(np.ceil(n_tokens * cfg.moe_top_k / cfg.moe_experts
                    * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_apply(params, cfg: ModelConfig, x):
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)  # (T,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ----
    # blocks > 1: block-local dispatch (§Perf cell B): tokens are ranked
    # within (block, expert) where a block = one data shard's tokens, and
    # each expert's capacity is laid out block-major — so the (E, C, D)
    # expert batch tile owned by a (model, data) shard is assembled from
    # that data shard's own tokens (no cross-data all-reduce of E·C·D).
    blocks = max(cfg.moe_dispatch_blocks, 1)
    tk = t * k
    flat_e = idx.reshape(tk)                           # expert of each (t,k)
    if blocks > 1 and tk % blocks == 0:
        per = tk // blocks
        c_blk = max(8, -(-int(np.ceil(per / e * cfg.capacity_factor)) // 8) * 8)
        c = blocks * c_blk
        e2 = flat_e.reshape(blocks, per)
        order_b = jnp.argsort(e2, axis=1, stable=True)
        sorted_e = jnp.take_along_axis(e2, order_b, axis=1)
        first = jax.vmap(
            lambda row: jnp.searchsorted(row, row, side="left"))(sorted_e)
        rank = jnp.arange(per, dtype=jnp.int32)[None] - first.astype(jnp.int32)
        keep = rank < c_blk
        cap_idx = jnp.arange(blocks, dtype=jnp.int32)[:, None] * c_blk + rank
        dest = jnp.where(keep, sorted_e * c + cap_idx, e * c).reshape(-1)
        order = (order_b
                 + jnp.arange(blocks, dtype=jnp.int32)[:, None] * per).reshape(-1)
    else:
        c = capacity(cfg, t)
        order = jnp.argsort(flat_e, stable=True)        # group by expert
        sorted_e = flat_e[order]
        rank = jnp.arange(tk, dtype=jnp.int32) - jnp.searchsorted(
            sorted_e, sorted_e, side="left"
        ).astype(jnp.int32)
        keep = rank < c
        dest = jnp.where(keep, sorted_e * c + rank, e * c)  # overflow drop
    slot_token = jnp.full((e * c + 1,), -1, jnp.int32).at[dest].set(
        (order // k).astype(jnp.int32), mode="drop"
    )[: e * c]
    slot_gate = jnp.zeros((e * c + 1,), jnp.float32).at[dest].set(
        gates.reshape(tk)[order], mode="drop"
    )[: e * c]

    valid = slot_token >= 0
    xg = jnp.where(
        valid[:, None], xf[jnp.maximum(slot_token, 0)],
        jnp.zeros((), x.dtype),
    ).reshape(e, c, d)
    if blocks > 1:
        xg = constrain(xg, "expert", "expert_cap", None)

    # ---- grouped expert GEMM (EP-sharded on axis 0) ----
    g = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xg, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(e * c, d)

    # ---- weighted combine (scatter-add) ----
    # Stays in the activation dtype end-to-end: an f32 combine upcasts the
    # (E·C, D) tensor that SPMD assembles across shards, doubling the
    # dominant MoE all-reduce wire bytes (§Perf cell B iteration 2; the sum
    # per row is over ≤ top_k + shared contributions, safe in bf16).
    contrib = y * slot_gate[:, None].astype(y.dtype)
    out = jnp.zeros((t, d), x.dtype).at[jnp.maximum(slot_token, 0)].add(
        jnp.where(valid[:, None], contrib, jnp.zeros((), y.dtype))
    )

    if cfg.moe_shared > 0:
        out = out + mlp_apply(params["shared"], xf)
    return out.reshape(b, s, d)


def moe_ref(params, cfg: ModelConfig, x):
    """Dense oracle (computes every expert on every token; tests only)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), cfg.moe_top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    g = jnp.einsum("td,edf->tef", xf, params["w_gate"])
    u = jnp.einsum("td,edf->tef", xf, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])  # (T,E,D)
    sel = jax.vmap(lambda ys, ii: ys[ii])(y_all, idx)        # (T,K,D)
    out = jnp.einsum("tkd,tk->td", sel.astype(jnp.float32), gates)
    out = out.astype(x.dtype)
    if cfg.moe_shared > 0:
        out = out + mlp_apply(params["shared"], xf)
    return out.reshape(b, s, d)
