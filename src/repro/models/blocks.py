"""Per-layer block assembly: pre-norm mixer (attn | MLA | SSD) + FFN
(MLP | MoE) with residuals.  A block's *kind* is static (from the config's
layer pattern); its params are stacked over pattern repetitions and scanned
by transformer.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import mamba2 as m2
from repro.models.layers.attention import (
    attn_out,
    attn_train,
    decode_attention,
    init_attention,
    qkv_proj,
)
from repro.models.layers.basic import init_mlp, init_rmsnorm, mlp_apply, rmsnorm_apply
from repro.models.layers.mla import init_mla, mla_decode, mla_prefill, mla_train
from repro.models.layers.moe import init_moe, moe_apply
from repro.parallel.ax import constrain


def block_kinds(cfg: ModelConfig, i: int) -> tuple[str, str]:
    return cfg.layer_kind(i), cfg.ffn_kind(i)


def _has_ffn(cfg: ModelConfig, ffn_kind: str) -> bool:
    return ffn_kind == "moe" or cfg.d_ff > 0


def init_block(key, cfg: ModelConfig, layer_idx: int):
    mixer_kind, ffn_kind = block_kinds(cfg, layer_idx)
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if mixer_kind == "attn":
        p["mixer"] = init_mla(k1, cfg) if cfg.mla else init_attention(k1, cfg)
    else:
        p["mixer"] = m2.init_mamba2(k1, cfg)
    if _has_ffn(cfg, ffn_kind):
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        p["ffn"] = init_moe(k2, cfg) if ffn_kind == "moe" else init_mlp(
            k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _ffn(params, cfg: ModelConfig, kind: str, x):
    return moe_apply(params, cfg, x) if kind == "moe" else mlp_apply(params, x)


# --------------------------------------------------------------- training ---


def block_train(params, cfg: ModelConfig, kinds: tuple[str, str], x, positions,
                causal: bool = True):
    mixer_kind, ffn_kind = kinds
    h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
    if mixer_kind == "attn":
        if cfg.mla:
            y = mla_train(params["mixer"], cfg, h, positions, causal=causal)
        else:
            y = attn_train(params["mixer"], cfg, h, positions, causal=causal)
    else:
        y = m2.mamba2_train(params["mixer"], cfg, h)
    x = x + y
    x = constrain(x, "batch", "seq", "embed")
    if _has_ffn(cfg, ffn_kind):
        h = rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
        x = x + _ffn(params["ffn"], cfg, ffn_kind, h)
    return constrain(x, "batch", "seq", "embed")


# ---------------------------------------------------------------- caching ---


def init_block_cache(cfg: ModelConfig, kinds, batch: int, max_len: int, dtype):
    """Zero cache pytree for one block."""
    mixer_kind, _ = kinds
    if mixer_kind == "attn":
        if cfg.mla:
            return {
                "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
            }
        return {
            "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    return {
        "state": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, m2.conv_dim(cfg)), dtype),
    }


def block_prefill(params, cfg: ModelConfig, kinds, x, positions, cache):
    """Run the block over a full prompt, filling `cache` in [0, S)."""
    mixer_kind, ffn_kind = kinds
    s = x.shape[1]
    h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
    if mixer_kind == "attn":
        if cfg.mla:
            y, ckv, krope = mla_prefill(params["mixer"], cfg, h, positions)
            cache = dict(cache)
            cache["ckv"] = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0))
            cache["krope"] = jax.lax.dynamic_update_slice(
                cache["krope"], krope.astype(cache["krope"].dtype), (0, 0, 0))
        else:
            q, k, v = qkv_proj(params["mixer"], cfg, h, positions)
            from repro.models.layers.attention import attention_naive, flash_attention
            if s > cfg.flash_threshold:
                o = flash_attention(q, k, v, causal=True, q_chunk=cfg.attn_chunk,
                                    kv_chunk=cfg.attn_chunk)
            else:
                o = attention_naive(q, k, v, causal=True)
            y = attn_out(params["mixer"], o)
            cache = dict(cache)
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    else:
        y, state, conv = m2.mamba2_prefill(params["mixer"], cfg, h)
        cache = {"state": state, "conv": conv.astype(cache["conv"].dtype)}
    x = x + y
    if _has_ffn(cfg, ffn_kind):
        h2 = rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
        x = x + _ffn(params["ffn"], cfg, ffn_kind, h2)
    return x, cache


def block_decode(params, cfg: ModelConfig, kinds, x, positions, cache, length):
    """Single-token step. x: (B,1,D); length: (B,) tokens already cached."""
    mixer_kind, ffn_kind = kinds
    b = x.shape[0]
    h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
    if mixer_kind == "attn":
        if cfg.mla:
            y, ckv, krope = mla_decode(
                params["mixer"], cfg, h, positions, cache["ckv"], cache["krope"],
                length)
            cache = {"ckv": ckv, "krope": krope}
        else:
            q, k, v = qkv_proj(params["mixer"], cfg, h, positions)
            if cfg.decode_uniform_length:
                # synchronized-batch decode: one dynamic_update_slice along
                # seq (writes B*KVH*HD elements) instead of a batched
                # scatter that op-level accounting charges as a full cache
                # rewrite (§Perf cell C iteration 2)
                kc = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), length[0], axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), length[0], axis=1)
            else:
                rows = jnp.arange(b)
                kc = cache["k"].at[rows, length].set(k[:, 0].astype(cache["k"].dtype))
                vc = cache["v"].at[rows, length].set(v[:, 0].astype(cache["v"].dtype))
            kc = constrain(kc, "batch", "decode_seq", None, None)
            vc = constrain(vc, "batch", "decode_seq", None, None)
            o = decode_attention(q, kc, vc, length + 1)
            y = attn_out(params["mixer"], o)
            cache = {"k": kc, "v": vc}
    else:
        y, state, conv = m2.mamba2_decode(
            params["mixer"], cfg, h, cache["state"], cache["conv"])
        cache = {"state": state, "conv": conv}
    x = x + y
    if _has_ffn(cfg, ffn_kind):
        h2 = rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
        x = x + _ffn(params["ffn"], cfg, ffn_kind, h2)
    return x, cache
