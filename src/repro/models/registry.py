"""Model facade + assignment shapes + input_specs (dry-run contract).

`api(cfg)` returns a uniform interface regardless of family:
    init_params(key) / loss_fn(params, batch) / prefill(params, ...) /
    decode_step(params, ...) / init_caches(batch, max_len)

`input_specs(cfg, shape_name)` returns ShapeDtypeStruct stand-ins for every
input of the step that shape lowers (train_step / prefill_step /
serve_step), with no device allocation — the multi-pod dry-run compiles
against exactly these.
"""

from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig

# assignment shape table: name -> (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """Per-assignment skips: long_500k needs sub-quadratic attention."""
    if shape_name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k skipped: pure full-attention arch (per assignment)"
    return True, ""


def api(cfg: ModelConfig) -> SimpleNamespace:
    mod = encdec if cfg.family == "audio" else transformer
    return SimpleNamespace(
        init_params=lambda key: mod.init_params(key, cfg),
        loss_fn=lambda params, batch: mod.loss_fn(params, cfg, batch),
        forward_train=lambda params, **kw: mod.forward_train(params, cfg, **kw),
        prefill=lambda params, *a, **kw: mod.prefill(params, cfg, *a, **kw),
        decode_step=lambda params, *a, **kw: mod.decode_step(params, cfg, *a, **kw),
        init_caches=lambda batch, max_len: mod.init_caches(cfg, batch, max_len),
        module=mod,
    )


def input_specs(cfg: ModelConfig, shape_name: str, batch_override: int | None = None):
    """ShapeDtypeStructs for the step the shape lowers. Returns
    (step_kind, specs_dict)."""
    seq, gbatch, kind = SHAPES[shape_name]
    b = batch_override or gbatch
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    S = jax.ShapeDtypeStruct

    if kind == "train":
        if cfg.family == "vlm":
            st = seq - cfg.vision_tokens
            specs = {
                "tokens": S((b, st), i32),
                "labels": S((b, st), i32),
                "vision_embeds": S((b, cfg.vision_tokens, cfg.d_model), act),
            }
        elif cfg.family == "audio":
            specs = {
                "tokens": S((b, seq), i32),
                "labels": S((b, seq), i32),
                "frames": S((b, cfg.encoder_seq, cfg.d_model), act),
            }
        else:
            specs = {"tokens": S((b, seq), i32), "labels": S((b, seq), i32)}
        return "train", specs

    mod = encdec if cfg.family == "audio" else transformer
    cache_spec = jax.eval_shape(lambda: mod.init_caches(cfg, b, seq))

    if kind == "prefill":
        if cfg.family == "vlm":
            specs = {
                "tokens": S((b, seq - cfg.vision_tokens), i32),
                "vision_embeds": S((b, cfg.vision_tokens, cfg.d_model), act),
                "caches": cache_spec,
            }
        elif cfg.family == "audio":
            specs = {
                "tokens": S((b, seq), i32),
                "frames": S((b, cfg.encoder_seq, cfg.d_model), act),
                "caches": cache_spec,
            }
        else:
            specs = {"tokens": S((b, seq), i32), "caches": cache_spec}
        return "prefill", specs

    # decode: one new token against a seq-long cache
    specs = {
        "token": S((b, 1), i32),
        "length": S((b,), i32),
        "caches": cache_spec,
    }
    return "decode", specs
