import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment MULTI-POD DRY-RUN): lower + compile every
(arch × shape × mesh) cell against ShapeDtypeStruct inputs on the 16×16
single-pod and 2×16×16 multi-pod production meshes; record
memory_analysis / cost_analysis / collective schedule for §Roofline.

The XLA_FLAGS line above MUST stay the first statement — jax locks the
device count on first init.  Do not import this module from tests.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
      --shape train_4k --mesh pod1 --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod1
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.analysis import roofline as R
from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.registry import SHAPES, api, input_specs, shape_applicable
from repro.optim import AdamWConfig, adamw_init
from repro.parallel import shardings as SH
from repro.parallel.ax import logical_rules
from repro.train import make_train_step


def _mesh_chips(mesh):
    return int(np.prod(list(mesh.shape.values())))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               extra_cfg: dict | None = None, accum_steps: int = 1):
    """Lower + compile one cell. Returns (record, compiled, lowered)."""
    import dataclasses

    cfg = get_config(arch)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "pod2" if multi_pod else "pod1",
                "status": "skipped", "reason": why}, None, None

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = _mesh_chips(mesh)
    m = api(cfg)
    kind, specs = input_specs(cfg, shape_name)
    seq, gbatch, _ = SHAPES[shape_name]

    params_shape = jax.eval_shape(m.init_params, jax.random.key(0))
    pspecs = SH.param_specs(params_shape)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_shape))
    n_active = R.active_params(cfg, n_params)

    named = lambda tree: SH.to_named(tree, mesh)

    t0 = time.time()
    with mesh, logical_rules(mesh):
        if kind == "train":
            ocfg = AdamWConfig(state_dtype="bfloat16")
            opt_shape = jax.eval_shape(lambda p: adamw_init(ocfg, p), params_shape)
            ospecs = SH.opt_specs(pspecs)
            bspec = {
                k: SH.batch_spec(mesh, gbatch, len(v.shape)) for k, v in specs.items()
            }
            step = make_train_step(cfg, ocfg, accum_steps=accum_steps)
            jitted = jax.jit(
                step,
                in_shardings=(named(pspecs), named(ospecs), named(bspec)),
                out_shardings=(named(pspecs), named(ospecs), None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, specs)
            n_tokens = gbatch * seq
        elif kind == "prefill":
            cspecs = SH.cache_specs(specs["caches"], mesh)
            bsp = {k: SH.batch_spec(mesh, gbatch, len(jax.tree.leaves(v)[0].shape)
                                    if k == "caches" else len(v.shape))
                   for k, v in specs.items() if k != "caches"}

            if cfg.family == "audio":
                fn = lambda p, tokens, frames, caches: m.prefill(
                    p, tokens, frames, caches)
                args = (params_shape, specs["tokens"], specs["frames"],
                        specs["caches"])
                in_sh = (named(pspecs), named(bsp["tokens"]),
                         named(bsp["frames"]), named(cspecs))
            elif cfg.family == "vlm":
                fn = lambda p, tokens, ve, caches: m.prefill(
                    p, tokens, caches, vision_embeds=ve)
                args = (params_shape, specs["tokens"], specs["vision_embeds"],
                        specs["caches"])
                in_sh = (named(pspecs), named(bsp["tokens"]),
                         named(bsp["vision_embeds"]), named(cspecs))
            else:
                fn = lambda p, tokens, caches: m.prefill(p, tokens, caches)
                args = (params_shape, specs["tokens"], specs["caches"])
                in_sh = (named(pspecs), named(bsp["tokens"]), named(cspecs))
            jitted = jax.jit(fn, in_shardings=in_sh,
                             out_shardings=(None, named(cspecs)),
                             donate_argnums=(len(args) - 1,))
            lowered = jitted.lower(*args)
            n_tokens = gbatch * seq
        else:  # decode
            cspecs = SH.cache_specs(specs["caches"], mesh)
            tok_sp = SH.batch_spec(mesh, gbatch, 2)
            len_sp = SH.batch_spec(mesh, gbatch, 1)
            fn = lambda p, token, caches, length: m.decode_step(
                p, token, caches, length)
            jitted = jax.jit(
                fn,
                in_shardings=(named(pspecs), named(tok_sp), named(cspecs),
                              named(len_sp)),
                out_shardings=(None, named(cspecs)),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_shape, specs["token"],
                                   specs["caches"], specs["length"])
            n_tokens = gbatch  # one token per sequence
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = R.collective_stats(hlo)
    mf = R.model_flops(cfg, kind, n_tokens, n_params, n_active)
    rf = R.roofline_terms(cost, coll, mf, n_chips)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2" if multi_pod else "pod1",
        "status": "ok",
        "step_kind": kind,
        "n_chips": n_chips,
        "n_params": n_params,
        "n_active_params": n_active,
        "n_tokens_global": n_tokens,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
        },
        "cost_analysis": {
            k: float(v)
            for k, v in (cost or {}).items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed")
                or k.startswith("bytes accessed")
            )
        },
        "collectives": coll,
        "roofline": rf.as_dict(),
        "accum_steps": accum_steps,
        "_probe": {  # raw terms for depth extrapolation
            "flops": float(cost.get("flops", 0.0)),
            "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
            "wire_bytes": float(coll["total_wire_bytes"]),
        },
    }
    return rec, compiled, lowered


def _probe_layers(cfg, r: int) -> dict:
    """Config override with r pattern-repetitions (plus prologue)."""
    over = {"num_layers": cfg.dense_layers + r * cfg.pattern_period}
    if cfg.encoder_layers:
        over["encoder_layers"] = r
    return over


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             accum_steps: int = 8, extra_cfg: dict | None = None):
    """Full compile (memory proof) + two shallow depth probes whose
    cost_analysis/collective terms are affine-extrapolated to full depth
    (lax.scan bodies are counted once by cost_analysis; probes at reps=1,2
    compile unrolled, so terms are exact at those depths and affine in
    depth).  Probes use accum_steps=1 (same total math)."""
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "pod2" if multi_pod else "pod1",
                "status": "skipped", "reason": why}

    _, _, kind = None, None, input_specs(cfg, shape_name)[0]
    accum = accum_steps if kind == "train" else 1
    rec, compiled, lowered = lower_cell(
        arch, shape_name, multi_pod, extra_cfg=extra_cfg, accum_steps=accum)
    del compiled, lowered

    probes = []
    for r in (1, 2):
        over = _probe_layers(cfg, r)
        # exact-counting substitutions (same math, no inner while loops):
        # naive attention instead of kv-chunk-scanned flash; vectorized SSD
        over.update({"unroll": True, "flash_threshold": 1 << 30,
                     "ssd_vectorized": True})
        over.update(extra_cfg or {})
        p, c, l = lower_cell(arch, shape_name, multi_pod, extra_cfg=over,
                             accum_steps=1)
        probes.append(p)
        del c, l
    reps_full = (cfg.num_layers - cfg.dense_layers) // cfg.pattern_period
    extra = {}
    for key in ("flops", "hbm_bytes", "wire_bytes"):
        f1, f2 = probes[0]["_probe"][key], probes[1]["_probe"][key]
        extra[key] = max(f1 + (f2 - f1) * (reps_full - 1), f1)
    n_chips = rec["n_chips"]
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    terms = {
        "compute_s": extra["flops"] / PEAK_FLOPS_BF16,
        "memory_s": extra["hbm_bytes"] / HBM_BW,
        "collective_s": extra["wire_bytes"] / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get).replace("_s", "")
    mf_dev = rec["roofline"]["model_flops_per_device"]
    rec["roofline_extrapolated"] = {
        **{k: extra[k] for k in extra},
        **terms,
        "bottleneck": bottleneck,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": mf_dev / extra["flops"] if extra["flops"] else 0.0,
        "probe_reps": [1, 2],
        "reps_full": reps_full,
    }
    rec["probe_compile_s"] = [p["compile_s"] for p in probes]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--block-skip", action="store_true",
                    help="causal block-skip flash schedule (§Perf)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    for arch, shape_name in cells:
        tag = f"{arch.replace('.', '_')}__{shape_name}__{args.mesh}"
        fp = outdir / f"{tag}.json"
        if fp.exists() and not args.force:
            print(f"[skip existing] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            rec = run_cell(arch, shape_name, args.mesh == "pod2")
            if rec["status"] == "ok":
                rx = rec["roofline_extrapolated"]
                print(f"  compile {rec['compile_s']}s  "
                      f"flops/dev {rx['flops']:.3e}  "
                      f"bottleneck {rx['bottleneck']}  "
                      f"useful {rx['useful_flops_ratio']:.2f}")
                print(f"  memory_analysis: args "
                      f"{rec['memory']['argument_size_bytes']} temp "
                      f"{rec['memory']['temp_size_bytes']}")
            else:
                print(f"  SKIPPED: {rec['reason']}")
        except Exception as e:  # record the failure; the sweep continues
            rec = {"arch": arch, "shape": shape_name, "mesh": args.mesh,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"  ERROR: {rec['error']}")
        fp.write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
