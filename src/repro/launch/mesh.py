"""Production mesh definition (assignment MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (assignment §Roofline)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
HBM_PER_CHIP = 16e9          # bytes (v5e)
