"""Production mesh definition (assignment MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_forest_mesh(num_shards: int):
    """1-D "shards" mesh for the DeltaForest (repro/distributed).

    Uses the largest divisor of ``num_shards`` that fits the available
    device count, so the stacked (S, ...) forest arenas always split
    evenly; leftover shards-per-device are vmapped inside the shard_map
    body.  On a single device this degenerates to a size-1 mesh (pure
    vmap), which keeps the forest runnable in unit tests without
    --xla_force_host_platform_device_count.
    """
    nd = jax.device_count()
    use = max(d for d in range(1, min(nd, num_shards) + 1)
              if num_shards % d == 0)
    return jax.sharding.Mesh(np.asarray(jax.devices()[:use]), ("shards",))


# TPU v5e hardware constants (assignment §Roofline)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
HBM_PER_CHIP = 16e9          # bytes (v5e)
