"""End-to-end training driver (example-scale on CPU, production mesh on TPU).

Features exercised here (DESIGN.md §10/§11):
- sharded params (TP+FSDP rules) under a host mesh,
- AdamW + cosine schedule + grad clip + grad accumulation,
- deterministic-by-step data pipeline with prefetch,
- checkpoint/restart (atomic, async, resharding-capable) + SIGTERM trap,
- optional DiLoCo-style cross-pod sync with int8-compressed deltas.

Usage (smoke scale):
  PYTHONPATH=src python -m repro.launch.train --arch granite_8b --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import CheckpointManager, latest_step
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, Pipeline
from repro.launch.mesh import make_host_mesh
from repro.models.registry import api
from repro.optim import AdamWConfig, adamw_init
from repro.parallel import shardings as SH
from repro.parallel.ax import logical_rules
from repro.train import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    m = api(cfg)
    mesh = make_host_mesh(args.data, args.model)
    ocfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=5)
    step_fn = make_train_step(cfg, ocfg, accum_steps=args.accum)

    params_shape = jax.eval_shape(m.init_params, jax.random.key(0))
    pspecs = SH.param_specs(params_shape)
    ospecs = SH.opt_specs(pspecs)
    psh = SH.to_named(pspecs, mesh)
    osh = SH.to_named(ospecs, mesh)
    bspec = NamedSharding(mesh, SH.batch_spec(mesh, args.batch, 2))

    with mesh, logical_rules(mesh):
        params = jax.jit(m.init_params, out_shardings=psh)(jax.random.key(0))
        opt = jax.jit(lambda p: adamw_init(ocfg, p), out_shardings=osh)(params)

        dcfg = DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            global_batch=args.batch, family=cfg.family, d_model=cfg.d_model,
            vision_tokens=cfg.vision_tokens, encoder_seq=cfg.encoder_seq,
        )
        start = 0
        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        if ckpt and args.resume and latest_step(args.ckpt_dir) is not None:
            start, (params, opt), extra = ckpt.restore(
                None, (params_shape,
                       jax.eval_shape(lambda p: adamw_init(ocfg, p),
                                      params_shape)),
                shardings=(psh, osh))
            print(f"[train] resumed from step {start}")

        jitted = jax.jit(
            step_fn,
            in_shardings=(psh, osh, None),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1),
        )

        stop = {"now": False}
        signal.signal(signal.SIGTERM, lambda *_: stop.update(now=True))

        pipe = Pipeline(dcfg, start_step=start)
        t0 = time.time()
        tokens_done = 0
        try:
            for _ in range(start, args.steps):
                step, batch = next(pipe)
                batch = {k: jax.device_put(jnp.asarray(v), bspec)
                         if v.ndim >= 2 else jnp.asarray(v)
                         for k, v in batch.items()}
                params, opt, metrics = jitted(params, opt, batch)
                tokens_done += args.batch * args.seq
                if step % args.log_every == 0 or step == args.steps - 1:
                    loss = float(metrics["loss"])
                    gn = float(metrics["grad_norm"])
                    tps = tokens_done / max(time.time() - t0, 1e-9)
                    print(f"[train] step {step:5d} loss {loss:8.4f} "
                          f"gnorm {gn:7.3f} tok/s {tps:9.0f}", flush=True)
                    assert np.isfinite(loss), "loss diverged"
                if ckpt and (step % args.ckpt_every == 0 or stop["now"]
                             or step == args.steps - 1):
                    ckpt.save(step + 1, (params, opt),
                              extra={"data_step": step + 1})
                if stop["now"]:
                    print("[train] SIGTERM: checkpointed and exiting")
                    break
        finally:
            pipe.close()
            if ckpt:
                ckpt.wait()
    return params


if __name__ == "__main__":
    main()
