"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun."""

from __future__ import annotations

import json
from pathlib import Path


def load(outdir="results/dryrun"):
    recs = []
    for p in sorted(Path(outdir).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    if x is None:
        return "-"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def dryrun_table(recs, mesh="pod1") -> str:
    lines = [
        "| arch | shape | status | compile | bytes/dev (args+temp) | "
        "collectives (AR/AG/RS/A2A/CP) |",
        "|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP: "
                         f"{r['reason'][:48]} | - | - | - |")
            continue
        if r["status"] == "error":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR "
                         f"{r['error'][:60]} | - | - | - |")
            continue
        mem = r["memory"]
        cc = r["collectives"]["counts"]
        coll = (f"{cc['all-reduce']}/{cc['all-gather']}/"
                f"{cc['reduce-scatter']}/{cc['all-to-all']}/"
                f"{cc['collective-permute']}")
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']}s | "
            f"{fmt_bytes(mem['argument_size_bytes'])}+"
            f"{fmt_bytes(mem['temp_size_bytes'])} | {coll} |")
    return "\n".join(lines)


def roofline_table(recs, mesh="pod1") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "useful FLOPs | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        rx = r.get("roofline_extrapolated") or r["roofline"]
        dom = rx["bottleneck"]
        note = {
            "compute": "more chips / faster matmul won't help others",
            "memory": "reduce bytes: fusion, remat policy, dtype",
            "collective": "reshard / overlap / compress",
        }[dom]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rx['compute_s'])} | "
            f"{fmt_s(rx['memory_s'])} | {fmt_s(rx['collective_s'])} | "
            f"**{dom}** | {rx['useful_flops_ratio']*100:.0f}% | {note} |")
    return "\n".join(lines)


def main():
    recs = load()
    for mesh in ("pod1", "pod2"):
        sub = [r for r in recs if r["mesh"] == mesh]
        if not sub:
            continue
        print(f"\n### Dry-run ({mesh})\n")
        print(dryrun_table(recs, mesh))
        print(f"\n### Roofline ({mesh})\n")
        print(roofline_table(recs, mesh))


if __name__ == "__main__":
    main()
