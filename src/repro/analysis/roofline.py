"""Roofline terms from a compiled dry-run artifact (assignment §Roofline).

All quantities are PER-DEVICE (the compiled module is the post-SPMD
per-partition program), which is equivalent to the assignment's
global/chips formulation:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = wire_bytes_per_device / ICI_bw

`collective_bytes` is not in cost_analysis(); we parse the post-optimization
HLO and model per-device wire traffic per op with ring formulas:
  all-reduce        2 * bytes * (n-1)/n
  all-gather            bytes * (n-1)/n          (bytes = result, i.e. the
                                                  gathered per-device output)
  reduce-scatter        bytes * (n-1)            (bytes = result = operand/n)
  all-to-all            bytes * (n-1)/n
  collective-permute    bytes
"""

from __future__ import annotations

import re
from dataclasses import dataclass

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * nb


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    return default


def collective_stats(hlo_text: str, default_group: int = 2) -> dict:
    """Per-device wire bytes by collective kind from post-SPMD HLO text."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        if "replica_groups" not in line and "collective-permute" not in line:
            continue
        mm = _COLL_RE.search(line)
        tuples = []
        if mm:
            kind = mm.group(3)
            tuples.append((mm.group(1), mm.group(2)))
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if not mt:
                continue
            kind = mt.group(2)
            for part in re.finditer(r"([a-z0-9]+)\[([\d,]*)\]", mt.group(1)):
                tuples.append((part.group(1), part.group(2)))
        bytes_ = sum(_shape_bytes(d, s) for d, s in tuples)
        n = _group_size(line, default_group)
        if kind == "all-reduce":
            wire = 2 * bytes_ * (n - 1) / n
        elif kind == "all-gather":
            wire = bytes_ * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = bytes_ * (n - 1)
        elif kind == "all-to-all":
            wire = bytes_ * (n - 1) / n
        else:
            wire = bytes_
        out[kind] += wire
        counts[kind] += 1
    return {"wire_bytes": out, "counts": counts,
            "total_wire_bytes": sum(out.values())}


@dataclass
class Roofline:
    flops: float            # per device
    hbm_bytes: float        # per device
    wire_bytes: float       # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_per_device: float
    useful_flops_ratio: float

    def as_dict(self):
        return self.__dict__.copy()


def roofline_terms(cost: dict, coll: dict, model_flops_global: float,
                   n_chips: int) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    wire = float(coll["total_wire_bytes"])
    terms = {
        "compute": flops / PEAK_FLOPS_BF16,
        "memory": hbm / HBM_BW,
        "collective": wire / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_global / n_chips
    return Roofline(
        flops=flops, hbm_bytes=hbm, wire_bytes=wire,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], bottleneck=bottleneck,
        model_flops_per_device=mf,
        useful_flops_ratio=(mf / flops) if flops else 0.0,
    )


def model_flops(cfg, shape_kind: str, n_tokens: int, n_params: int,
                n_active_params: int) -> float:
    """6·N_active·D train, 2·N_active·D inference (assignment §Roofline;
    N_active = N for dense archs)."""
    if shape_kind == "train":
        return 6.0 * n_active_params * n_tokens
    return 2.0 * n_active_params * n_tokens


def active_params(cfg, n_params: int) -> int:
    """Subtract non-routed expert weights for MoE archs."""
    if not cfg.moe_experts:
        return n_params
    moe_layers = sum(
        1 for i in range(cfg.num_layers) if cfg.ffn_kind(i) == "moe"
    )
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    unused = moe_layers * per_expert * (cfg.moe_experts - cfg.moe_top_k)
    return n_params - unused
