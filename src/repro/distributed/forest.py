"""DeltaForest — S independent ΔTree arenas partitioned by key range.

The forest is the scale-out layer over `repro.core` (DESIGN.md §4): each
shard is a full ΔTree arena owning a contiguous key range, stacked into one
pytree with a leading (S,) axis and driven through ``jax.shard_map`` over
the "shards" mesh (`repro.launch.mesh.make_forest_mesh`).  The public API
is a drop-in superset of `repro.core`:

    ForestConfig, Forest, empty, bulk_build,
    search_batch, lookup_batch, update_batch, successor_jit,
    live_keys, live_items

Semantics are *identical* to a single tree: the router's stable bucket
sort preserves batch order within each shard, and ops on one key always
route to the same shard, so per-shard batch-order application is a valid
linearization of the whole batch.  Searches stay wait-free (pre-step
snapshot per shard).  Maintenance (Rebalance / Expand / Merge) runs
entirely shard-local — the paper's locality argument is what makes the
partition free of cross-shard traffic outside the router's permutation.

Reads take one of two dispatches (DESIGN.md §8): the dense per-shard
vmap (always for updates; for reads when the engine has no fused entry
point or ``ForestConfig.fused`` is off) or the *fused* cross-shard
frontier — co-resident shard arenas concatenated into one base-offset
view, every query seeded at its owner shard's root, one ``delta_walk``
kernel launch per frontier round for the whole routed batch.  Both are
bit-identical (found/payload/succ and per-query hops); the fused path is
what makes ``engine="lockstep"`` pay one frontier instead of S.

Cross-shard coordination exists in exactly one read-only place: a
successor query whose owner shard has no key above it falls through to the
first later non-empty shard's minimum.  The per-shard minima are computed
inside the same dispatch (one extra wait-free successor probe per shard)
and combined with a suffix-min outside the shard_map — no second hop.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DeltaTree,
    TreeConfig,
    layout,
)
from repro.core import deltatree as DT
from repro.core import engine as E
from repro.distributed import router as R
from repro.distributed import splits as SP
from repro.maintenance import MaintenanceStats

OP_SEARCH, OP_INSERT, OP_DELETE = DT.OP_SEARCH, DT.OP_INSERT, DT.OP_DELETE

_NO_SUCC = jnp.int32(2**31 - 1)  # suffix-min identity for absent shard minima


@dataclasses.dataclass(frozen=True)
class ForestConfig:
    """Static forest parameters (hashable; closed over by jitted fns).

    num_shards: S — number of independent ΔTree arenas.
    tree:       per-shard TreeConfig (arena size is *per shard*; its
                ``engine`` field picks the SearchEngine every shard's
                reads run under the shard_map dispatch).
    key_min/max: key domain used for fallback equi-width boundaries.
    """

    num_shards: int = 4
    tree: TreeConfig = TreeConfig()
    key_min: int = layout.KEY_MIN
    key_max: int = layout.KEY_MAX
    fused: bool = True      # use the engine's fused cross-shard frontier
    #                         (when it provides one); False pins reads to
    #                         the dense per-shard vmap dispatch — the
    #                         reference path the fused-conformance suite
    #                         and benchmarks compare against


class Forest(NamedTuple):
    """Stacked-arena pytree: every DeltaTree leaf gains a leading (S,) axis;
    ``splits`` is the (S-1,) boundary array the router searchsorts.

    ``reads``/``updates`` are cumulative per-shard (S,) op counters (the
    obs subsystem's skew view — `shard_load`).  Updates auto-count inside
    `update_batch`; reads are pure, so read batches only accumulate when
    the caller opts in via the `record_reads` state transition.

    ``epoch`` is the arena-mutation counter: bumped by every
    `update_batch`/`flush` (the only transitions that touch arena
    contents), preserved by pure-counter transitions (`record_reads`).
    It keys the host-side fused-view cache — a read on an unchanged
    epoch reuses the cached `fuse_arenas` base-offset view instead of
    rebuilding it per call."""

    trees: DeltaTree
    splits: jax.Array
    reads: jax.Array      # (S,) int32 — ops recorded via `record_reads`
    updates: jax.Array    # (S,) int32 — non-search rows seen by `update_batch`
    epoch: jax.Array      # () int32 — arena-mutation counter (view cache key)


def _stack(trees: list[DeltaTree]) -> DeltaTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def shard_tree(forest: Forest, s: int) -> DeltaTree:
    """Host-side view of one shard's arena (tests / debug)."""
    return jax.tree.map(lambda x: x[s], forest.trees)


def _as_splits(fcfg: ForestConfig, splits) -> jax.Array:
    if splits is None:
        splits = SP.equiwidth_splits(fcfg.num_shards, fcfg.key_min,
                                     fcfg.key_max)
    splits = np.asarray(splits, np.int64)
    assert splits.shape == (fcfg.num_shards - 1,), splits.shape
    return jnp.asarray(splits.astype(np.int32))


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------


def _zero_counters(fcfg: ForestConfig) -> jax.Array:
    return jnp.zeros((fcfg.num_shards,), jnp.int32)


def empty(fcfg: ForestConfig, splits=None) -> Forest:
    trees = _stack([DT.empty(fcfg.tree) for _ in range(fcfg.num_shards)])
    return Forest(trees=trees, splits=_as_splits(fcfg, splits),
                  reads=_zero_counters(fcfg), updates=_zero_counters(fcfg),
                  epoch=jnp.int32(0))


def bulk_build(fcfg: ForestConfig, values: np.ndarray,
               payloads: np.ndarray | None = None, splits=None) -> Forest:
    """Build a forest from unique keys (host-side, like core bulk_build).

    With no explicit ``splits`` the boundaries are equi-depth over
    ``values`` — every shard starts with |values|/S keys regardless of the
    key distribution (the interpolation-tree property)."""
    values = np.asarray(values, np.int64)
    order = np.argsort(values)
    values = values[order]
    if payloads is not None:
        payloads = np.asarray(payloads, np.int64)[order]
    if splits is None:
        splits = SP.equidepth_splits(values, fcfg.num_shards,
                                     fcfg.key_min, fcfg.key_max)
    splits = np.asarray(splits, np.int64)
    sid = SP.shard_of_np(splits, values)
    trees = []
    for s in range(fcfg.num_shards):
        mask = sid == s
        trees.append(DT.bulk_build(
            fcfg.tree, values[mask],
            payloads[mask] if payloads is not None else None))
    return Forest(trees=_stack(trees), splits=_as_splits(fcfg, splits),
                  reads=_zero_counters(fcfg), updates=_zero_counters(fcfg),
                  epoch=jnp.int32(0))


# --------------------------------------------------------------------------
# wait-free reads
# --------------------------------------------------------------------------

# dense pad-lane key: the reserved ROUTE_LEFT sentinel — provably matches
# no stored key, makes lockstep pad lanes born-resolved (round 0, no
# successor chase), and can never alias a real query the way the old
# ``fill=0`` did (0 is EMPTY-adjacent but a *legal* key's neighborhood;
# ROUTE_LEFT is outside the key domain entirely)
_PAD_KEY = jnp.int32(layout.ROUTE_LEFT)


def _route_keys(keys: jax.Array) -> jax.Array:
    """Clamp query keys to the int32 key domain *in the caller's dtype*,
    then cast: under x64 an int64 probe beyond the int32 range would
    otherwise wrap before ``searchsorted`` and route to (and walk in) the
    wrong shard.  Below-domain probes clamp to KEY_MIN-1 = 0 (never
    stored; successor = global minimum), above-domain probes to the
    reserved ROUTE_LEFT sentinel (never stored; no successor) — both
    exactly the semantics of the original out-of-range key."""
    keys = jnp.asarray(keys)
    return jnp.clip(keys, 0, layout.ROUTE_LEFT).astype(jnp.int32)


def _fused(fcfg: ForestConfig):
    """The engine's fused forest entry point when enabled, else None."""
    return E.forest_batch(fcfg.tree) if fcfg.fused else None


# ---- fused-view hoisting (ROADMAP fold-in; serve decode loops) -----------
#
# The fused dispatch's base-offset arena view (`ForestBatch.make_view` →
# `kernels.veb_search.fuse_arenas`) is pure data derived from the arenas:
# read-heavy loops over an unchanged forest were rebuilding it on every
# call.  The public read wrappers below look it up in a small host-side
# LRU keyed on ``(fcfg, epoch)`` — epoch bumps on every arena mutation,
# and a paranoid identity check on the trees pytree catches two distinct
# forests that happen to share an epoch — then hand it to the jitted read
# core as a regular pytree argument.  Inside someone else's trace the
# epoch is a Tracer (unreadable host-side), so the wrapper passes
# ``view=None`` and the hooks build inline — exactly the old graph.

_VIEW_CACHE_CAP = 4  # distinct (fcfg, forest) streams kept warm at once
_VIEW_CACHE: collections.OrderedDict = collections.OrderedDict()
_VIEW_STATS = {"builds": 0, "hits": 0}


@functools.partial(jax.jit, static_argnums=0)
def _build_view(fcfg: ForestConfig, trees):
    fb = _fused(fcfg)
    return R.build_fused_view(fcfg.num_shards,
                              functools.partial(fb.make_view, fcfg.tree),
                              trees)


def _maybe_cached_view(fcfg: ForestConfig, f: Forest):
    """The cached fused view for ``f`` (building + caching on miss), or
    None when hoisting does not apply: fused dispatch off / engine has no
    ``make_view`` / we are inside a trace (epoch unreadable)."""
    fb = _fused(fcfg)
    if fb is None or fb.make_view is None:
        return None
    if isinstance(f.epoch, jax.core.Tracer):
        return None
    key = (fcfg, int(f.epoch))
    ent = _VIEW_CACHE.get(key)
    if ent is not None and ent[0] is f.trees:
        _VIEW_STATS["hits"] += 1
        _VIEW_CACHE.move_to_end(key)
        return ent[1]
    view = _build_view(fcfg, f.trees)
    _VIEW_STATS["builds"] += 1
    # one live view per fcfg: a rebuild means the arena moved on (update /
    # different forest), so the old epoch's view is dead weight — arena-
    # sized, worth dropping eagerly rather than waiting out the LRU
    for stale in [k for k in _VIEW_CACHE if k[0] == fcfg]:
        del _VIEW_CACHE[stale]
    _VIEW_CACHE[key] = (f.trees, view)
    while len(_VIEW_CACHE) > _VIEW_CACHE_CAP:
        _VIEW_CACHE.popitem(last=False)
    return view


def fused_view_cache_stats() -> dict:
    """Host-side cache counters (obs / regression tests): cumulative
    builds + hits since process start or the last reset, current size."""
    return {"builds": _VIEW_STATS["builds"], "hits": _VIEW_STATS["hits"],
            "size": len(_VIEW_CACHE)}


def reset_fused_view_cache() -> None:
    _VIEW_CACHE.clear()
    _VIEW_STATS["builds"] = 0
    _VIEW_STATS["hits"] = 0


def search_batch(fcfg: ForestConfig, f: Forest, keys: jax.Array):
    """Routed wait-free search. Returns (found[K], hops[K]) — plus a
    trailing `ReadStats` when ``fcfg.tree.collect_stats`` is on."""
    return _search_core(fcfg, f, keys, _maybe_cached_view(fcfg, f))


def lookup_batch(fcfg: ForestConfig, f: Forest, keys: jax.Array):
    """Routed map-mode lookup. Returns (found[K], payload[K], hops[K]) —
    plus a trailing `ReadStats` when ``fcfg.tree.collect_stats`` is on."""
    return _lookup_core(fcfg, f, keys, _maybe_cached_view(fcfg, f))


@functools.partial(jax.jit, static_argnums=0)
def _search_core(fcfg: ForestConfig, f: Forest, keys: jax.Array, view):
    out = _lookup(fcfg, f, keys, view)
    if E.collecting(fcfg.tree):
        found, _, hops, stats = out
        return found, hops, stats
    found, _, hops = out
    return found, hops


@functools.partial(jax.jit, static_argnums=0)
def _lookup_core(fcfg: ForestConfig, f: Forest, keys: jax.Array, view):
    return _lookup(fcfg, f, keys, view)


def _forest_read_stats(fcfg: ForestConfig, f: Forest, raw, keys, sid,
                       found, hops):
    """Forest `ReadStats` from batch-order read columns (obs tentpole).

    Computed on the *gathered* batch-order (found, hops) so both dispatch
    paths (fused frontier / dense vmap) produce bit-identical stats —
    same structural argument as the single-tree dispatch layer.  The
    router leg adds per-shard lane counts plus how many caller keys the
    key-domain clamp (`_route_keys`) rewrote."""
    from repro.obs.stats import ReadStats, RouterStats, SearchStats

    pad = keys == _PAD_KEY
    member = jax.vmap(lambda t: DT.buffered_member(fcfg.tree, t, keys))(
        f.trees)  # (S, K) buffered membership; pick each lane's owner shard
    bhit = found & member[sid, jnp.arange(keys.shape[0])]
    clamped = jnp.sum((raw != keys.astype(raw.dtype)).astype(jnp.int32))
    transfers = None
    if E.collecting_transfers(fcfg.tree):
        from repro.obs import transfers as OTR

        # shard-local replay from (stacked arenas, owner sid, keys): both
        # dispatch paths hand this the same sid values (fused computes
        # shard_ids, vmap reuses the route's), so fused/vmap transfer
        # parity is structural like the search leg above
        transfers = OTR.measure_stacked(
            fcfg.tree, f.trees.value, f.trees.child, f.trees.root[sid],
            sid, keys)
    return ReadStats(
        search=SearchStats.of(hops, pad, bhit),
        router=RouterStats.of(R.lane_counts(sid, fcfg.num_shards), clamped),
        transfers=transfers,
    )


def _lookup(fcfg: ForestConfig, f: Forest, keys: jax.Array, view=None):
    raw = jnp.asarray(keys)
    keys = _route_keys(raw)
    fb = _fused(fcfg)
    if fb is not None:
        # fused frontier: batch order end to end, one kernel launch per
        # round across all co-resident shards (no (S, K) dense scatter)
        sid = R.shard_ids(f.splits, keys)

        def per_device(trees_loc, lid, ks, view_loc):
            return fb.lookup(fcfg.tree, trees_loc, lid, ks,
                             view=view_loc), None

        r, lane, _ = R.fused_dispatch(fcfg.num_shards, per_device,
                                      f.trees, sid, keys, view=view)
        found, pay, hops = R.gather_fused(r, lane)
    else:
        r = R.route(f.splits, keys)
        sid = r.sid
        dkeys = R.scatter_dense(r, fcfg.num_shards, keys, _PAD_KEY)

        def per_shard(t, ks):
            # bare engine hook (always 3-tuple): stats derive once below,
            # from batch-order columns, not per shard inside the dispatch
            return E.lookup_cols(fcfg.tree, t, ks)

        found, pay, hops = R.dispatch(fcfg.num_shards, per_shard, f.trees,
                                      dkeys)
        found, pay, hops = (R.gather_batch(r, found), R.gather_batch(r, pay),
                            R.gather_batch(r, hops))
    if not E.collecting(fcfg.tree):
        return found, pay, hops
    return found, pay, hops, _forest_read_stats(fcfg, f, raw, keys, sid,
                                                found, hops)


def _succ_combine(sid, f_owner, s_owner, has_min, mins):
    """Cross-shard successor combine: first non-empty shard strictly
    after each owner shard (suffix min over shard minima works because
    shards are key-ordered) — shared by both dispatch paths so the fused
    frontier cannot drift from the vmap reference."""
    masked = jnp.where(has_min, mins, _NO_SUCC)
    suffix = jax.lax.associative_scan(jnp.minimum, masked, reverse=True)
    after = jnp.concatenate([suffix[1:], jnp.full((1,), _NO_SUCC)])
    fallback = after[sid]
    out_found = f_owner | (fallback < _NO_SUCC)
    out_succ = jnp.where(f_owner, s_owner,
                         jnp.where(fallback < _NO_SUCC, fallback, 0))
    return out_found, out_succ


def successor_jit(fcfg: ForestConfig, f: Forest, keys: jax.Array):
    """Routed wait-free successor. Returns (found[K], succ[K]).

    Owner-shard miss falls through to the first later non-empty shard's
    minimum (computed in the same dispatch; combined with a suffix-min)."""
    return _successor_core(fcfg, f, keys, _maybe_cached_view(fcfg, f))


@functools.partial(jax.jit, static_argnums=0)
def _successor_core(fcfg: ForestConfig, f: Forest, keys: jax.Array, view):
    keys = _route_keys(keys)
    fb = _fused(fcfg)
    if fb is not None:
        sid = R.shard_ids(f.splits, keys)

        def per_device(trees_loc, lid, ks, view_loc):
            found, succ, has_min, mins = fb.successor(
                fcfg.tree, trees_loc, lid, ks, view=view_loc)
            return (found, succ), (has_min, mins)

        r, (found, succ), (has_min, mins) = R.fused_dispatch(
            fcfg.num_shards, per_device, f.trees, sid, keys, view=view)
        f_owner, s_owner = R.gather_fused(r, (found, succ))
        return _succ_combine(sid, f_owner, s_owner, has_min, mins)
    r = R.route(f.splits, keys)
    dkeys = R.scatter_dense(r, fcfg.num_shards, keys, _PAD_KEY)

    def per_shard(t, ks):
        # shard minimum = successor of (KEY_MIN - 1), riding the same
        # engine dispatch as one extra lane of the batch (lanes are
        # independent, so results are unchanged and the lockstep engine
        # pays no second walk)
        probe = jnp.concatenate(
            [ks, jnp.full((1,), layout.KEY_MIN - 1, jnp.int32)])
        found, succ = DT.successor_batch(fcfg.tree, t, probe)
        return found[:-1], succ[:-1], found[-1], succ[-1]

    found, succ, has_min, mins = R.dispatch(
        fcfg.num_shards, per_shard, f.trees, dkeys)
    f_owner = R.gather_batch(r, found)
    s_owner = R.gather_batch(r, succ)
    return _succ_combine(r.sid, f_owner, s_owner, has_min, mins)


# --------------------------------------------------------------------------
# ordered bulk reads (range scan / successor_k)
# --------------------------------------------------------------------------


def scan_batch(fcfg: ForestConfig, f: Forest, starts: jax.Array,
               his: jax.Array, *, max_items: int):
    """Routed wait-free range scan: per lane, up to ``max_items`` live
    items with ``start < key <= hi`` in *global* key order.

    Returns the engine `scan` contract — (out (K, max_items) packed
    ascending with sentinel padding, n (K,), hops (K,), more (K,) bool).
    Unlike point reads, a range can span shards, so every lane is scanned
    against every shard (one emit-cursor lane per (lane, shard) pair —
    still ONE ``delta_scan`` dispatch under the fused frontier); shards
    partition the key space in split order, so the per-shard bands
    concatenate sorted and the first ``max_items`` of the union are the
    globally correct page even when an early shard's band truncated
    (everything after a truncated band belongs to the continuation).
    ``hops`` is the lane's total ΔNode visits across all shards."""
    return _scan_core(fcfg, f, starts, his, max_items,
                      _maybe_cached_view(fcfg, f))


def successor_k(fcfg: ForestConfig, f: Forest, keys: jax.Array, k: int):
    """Routed bulk successors: the ``k`` smallest live keys strictly
    greater than each query, forest-wide (same return contract as
    `scan_batch`; subsumes the point `successor_jit` fallthrough — the
    scan's shard bands are what the suffix-min combine approximates for
    k=1)."""
    keys = jnp.asarray(keys, jnp.int32)
    his = jnp.full(keys.shape, layout.KEY_MAX, jnp.int32)
    return _scan_core(fcfg, f, keys, his, k, _maybe_cached_view(fcfg, f))


@functools.partial(jax.jit, static_argnums=(0, 4))
def _scan_core(fcfg: ForestConfig, f: Forest, starts: jax.Array,
               his: jax.Array, max_items: int, view):
    cfg = fcfg.tree
    starts = _route_keys(starts)
    his = _route_keys(his)
    s = fcfg.num_shards
    k = starts.shape[0]
    fb = _fused(fcfg)
    if fb is not None and fb.scan is not None:
        # (lane, shard) tiling, shard-major: tiled lane s*k + i scans
        # lane i's band inside shard s, seeded at that shard's fused
        # root; sid routes each tiled lane to its shard's device
        sid = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)

        def per_device(trees_loc, lid, bounds, view_loc):
            st, hb = bounds
            return fb.scan(cfg, trees_loc, lid, st, hb, max_items,
                           view=view_loc), None

        r, lane, _ = R.fused_dispatch(
            s, per_device, f.trees, sid,
            (jnp.tile(starts, s), jnp.tile(his, s)), view=view)
        out, n, hops, more = R.gather_fused(r, lane)
        out = out.reshape(s, k, max_items)
        n, hops, more = (n.reshape(s, k), hops.reshape(s, k),
                         more.reshape(s, k))
    else:

        def per_shard(t):
            return E.scan(cfg, t, starts, his, max_out=max_items)

        out, n, hops, more = R.dispatch(s, per_shard, f.trees)
    # shard bands are key-disjoint and shard order == key order: the
    # sorted union's first max_items are exactly the bands in split
    # order, truncated where the page fills (sentinel padding sorts last)
    union = jnp.sort(out.transpose(1, 0, 2).reshape(k, s * max_items),
                     axis=1)[:, :max_items]
    total = jnp.sum(n, axis=0)
    return (union,
            jnp.minimum(jnp.int32(max_items), total),
            jnp.sum(hops, axis=0),
            jnp.any(more, axis=0) | (total > max_items))


# --------------------------------------------------------------------------
# batched updates
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
def update_batch(fcfg: ForestConfig, f: Forest, kinds: jax.Array,
                 keys: jax.Array, payloads: jax.Array | None = None):
    """Routed batch-order updates; per-shard maintenance under the tree
    config's ``maintenance`` policy (shard-local, like all maintenance).

    Returns (forest, results[K] bool, MaintenanceStats) — stats aggregated
    over shards (``rounds`` = max, the critical path of the concurrent
    shards; work counters and ``pending`` sum) — identical contract to
    ``repro.core.update_batch``.

    Updates share the reads' key-domain boundary (`_route_keys`): an
    out-of-int32-domain key (x64 caller) is a no-op row with result
    False — it can never be stored, and silently wrapping it would
    insert a bogus key that the clamped reads could then never see."""
    kq = jnp.asarray(keys)
    in_domain = (kq >= layout.KEY_MIN) & (kq <= layout.KEY_MAX)
    kinds = jnp.where(in_domain, kinds.astype(jnp.int32),
                      jnp.int32(OP_SEARCH))
    keys = _route_keys(kq)
    k = keys.shape[0]
    if payloads is None:
        payloads = jnp.zeros((k,), jnp.int32)
    payloads = payloads.astype(jnp.int32)
    r = R.route(f.splits, keys)
    s = fcfg.num_shards
    dkinds = R.scatter_dense(r, s, kinds.astype(jnp.int32),
                             jnp.int32(OP_SEARCH))  # pads are no-ops
    dkeys = R.scatter_dense(r, s, keys, jnp.int32(0))
    dpays = R.scatter_dense(r, s, payloads, jnp.int32(0))

    def per_shard(t, kn, ks, ps):
        return DT.update_batch_impl(fcfg.tree, t, kn, ks, ps)

    trees, dres, stats = R.dispatch(s, per_shard, f.trees, dkinds, dkeys,
                                    dpays, sequential=True)
    # per-shard cumulative update counters: non-search rows post in-domain
    # masking (a clamped-out row never reaches a shard's update kernel)
    upd = jnp.zeros((s,), jnp.int32).at[r.sid].add(
        (kinds != OP_SEARCH).astype(jnp.int32))
    return (Forest(trees=trees, splits=f.splits,
                   reads=f.reads, updates=f.updates + upd,
                   epoch=f.epoch + 1),
            R.gather_batch(r, dres), MaintenanceStats.reduce(stats))


@functools.partial(jax.jit, static_argnums=(0, 2), donate_argnums=1)
def flush(fcfg: ForestConfig, f: Forest, budget: int = 64):
    """Drain pending maintenance on every shard (restores I5 forest-wide
    after ``deferred``/``budgeted`` batches).  Returns (forest, stats)."""

    def per_shard(t):
        return DT.flush_impl(fcfg.tree, t, budget)

    trees, stats = R.dispatch(fcfg.num_shards, per_shard, f.trees,
                              sequential=True)
    return (Forest(trees=trees, splits=f.splits,
                   reads=f.reads, updates=f.updates, epoch=f.epoch + 1),
            MaintenanceStats.reduce(stats))


# --------------------------------------------------------------------------
# per-shard load counters (obs)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
def record_reads(fcfg: ForestConfig, f: Forest, keys: jax.Array) -> Forest:
    """Fold one read batch into the cumulative per-shard ``reads``
    counters.  Reads themselves are pure (wait-free snapshots), so
    accumulation is an explicit state transition the serving/benchmark
    loop opts into — the read path never grows a hidden side effect."""
    sid = R.shard_ids(f.splits, _route_keys(keys))
    return f._replace(reads=f.reads + R.lane_counts(sid, fcfg.num_shards))


def shard_load(f: Forest) -> dict:
    """Host-side view of the cumulative per-shard op counters."""
    return {"reads": np.asarray(f.reads).tolist(),
            "updates": np.asarray(f.updates).tolist()}


# --------------------------------------------------------------------------
# host-side debug / verification (mirror repro.core)
# --------------------------------------------------------------------------


def live_items(fcfg: ForestConfig, f: Forest):
    """All live (key, payload) pairs, key-sorted (shard order == key order)."""
    out = []
    for s in range(fcfg.num_shards):
        out.extend(DT.live_items(fcfg.tree, shard_tree(f, s)))
    return out


def live_keys(fcfg: ForestConfig, f: Forest) -> np.ndarray:
    return np.asarray([k for k, _ in live_items(fcfg, f)], dtype=np.int64)


def alloc_failed(f: Forest) -> bool:
    """True if any shard's arena ever exhausted (sticky, like core)."""
    return bool(np.asarray(f.trees.alloc_fail).any())
