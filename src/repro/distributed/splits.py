"""Key-range partitioner for the DeltaForest (DESIGN.md §4).

Shard boundaries follow the *observed* key distribution, interpolation-tree
style (Prokopec et al., 2020): given a key sample, ``equidepth_splits``
places the S-1 boundaries at equi-depth quantiles so every shard owns the
same number of sampled keys.  Shard ownership is

    shard(k) = #{ j : splits[j] <= k }       (jnp.searchsorted side="right")

i.e. shard 0 owns keys below ``splits[0]`` and shard j owns
``[splits[j-1], splits[j])`` — ``splits[j]`` is the smallest key of shard
j+1.  Boundaries are strictly increasing; degenerate samples fall back to
equi-width boundaries over the key domain.

The partition is a control-plane decision: it is chosen host-side (numpy),
then baked into the forest as a tiny (S-1,) device array that the jitted
router searchsorts against.  ``rebalance`` is the slow-path entry point
that re-derives boundaries from the *live* key set and rebuilds the forest
when growth has skewed the shards.
"""

from __future__ import annotations

import numpy as np

from repro.core import layout


def equiwidth_splits(num_shards: int, key_min: int = layout.KEY_MIN,
                     key_max: int = layout.KEY_MAX) -> np.ndarray:
    """Uniform boundaries over [key_min, key_max] (no-sample fallback)."""
    assert num_shards >= 1
    span = int(key_max) - int(key_min) + 1
    bnd = key_min + (np.arange(1, num_shards, dtype=np.int64) * span) // num_shards
    return bnd.astype(np.int64)


def equidepth_splits(sample: np.ndarray, num_shards: int,
                     key_min: int = layout.KEY_MIN,
                     key_max: int = layout.KEY_MAX) -> np.ndarray:
    """Equi-depth boundaries from a key sample.

    Returns (num_shards - 1,) strictly increasing boundaries.  Quantile
    positions that collide (tiny or highly skewed samples) are repaired
    from the equi-width grid so the router always sees a valid partition.
    """
    assert num_shards >= 1
    if num_shards == 1:
        return np.zeros((0,), np.int64)
    sample = np.sort(np.asarray(sample, np.int64).ravel())
    fallback = equiwidth_splits(num_shards, key_min, key_max)
    if sample.size == 0:
        return fallback
    # boundary j = smallest key of shard j+1 -> the (j+1)*n/S-th sample
    idx = ((np.arange(1, num_shards, dtype=np.int64) * sample.size)
           // num_shards)
    bnd = sample[np.clip(idx, 0, sample.size - 1)]
    # enforce strict monotonicity inside (key_min, key_max]
    out = np.empty(num_shards - 1, np.int64)
    prev = int(key_min)
    for j in range(num_shards - 1):
        b = int(max(bnd[j], prev + 1))
        b = min(b, int(key_max))
        out[j] = b
        prev = b
    # if we saturated at key_max, spread the tail from the equi-width grid
    for j in range(num_shards - 2, -1, -1):
        hi = int(key_max) - (num_shards - 2 - j)
        if out[j] > hi:
            out[j] = hi
    if (np.diff(out) <= 0).any():
        return fallback
    return out


def shard_of_np(splits: np.ndarray, keys: np.ndarray) -> np.ndarray:
    """Host-side shard ownership (mirrors the jitted router)."""
    return np.searchsorted(np.asarray(splits, np.int64),
                           np.asarray(keys, np.int64), side="right")


def shard_counts(fcfg, forest) -> np.ndarray:
    """Live keys per shard (host-side).  Buffers are empty post-step
    (invariant I5), so per-arena ``nlive`` over alive ΔNodes is exact."""
    nlive = np.asarray(forest.trees.nlive)
    alive = np.asarray(forest.trees.alive)
    return (nlive * alive).sum(axis=1).astype(np.int64)


def needs_rebalance(fcfg, forest, *, skew: float = 2.0) -> bool:
    """True when the fullest shard holds > ``skew`` times its fair share.

    The worst case with S shards is S times the mean, so the effective
    threshold is clamped to (S+1)/2 — strictly below S — ensuring maximal
    skew always trips regardless of shard count (S=2 included)."""
    counts = shard_counts(fcfg, forest)
    total = counts.sum()
    if total == 0 or len(counts) <= 1:
        return False
    eff = min(skew, (len(counts) + 1) / 2)
    return bool(counts.max() > eff * (total / len(counts)))


def rebalance(fcfg, forest):
    """Re-partition the forest equi-depth over its *live* keys and rebuild.

    Slow path by design (host-side gather + bulk_build): the paper's
    maintenance stays shard-local; this is the forest-level analogue of a
    Rebalance sweep, invoked rarely by the driver when ``needs_rebalance``
    trips.  Returns a new Forest; the old one remains valid (functional).
    """
    from repro.distributed import forest as F

    items = F.live_items(fcfg, forest)
    keys = np.asarray([k for k, _ in items], np.int64)
    pays = np.asarray([p for _, p in items], np.int64)
    new_splits = equidepth_splits(keys, fcfg.num_shards,
                                  fcfg.key_min, fcfg.key_max)
    return F.bulk_build(fcfg, keys, pays if fcfg.tree.payload_bits else None,
                        splits=new_splits)
