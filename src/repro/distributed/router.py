"""Batched cross-shard routing for the DeltaForest (DESIGN.md §4).

A mixed query/update batch arrives in *linearization order*.  The router

  1. assigns every op its owner shard with one ``searchsorted`` against the
     (S-1,) boundary array,
  2. bucket-sorts the batch by shard with a single stable argsort (stability
     preserves batch order *within* each shard, which is exactly what the
     per-shard linearization needs — ops on the same key always land in the
     same shard, so batch-order semantics are preserved end to end),
  3. computes segment offsets of the sorted shard ids (a second
     searchsorted) and scatters each op into a dense (S, K) per-shard lane,
     padded with no-op rows (OP_SEARCH / key 0),
  4. dispatches the per-shard kernels under ``shard_map`` over the
     "shards" mesh (leftover shards-per-device vmapped inside the body),
  5. inverse-permutes the (S, K) per-shard results back to batch order.

Everything on the hot path is shape-static and jittable: no Python loop
touches an op, and the only per-shard state a device reads is its own arena
slice — the forest's realization of the paper's "maintenance stays local".
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.parallel import make_forest_mesh


class Routing(NamedTuple):
    """Static-shape routing plan for one batch (all (K,) int32)."""

    sid: jax.Array         # owner shard per op, batch order
    order: jax.Array       # stable permutation sorting ops by shard
    sid_sorted: jax.Array  # sid[order]
    local: jax.Array       # lane within the owner shard's dense row


def route(splits: jax.Array, keys: jax.Array) -> Routing:
    """Build the bucket-sort plan: searchsorted + segment offsets."""
    k = keys.shape[0]
    num_shards = splits.shape[0] + 1
    sid = jnp.searchsorted(
        splits, keys.astype(splits.dtype), side="right"
    ).astype(jnp.int32)
    order = jnp.argsort(sid, stable=True)
    sid_sorted = sid[order]
    # offsets[s] = first sorted index owned by shard s (segment offsets)
    offsets = jnp.searchsorted(
        sid_sorted, jnp.arange(num_shards, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    local = jnp.arange(k, dtype=jnp.int32) - offsets[sid_sorted]
    return Routing(sid, order, sid_sorted, local)


def scatter_dense(r: Routing, num_shards: int, x: jax.Array, fill) -> jax.Array:
    """Batch-order (K,) -> dense per-shard (S, K), padded with ``fill``."""
    k = x.shape[0]
    dense = jnp.full((num_shards, k), fill, x.dtype)
    return dense.at[r.sid_sorted, r.local].set(x[r.order])


def gather_batch(r: Routing, dense: jax.Array) -> jax.Array:
    """Inverse permute dense per-shard (S, K, ...) results to batch order."""
    k = r.order.shape[0]
    picked = dense[r.sid_sorted, r.local]
    out = jnp.zeros((k,) + dense.shape[2:], dense.dtype)
    return out.at[r.order].set(picked)


@functools.lru_cache(maxsize=None)
def forest_mesh(num_shards: int):
    return make_forest_mesh(num_shards)


def dispatch(num_shards: int, fn, trees, *dense_args, sequential=False):
    """Run ``fn(tree, *args)`` once per shard under shard_map.

    ``trees`` is the stacked (S, ...) arena pytree; every ``dense_args``
    leaf carries a leading S axis.  The mesh splits the S axis across
    devices; shards co-resident on one device run under vmap (reads) or
    ``lax.map`` (``sequential=True`` — the update path: vmapping
    `update_batch_impl` would lower its lax.cond/switch branches to
    execute-all-branches selects, a ~100x slowdown, whereas lax.map keeps
    them real XLA conditionals; cross-*device* shards still run in
    parallel under the shard_map).  Outputs may be any pytree whose
    leaves carry the leading S axis.
    """
    mesh = forest_mesh(num_shards)

    def body(trees_loc, *args_loc):
        if sequential:
            return jax.lax.map(lambda a: fn(*a), (trees_loc,) + args_loc)
        return jax.vmap(fn)(trees_loc, *args_loc)

    nargs = 1 + len(dense_args)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P("shards"),) * nargs,
        out_specs=P("shards"),
        check_rep=False,
    )(trees, *dense_args)
