"""Batched cross-shard routing for the DeltaForest (DESIGN.md §4, §8).

A mixed query/update batch arrives in *linearization order*.  The dense
dispatch (updates; reads under engines without a fused entry point)

  1. assigns every op its owner shard with one ``searchsorted`` against the
     (S-1,) boundary array,
  2. bucket-sorts the batch by shard with a single stable argsort (stability
     preserves batch order *within* each shard, which is exactly what the
     per-shard linearization needs — ops on the same key always land in the
     same shard, so batch-order semantics are preserved end to end),
  3. computes segment offsets of the sorted shard ids (a second
     searchsorted) and scatters each op into a dense (S, K) per-shard lane,
     padded with no-op rows (OP_SEARCH / the born-resolved ROUTE_LEFT
     sentinel key),
  4. dispatches the per-shard kernels under ``shard_map`` over the
     "shards" mesh (leftover shards-per-device vmapped inside the body),
  5. inverse-permutes the (S, K) per-shard results back to batch order.

``fused_dispatch`` (DESIGN.md §8) is the read path's alternative when the
engine provides a fused cross-shard frontier: no per-*shard* lanes at all
— on one device the batch passes through in batch order; on D devices it
bucket-sorts by owner device ((D, K) lanes) and each device fuses its
co-resident shards into one base-offset arena walk.

Everything on the hot path is shape-static and jittable: no Python loop
touches an op, and the only per-shard state a device reads is its own arena
slice — the forest's realization of the paper's "maintenance stays local".
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import layout
from repro.obs import trace as TR
from repro.parallel import make_forest_mesh


class Routing(NamedTuple):
    """Static-shape routing plan for one batch (all (K,) int32)."""

    sid: jax.Array         # owner bucket per op, batch order
    order: jax.Array       # stable permutation sorting ops by bucket
    sid_sorted: jax.Array  # sid[order]
    local: jax.Array       # lane within the owner bucket's dense row


def shard_ids(splits: jax.Array, keys: jax.Array) -> jax.Array:
    """Owner shard per key: one searchsorted against the boundaries.

    The *boundaries* widen to the key dtype, never the reverse — an int64
    probe beyond the int32 range (x64 callers) must not wrap before it is
    routed, or it lands on a bogus shard.  Splits always fit int32, so
    widening them is lossless."""
    return jnp.searchsorted(
        splits.astype(keys.dtype), keys, side="right"
    ).astype(jnp.int32)


def route(splits: jax.Array, keys: jax.Array) -> Routing:
    """Build the bucket-sort plan: searchsorted + segment offsets."""
    return route_by(shard_ids(splits, keys), splits.shape[0] + 1)


def route_by(ids: jax.Array, num_buckets: int) -> Routing:
    """Bucket-sort plan over precomputed bucket ids (stable argsort ⇒
    batch order is preserved *within* each bucket — the per-bucket
    linearization).  ``route`` is this over owner shards; the fused
    dispatch uses it over owner *devices*."""
    k = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    ids_sorted = ids[order]
    # offsets[s] = first sorted index owned by bucket s (segment offsets)
    offsets = jnp.searchsorted(
        ids_sorted, jnp.arange(num_buckets, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    local = jnp.arange(k, dtype=jnp.int32) - offsets[ids_sorted]
    return Routing(ids, order, ids_sorted, local)


def lane_counts(ids: jax.Array, num_buckets: int) -> jax.Array:
    """Per-bucket lane counts of one routed batch ((num_buckets,) int32)
    — the router leg of ``ReadStats`` and the forest's per-shard load
    counters share this one scatter-add."""
    return jnp.zeros((num_buckets,), jnp.int32).at[ids].add(1)


def scatter_dense(r: Routing, num_shards: int, x: jax.Array, fill) -> jax.Array:
    """Batch-order (K,) -> dense per-shard (S, K), padded with ``fill``."""
    k = x.shape[0]
    dense = jnp.full((num_shards, k), fill, x.dtype)
    return dense.at[r.sid_sorted, r.local].set(x[r.order])


def gather_batch(r: Routing, dense: jax.Array) -> jax.Array:
    """Inverse permute dense per-shard (S, K, ...) results to batch order."""
    k = r.order.shape[0]
    picked = dense[r.sid_sorted, r.local]
    out = jnp.zeros((k,) + dense.shape[2:], dense.dtype)
    return out.at[r.order].set(picked)


@functools.lru_cache(maxsize=None)
def _forest_mesh_cached(num_shards: int, ndev: int):
    del ndev  # cache key only — make_forest_mesh reads the live device set
    return make_forest_mesh(num_shards)


def forest_mesh(num_shards: int):
    """The "shards" mesh for ``num_shards``, cached per (num_shards,
    device_count) — a change in visible devices within one process
    (fake-device tests, late backend selection) gets a fresh mesh instead
    of a stale cached one."""
    return _forest_mesh_cached(num_shards, jax.device_count())


def dispatch(num_shards: int, fn, trees, *dense_args, sequential=False):
    """Run ``fn(tree, *args)`` once per shard under shard_map.

    ``trees`` is the stacked (S, ...) arena pytree; every ``dense_args``
    leaf carries a leading S axis.  The mesh splits the S axis across
    devices; shards co-resident on one device run under vmap (reads) or
    ``lax.map`` (``sequential=True`` — the update path: vmapping
    `update_batch_impl` would lower its lax.cond/switch branches to
    execute-all-branches selects, a ~100x slowdown, whereas lax.map keeps
    them real XLA conditionals; cross-*device* shards still run in
    parallel under the shard_map).  Outputs may be any pytree whose
    leaves carry the leading S axis.
    """
    mesh = forest_mesh(num_shards)

    def body(trees_loc, *args_loc):
        if sequential:
            return jax.lax.map(lambda a: fn(*a), (trees_loc,) + args_loc)
        return jax.vmap(fn)(trees_loc, *args_loc)

    nargs = 1 + len(dense_args)
    with TR.annotate("router.dispatch"):
        return shard_map(
            body, mesh=mesh,
            in_specs=(P("shards"),) * nargs,
            out_specs=P("shards"),
            check_rep=False,
        )(trees, *dense_args)


def build_fused_view(num_shards: int, make_view, trees):
    """Precompute the fused base-offset view ``fused_dispatch`` would
    otherwise rebuild per call (the engine's ``ForestBatch.make_view``
    hook, run under the same mesh layout the dispatch uses).

    On a 1-device mesh this is ``make_view(trees)`` verbatim; on D
    devices each device fuses its co-resident shards and the per-device
    views stack to a leading (D,) axis (mirroring the dispatch body's
    ``x[None]`` wrap), so ``fused_dispatch(view=...)`` can split the same
    axis back out through shard_map.  The result is pure data derived
    from ``trees`` — the forest layer caches it keyed on the update
    epoch and hands it back to read calls until the arena changes."""
    mesh = forest_mesh(num_shards)
    d = mesh.devices.size
    if d == 1:
        with TR.annotate("router.fuse_view"):
            return make_view(trees)

    def body(trees_loc):
        return jax.tree.map(lambda x: x[None], make_view(trees_loc))

    with TR.annotate("router.fuse_view"):
        return shard_map(
            body, mesh=mesh,
            in_specs=(P("shards"),),
            out_specs=P("shards"),
            check_rep=False,
        )(trees)


def fused_dispatch(num_shards: int, fn, trees, sid, keys, view=None):
    """Fused-frontier dispatch: one ``fn`` call per *device*, each over
    the base-offset fusion of its co-resident shards (DESIGN.md §8).

    ``fn(trees_loc, lid[K'], keys[K'], view_loc)`` sees the device-local
    stacked (S_loc, ...) arenas, the per-lane local shard index, its
    lanes' keys, and the device-local slice of ``view`` (None when no
    precomputed view was passed — the hook builds it inline), and returns
    ``(lane_outs, shard_outs)`` — pytrees whose leaves carry a leading
    lane axis (K',) resp. per-local-shard axis (S_loc,); ``shard_outs``
    may be None.  ``view`` must come from ``build_fused_view`` over the
    *same* trees (1-device: passed through as-is; D devices: leading (D,)
    axis split across the mesh alongside the arenas).

    On a 1-device mesh the whole batch passes through in batch order —
    no permutation, no dense scatter (the fused path's claim that routing
    needs only ``sid``).  On D devices the batch bucket-sorts by owner
    *device* (stable, so per-device batch order is preserved) into (D, K)
    dense lanes — D×K lanes instead of the vmap dispatch's S×K — padded
    with the born-resolved ROUTE_LEFT sentinel key (pad lanes terminate
    in round 0 and are never gathered).

    Returns (routing | None, lane_outs, shard_outs): lane outputs stay in
    the device-dense layout — map them through ``gather_fused`` with the
    returned routing; shard outputs concatenate to a leading (S,) axis in
    shard order.
    """
    mesh = forest_mesh(num_shards)
    d = mesh.devices.size
    if d == 1:
        with TR.annotate("router.fused"):
            lane, per_shard = fn(trees, sid, keys, view)
        return None, lane, per_shard
    sloc = num_shards // d
    r = route_by(sid // jnp.int32(sloc), d)
    dlid = scatter_dense(r, d, sid % jnp.int32(sloc), jnp.int32(0))
    # ``keys`` may be a pytree of per-lane columns (the scan path sends
    # (starts, his) pairs); every leaf scatters identically, and the pad
    # fill is the born-resolved sentinel either way
    dkeys = jax.tree.map(
        lambda x: scatter_dense(r, d, x, jnp.int32(layout.ROUTE_LEFT)), keys)

    def body(trees_loc, lid_loc, keys_loc, *view_arg):
        # each device's view slice arrives with a leading length-1 device
        # axis (the build's x[None] wrap) — peel it before the hook
        view_loc = (jax.tree.map(lambda x: x[0], view_arg[0])
                    if view_arg else None)
        lane, per_shard = fn(trees_loc, lid_loc[0],
                             jax.tree.map(lambda x: x[0], keys_loc), view_loc)
        # lane leaves regain a leading device axis so shard_map stacks
        # them to (D, K); per-shard leaves concatenate to (S,) directly
        return jax.tree.map(lambda x: x[None], lane), per_shard

    extra = () if view is None else (view,)
    with TR.annotate("router.fused"):
        lane, per_shard = shard_map(
            body, mesh=mesh,
            in_specs=(P("shards"),) * (3 + len(extra)),
            out_specs=P("shards"),
            check_rep=False,
        )(trees, dlid, dkeys, *extra)
    return r, lane, per_shard


def gather_fused(r: Routing | None, lane_outs):
    """Batch-order view of ``fused_dispatch`` lane outputs: the identity
    when no permutation happened (1-device mesh), else the device-dense
    inverse permutation."""
    if r is None:
        return lane_outs
    return jax.tree.map(lambda x: gather_batch(r, x), lane_outs)
