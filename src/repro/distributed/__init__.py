"""DeltaForest — key-range-sharded ΔTree subsystem (DESIGN.md §4).

``__all__`` is the single source of truth for this package's surface
(tests/test_exports.py asserts every name imports).  Types and the
``router`` / ``splits`` submodules are stable; the free-function entry
points are *deprecated shims* for the handle-based Index API:

    from repro.api import make_index
    ix = make_index("forest", initial=keys, num_shards=4, height=7)

Accessing a deprecated name still works (it resolves to
``repro.distributed.forest``) but emits ``DeprecationWarning``.  Internal
code imports ``repro.distributed.forest`` directly and never hits the shim.
"""

import warnings

from repro.distributed import router, splits
from repro.distributed.forest import Forest, ForestConfig

__all__ = [
    "Forest",
    "ForestConfig",
    "alloc_failed",
    "bulk_build",
    "empty",
    "flush",
    "live_items",
    "live_keys",
    "lookup_batch",
    "router",
    "search_batch",
    "shard_tree",
    "splits",
    "successor_jit",
    "update_batch",
]

_DEPRECATED = sorted(set(__all__) - set(globals()))


def __getattr__(name: str):
    if name in _DEPRECATED:
        warnings.warn(
            f"repro.distributed.{name} is deprecated; use the Index API "
            f"(repro.api.make_index('forest', ...)) or import "
            f"repro.distributed.forest.{name} directly",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.distributed import forest

        return getattr(forest, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
