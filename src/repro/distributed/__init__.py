"""DeltaForest — key-range-sharded ΔTree subsystem (DESIGN.md §4).

Public API (drop-in superset of `repro.core`):
    ForestConfig, Forest, empty, bulk_build,
    search_batch, lookup_batch, update_batch, successor_jit,
    live_keys, live_items, alloc_failed, shard_tree,
    splits (partitioner), router (batched cross-shard routing).
"""

from repro.distributed import router, splits
from repro.distributed.forest import (
    Forest,
    ForestConfig,
    alloc_failed,
    bulk_build,
    empty,
    live_items,
    live_keys,
    lookup_batch,
    search_batch,
    shard_tree,
    successor_jit,
    update_batch,
)

__all__ = [
    "Forest",
    "ForestConfig",
    "alloc_failed",
    "bulk_build",
    "empty",
    "live_items",
    "live_keys",
    "lookup_batch",
    "router",
    "search_batch",
    "shard_tree",
    "splits",
    "successor_jit",
    "update_batch",
]
