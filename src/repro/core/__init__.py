"""ΔTree core — the paper's contribution (dynamic vEB layout + concurrent
search tree semantics), implemented as batched functional JAX.

Public API:
    TreeConfig, DeltaTree, empty, bulk_build,
    search_batch, search_jit, update_batch,
    OP_SEARCH, OP_INSERT, OP_DELETE,
    layout (vEB math), live_keys (debug).
"""

from repro.core import layout
from repro.core.deltatree import (
    OP_DELETE,
    lookup_batch,
    lookup_jit,
    live_items,
    OP_INSERT,
    OP_SEARCH,
    DeltaTree,
    TreeConfig,
    bulk_build,
    empty,
    live_keys,
    search_batch,
    search_one,
    successor_jit,
    successor_one,
    search_jit,
    update_batch,
    update_batch_impl,
)

__all__ = [
    "layout",
    "TreeConfig",
    "DeltaTree",
    "empty",
    "bulk_build",
    "live_keys",
    "search_batch",
    "search_one",
    "successor_jit",
    "successor_one",
    "lookup_batch",
    "lookup_jit",
    "live_items",
    "search_jit",
    "update_batch",
    "update_batch_impl",
    "OP_SEARCH",
    "OP_INSERT",
    "OP_DELETE",
]
