"""ΔTree core — the paper's contribution (dynamic vEB layout + concurrent
search tree semantics), implemented as batched functional JAX.

``__all__`` below is the single source of truth for this package's surface
(tests/test_exports.py asserts every name imports).  Types, constants and
the ``layout`` submodule are stable; the free-function entry points are
*deprecated shims* — the supported surface is the handle-based Index API:

    from repro.api import make_index, OpBatch
    ix = make_index("deltatree", initial=keys, height=7)

Accessing a deprecated name still works (it resolves to
``repro.core.deltatree``) but emits ``DeprecationWarning``.  Internal code
imports ``repro.core.deltatree`` directly and never hits the shim.
"""

import warnings

from repro.core import layout
from repro.core.deltatree import (
    OP_DELETE,
    OP_INSERT,
    OP_SEARCH,
    DeltaTree,
    TreeConfig,
)
from repro.core import engine
from repro.core.engine import (
    ForestBatch,
    SearchEngine,
    available_engines,
    get_engine,
    register_engine,
)

__all__ = [
    "layout",
    "engine",
    "ForestBatch",
    "SearchEngine",
    "available_engines",
    "get_engine",
    "register_engine",
    "TreeConfig",
    "DeltaTree",
    "empty",
    "bulk_build",
    "live_keys",
    "search_batch",
    "search_one",
    "successor_batch",
    "successor_jit",
    "successor_one",
    "lookup_batch",
    "lookup_jit",
    "live_items",
    "search_jit",
    "update_batch",
    "update_batch_impl",
    "flush",
    "flush_impl",
    "OP_SEARCH",
    "OP_INSERT",
    "OP_DELETE",
]

# names not bound above resolve lazily through __getattr__ with a warning
_DEPRECATED = sorted(set(__all__) - set(globals()))


def __getattr__(name: str):
    if name in _DEPRECATED:
        warnings.warn(
            f"repro.core.{name} is deprecated; use the Index API "
            f"(repro.api.make_index('deltatree', ...)) or import "
            f"repro.core.deltatree.{name} directly",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.core import deltatree

        return getattr(deltatree, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
