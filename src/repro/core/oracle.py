"""Pure-Python oracle for ΔTree semantics (tests' ground truth).

The ΔTree dictionary semantics (paper §3): a set of keys with INSERT /
DELETE / SEARCH.  Batched step semantics (DESIGN.md §2): searches in a step
observe the pre-step snapshot; updates apply in batch order.
"""

from __future__ import annotations

import numpy as np

OP_SEARCH, OP_INSERT, OP_DELETE = 0, 1, 2


class SetOracle:
    def __init__(self, initial=()):
        self.s = set(int(x) for x in initial)

    def snapshot_search(self, keys) -> np.ndarray:
        snap = frozenset(self.s)
        return np.asarray([int(k) in snap for k in keys], dtype=bool)

    def apply_updates(self, kinds, keys) -> np.ndarray:
        out = np.zeros(len(keys), dtype=bool)
        for i, (k, v) in enumerate(zip(kinds, keys)):
            v = int(v)
            if k == OP_INSERT:
                out[i] = v not in self.s
                self.s.add(v)
            elif k == OP_DELETE:
                out[i] = v in self.s
                self.s.discard(v)
        return out

    def keys(self) -> np.ndarray:
        return np.asarray(sorted(self.s), dtype=np.int32)


class MapOracle:
    """key -> payload dictionary oracle (ΔTree map mode)."""

    def __init__(self, initial=()):
        self.d = {int(k): int(p) for k, p in initial}

    def snapshot_lookup(self, keys):
        snap = dict(self.d)
        found = np.asarray([int(k) in snap for k in keys], dtype=bool)
        pay = np.asarray([snap.get(int(k), -1) for k in keys], dtype=np.int32)
        return found, pay

    def apply_updates(self, kinds, keys, payloads) -> np.ndarray:
        out = np.zeros(len(keys), dtype=bool)
        for i, (k, v, p) in enumerate(zip(kinds, keys, payloads)):
            v, p = int(v), int(p)
            if k == OP_INSERT:
                out[i] = v not in self.d
                if out[i]:
                    self.d[v] = p
            elif k == OP_DELETE:
                out[i] = v in self.d
                self.d.pop(v, None)
        return out

    def items(self):
        return sorted(self.d.items())
