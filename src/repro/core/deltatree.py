"""ΔTree — locality-aware concurrent search tree (paper §3–4), batched for SPMD.

Semantics map (paper → this implementation; DESIGN.md §2 has the rationale):

- *wait-free Search* → searches in a step read the immutable pre-step
  snapshot; `search_batch` is fully vectorized (vmap) and touches no locks —
  trivially wait-free, linearized at the step boundary.
- *non-blocking Insert/Delete (CAS leaf-grow / mark-delete)* → a batch of K
  update ops is applied in deterministic batch order (the linearization
  order).  A grow-leaf is the paper's Fig. 9 CAS pair; a delete is the
  paper's mark-CAS (Fig. 9 line 18).
- *buffer + TAS lock + mirror* (paper §3, Fig. 9 lines 87..106) → inserts
  that reach a full bottom leaf append to the ΔNode's overflow ``buf``
  (the paper's ``rootbuffer``); the maintenance sweep (the "lock winner")
  drains buffers by Rebalance (rebuild into a functional mirror and swap —
  here: a pure-functional array update) or Expand (allocate child ΔNodes).
- *Merge* (paper Fig. 10) → a sparse childless ΔNode is unioned with its
  sibling subtree and the parent router is set to ``ROUTE_LEFT`` — the
  implicit-layout equivalent of the paper's grandparent-pointer splice.

Layout: each ΔNode stores a complete binary tree of height ``H`` in vEB
order (``layout.veb_pos_table``); the tree of ΔNodes is linked by int32
indices into a pre-allocated arena (the "dynamic vEB layout", paper §2.3).
Only bottom-row positions may carry child links.  Leaf-oriented BST routing:
``v < router ⇒ left`` where router = min of the right subtree.

MAP MODE (beyond-paper extension; used by the serving pager): with
``payload_bits > 0`` each stored "value" is an int64 ``key << bits |
payload``.  Ordering by packed value equals ordering by key, so routing is
unchanged; *queries* are packed with all-ones payload so that a query for
key k compares ``>=`` any stored packing of k (min-of-right-subtree routers
stay correct).  Equality tests compare ``key_of`` only.  With
``payload_bits == 0`` everything is int32 and byte-identical to the paper's
set semantics.

Occupancy invariants (checked by ``check_invariants`` in
tests/test_deltatree.py):
  I1. value==EMPTY ⇔ slot unoccupied; internal node ⇔ left child occupied.
  I2. an occupied odd position implies its even sibling is occupied.
  I3. child links only at bottom positions whose value is non-EMPTY
      (the value is a cosmetic marker; routing hops unconditionally).
  I4. in-order traversal of live leaves is strictly sorted and consistent
      with every router on the path.
  I5. under the default ``maintenance="eager"`` policy, after
      `update_batch` returns every buffer is empty (maintenance ran to
      fixpoint).  Non-eager policies (``repro.maintenance``) relax this to
  I5'. every buffered value's root descent lands in the ΔNode whose buffer
      holds it — which is what keeps `searchnode`'s final-ΔNode buffer
      probe (and hence every wait-free read) correct over pending items;
      `flush` restores I5.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core.layout import EMPTY, ROUTE_LEFT

NONE = jnp.int32(-1)


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Static ΔTree parameters (hashable; closed over by jitted fns).

    height:       H; a ΔNode holds UB = 2**H - 1 slots (paper's UB).
    max_dnodes:   arena capacity M.
    buf_cap:      per-ΔNode overflow buffer length (paper: #threads).
    max_rounds:   safety bound on maintenance rounds per step.
    payload_bits: 0 = paper set semantics (int32); >0 = key→payload map
                  (int64 packed values, payload in the low bits).
    engine:       which registered SearchEngine serves the read path —
                  "scalar" (vmap-of-while_loop reference) or "lockstep"
                  (Pallas vEB walk kernel in frontier rounds); see
                  ``repro.core.engine``.  The lockstep engine also routes
                  the update path's position-finding through the kernel
                  (one frontier pass per round).  ``make_index`` callers
                  may pass ``engine="auto"``, which resolves to the
                  bench-table winner for the backend + execution mode
                  (``core.engine.resolve_engine``) before this config is
                  built — a constructed TreeConfig always names a real
                  registered engine.
    walk_fused:   lockstep walk driver: True (default) = the fused
                  single-launch walk (`kernels.ops.delta_walk_fused` —
                  all rounds inside one kernel/program); False = the
                  per-round pallas_call-in-while_loop driver (parity
                  oracle / VMEM-overflow fallback).  Bit-identical
                  results either way.
    walk_rounds:  walk round cap; 0 (default) derives it from the arena
                  geometry at trace time (`kernels.ops.walk_round_cap`)
                  instead of the historical fixed 64 — see the
                  ``walk_round_cap`` property.
    maintenance:  maintenance policy string — "eager" (drain to fixpoint
                  inside every update step; the paper/default semantics),
                  "deferred" (maintenance only on ``flush``), or
                  "budgeted:K" (at most K ΔNode repairs per batch); see
                  ``repro.maintenance``.
    q_tile:       lockstep kernel query tile; 0 = auto (the
                  ``REPRO_PALLAS_QTILE`` env override, else 256).
    collect_stats: observability flag (``repro.obs``): stats-capable
                  reads (search/lookup, forest reads) return a trailing
                  ``ReadStats`` counter pytree.  Static, so the disabled
                  path traces exactly the pre-obs graph — byte-identical
                  lowered HLO (asserted by tests/test_obs.py).
    collect_transfers: sub-gate under ``collect_stats``: additionally
                  derive measured ideal-cache ``TransferStats``
                  (``repro.obs.transfers`` — distinct ΔNode visits and
                  distinct B-block touches per read batch) into
                  ``ReadStats.transfers``.  Separate knob because the
                  device-side descent replay costs real work per batch;
                  off (None leg) it adds nothing to the collect_stats
                  graph, and with collect_stats off the whole read path
                  still lowers byte-identical to the pre-obs graph.
    """

    height: int = 7           # UB = 127, the paper's best (page-sized) ΔNode
    max_dnodes: int = 1024
    buf_cap: int = 32
    max_rounds: int = 64
    payload_bits: int = 0
    parallel_updates: bool = True   # vectorized non-conflicting fast path
    engine: str = "scalar"    # read-path SearchEngine (core.engine registry)
    maintenance: str = "eager"  # scheduler policy (repro.maintenance)
    q_tile: int = 0           # lockstep kernel tile (0 = env/autotune)
    collect_stats: bool = False  # reads return ReadStats (repro.obs)
    collect_transfers: bool = False  # + measured TransferStats sub-gate
    walk_fused: bool = True   # fused single-launch walk driver
    walk_rounds: int = 0      # walk round cap (0 = derive from geometry)

    @property
    def walk_round_cap(self) -> int:
        """Round cap the lockstep walk traces with: the ``walk_rounds``
        override, else derived from (height, max_dnodes) — tight enough
        that compiled fused kernels carry no dead iterations, with the
        structural depth assertion in ``check_invariants`` pinning it."""
        if self.walk_rounds:
            return self.walk_rounds
        from repro.kernels.ops import walk_round_cap

        return walk_round_cap(self.height, self.max_dnodes)

    @property
    def maintenance_policy(self):
        """Parsed ``MaintenancePolicy`` (raises ValueError on a bad spec)."""
        from repro.maintenance.policy import parse_policy

        return parse_policy(self.maintenance)

    @property
    def ub(self) -> int:
        return 2**self.height - 1

    @property
    def leaf_cap(self) -> int:
        return 2 ** (self.height - 1)

    @property
    def bottom0(self) -> int:
        return 2 ** (self.height - 1)

    @property
    def half_cap(self) -> int:
        return self.leaf_cap // 2

    # ---- packing helpers (identity in set mode) ----

    @property
    def vdtype(self):
        return jnp.int64 if self.payload_bits else jnp.int32

    @property
    def pmask(self) -> int:
        return (1 << self.payload_bits) - 1

    @property
    def route_left(self):
        if self.payload_bits:
            return jnp.int64(1) << 62
        return jnp.int32(ROUTE_LEFT)

    def pack(self, key, payload):
        if not self.payload_bits:
            return jnp.asarray(key, jnp.int32)
        return (jnp.asarray(key, jnp.int64) << self.payload_bits) | (
            jnp.asarray(payload, jnp.int64) & self.pmask
        )

    def qpack(self, key):
        """Query packing: all-ones payload so q >= any stored pack of key."""
        if not self.payload_bits:
            return jnp.asarray(key, jnp.int32)
        return (jnp.asarray(key, jnp.int64) << self.payload_bits) | self.pmask

    def key_of(self, x):
        if not self.payload_bits:
            return x
        return (x >> self.payload_bits).astype(jnp.int32)

    def payload_of(self, x):
        if not self.payload_bits:
            return jnp.zeros_like(x)
        return (x & self.pmask).astype(jnp.int32)


class DeltaTree(NamedTuple):
    """Arena-of-ΔNodes pytree. All arrays are per-ΔNode rows."""

    value: jax.Array      # (M, UB) packed values, vEB storage order
    mark: jax.Array       # (M, UB) bool — logical deletion (paper Fig. 9 l.18)
    child: jax.Array      # (M, leaf_cap) int32 child ΔNode id per bottom slot, -1 = none
    buf: jax.Array        # (M, buf_cap) packed overflow buffer (paper rootbuffer)
    nlive: jax.Array      # (M,) live (unmarked, non-marker) leaves
    bcount: jax.Array     # (M,) occupied buffer entries
    nchild: jax.Array     # (M,) number of child links
    parent: jax.Array     # (M,) parent ΔNode id (-1 root)
    pslot: jax.Array      # (M,) bottom slot index within parent
    alive: jax.Array      # (M,) bool allocated
    free_stack: jax.Array # (M,) int32 freelist
    free_top: jax.Array   # () int32 number of free ids on the stack
    root: jax.Array       # () int32 root ΔNode id
    ins_flag: jax.Array   # (M,) bool needs insert-side maintenance
    del_flag: jax.Array   # (M,) bool merge candidate
    alloc_fail: jax.Array # () bool arena exhausted at some point (sticky)


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------


def empty(cfg: TreeConfig) -> DeltaTree:
    m, ub, lc, bc = cfg.max_dnodes, cfg.ub, cfg.leaf_cap, cfg.buf_cap
    # free stack holds ids M-1 .. 1 (0 is the root, pre-allocated).
    free = np.zeros(m, dtype=np.int32)
    free[: m - 1] = np.arange(m - 1, 0, -1, dtype=np.int32)
    alive = np.zeros(m, dtype=bool)
    alive[0] = True
    return DeltaTree(
        value=jnp.full((m, ub), EMPTY, cfg.vdtype),
        mark=jnp.zeros((m, ub), jnp.bool_),
        child=jnp.full((m, lc), -1, jnp.int32),
        buf=jnp.full((m, bc), EMPTY, cfg.vdtype),
        nlive=jnp.zeros((m,), jnp.int32),
        bcount=jnp.zeros((m,), jnp.int32),
        nchild=jnp.zeros((m,), jnp.int32),
        parent=jnp.full((m,), -1, jnp.int32),
        pslot=jnp.zeros((m,), jnp.int32),
        alive=jnp.asarray(alive),
        free_stack=jnp.asarray(free),
        free_top=jnp.int32(m - 1),
        root=jnp.int32(0),
        ins_flag=jnp.zeros((m,), jnp.bool_),
        del_flag=jnp.zeros((m,), jnp.bool_),
        alloc_fail=jnp.bool_(False),
    )


def _pos(cfg: TreeConfig) -> jnp.ndarray:
    return jnp.asarray(layout.veb_pos_table(cfg.height))


# --------------------------------------------------------------------------
# descend — the memory-transfer path (paper Fig. 8 / Lemma 2.1)
# --------------------------------------------------------------------------


def _descend(cfg: TreeConfig, t: DeltaTree, q, dn0, b0):
    """Walk from (dn0, b0) to the leaf position that owns packed query ``q``.

    Returns (dn, b, hops): ``hops`` counts ΔNode boundary crossings — in the
    relaxed-CO model each hop is O(1) block transfers (Lemma 2.1), so hops is
    the exact transfer-count statistic reported by the benchmarks.
    """
    pos = _pos(cfg)
    bottom0 = cfg.bottom0

    def cond(s):
        return ~s[2]

    def body(s):
        dn, b, _, hops = s
        router = t.value[dn, pos[b]]
        at_bottom = b >= bottom0
        left_val = jnp.where(
            at_bottom, jnp.zeros((), cfg.vdtype),
            t.value[dn, pos[jnp.minimum(2 * b, 2 * bottom0 - 1)]],
        )
        internal = (~at_bottom) & (left_val != EMPTY)
        slot = jnp.where(at_bottom, b - bottom0, 0)
        ch = jnp.where(at_bottom, t.child[dn, slot], NONE)
        hop = at_bottom & (ch >= 0)
        b_next = jnp.where(internal, 2 * b + (q >= router).astype(jnp.int32), b)
        b_next = jnp.where(hop, jnp.int32(1), b_next)
        dn_next = jnp.where(hop, ch, dn)
        done = (~internal) & (~hop)
        return dn_next, b_next, done, hops + hop.astype(jnp.int32)

    dn, b, _, hops = jax.lax.while_loop(
        cond, body, (jnp.int32(dn0), jnp.int32(b0), jnp.bool_(False), jnp.int32(1))
    )
    return dn, b, hops


# --------------------------------------------------------------------------
# Search — wait-free (paper Fig. 8, Lemma 4.1/4.2)
# --------------------------------------------------------------------------


def searchnode(cfg: TreeConfig, t: DeltaTree, keys, leaf_val, leaf_b, dn):
    """Paper SEARCHNODE resolution (Fig. 8 lines 9..17) at the walk's
    final position: leaf match & ~mark, else overflow-buffer membership;
    payload from the matching leaf or buffer slot.

    Shape-polymorphic over scalar ``()`` or batched ``(K,)`` queries, and
    the single source of truth both SearchEngines resolve through — the
    scalar engine per lane (via `search_one`), the lockstep engine on the
    kernel walk's outputs — so the bit-for-bit parity the conformance
    suite asserts cannot drift.  Returns (found, payload | -1).
    """
    pos = _pos(cfg)
    keys = jnp.asarray(keys)
    leaf_hit = (leaf_val != EMPTY) & (cfg.key_of(leaf_val) == keys)
    leaf_found = leaf_hit & ~t.mark[dn, pos[leaf_b]]
    brow = t.buf[dn]                           # (..., buf_cap)
    bhit = (brow != EMPTY) & (cfg.key_of(brow) == keys[..., None])
    in_buf = jnp.any(bhit, axis=-1)
    bsel = jnp.take_along_axis(
        brow, jnp.argmax(bhit, axis=-1)[..., None], axis=-1)[..., 0]
    found = jnp.where(leaf_hit, leaf_found, in_buf)
    payload = jnp.where(leaf_hit, cfg.payload_of(leaf_val),
                        cfg.payload_of(bsel))
    return found, jnp.where(found, payload, -1)


def search_one(cfg: TreeConfig, t: DeltaTree, key):
    """Returns (found: bool, payload: int32, hops: int32)."""
    pos = _pos(cfg)
    q = cfg.qpack(key)
    dn, b, hops = _descend(cfg, t, q, t.root, 1)
    found, payload = searchnode(cfg, t, key, t.value[dn, pos[b]], b, dn)
    return found, payload, hops


def search_batch(cfg: TreeConfig, t: DeltaTree, keys: jax.Array):
    """Vectorized wait-free search via ``cfg.engine``. (found[K], hops[K])."""
    from repro.core import engine as E  # deferred: engine imports this module

    return E.search(cfg, t, keys)


def lookup_batch(cfg: TreeConfig, t: DeltaTree, keys: jax.Array):
    """Map-mode search via ``cfg.engine``: (found[K], payload[K], hops[K])."""
    from repro.core import engine as E  # deferred: engine imports this module

    return E.lookup(cfg, t, keys)


# --------------------------------------------------------------------------
# allocation helpers
# --------------------------------------------------------------------------


def _alloc(cfg: TreeConfig, t: DeltaTree):
    """Pop a ΔNode id off the freelist. Returns (t, cid). Sticky-fails when
    exhausted (cid = root is returned but alloc_fail is set; tests assert)."""
    ok = t.free_top > 0
    top = jnp.maximum(t.free_top - 1, 0)
    cid = t.free_stack[top]
    t = t._replace(
        free_top=jnp.where(ok, top, t.free_top),
        alive=t.alive.at[cid].set(True),
        alloc_fail=t.alloc_fail | ~ok,
    )
    return t, cid


def _free(cfg: TreeConfig, t: DeltaTree, dn):
    t = t._replace(
        value=t.value.at[dn].set(EMPTY),
        mark=t.mark.at[dn].set(False),
        child=t.child.at[dn].set(-1),
        buf=t.buf.at[dn].set(EMPTY),
        nlive=t.nlive.at[dn].set(0),
        bcount=t.bcount.at[dn].set(0),
        nchild=t.nchild.at[dn].set(0),
        parent=t.parent.at[dn].set(-1),
        pslot=t.pslot.at[dn].set(0),
        alive=t.alive.at[dn].set(False),
        ins_flag=t.ins_flag.at[dn].set(False),
        del_flag=t.del_flag.at[dn].set(False),
        free_stack=t.free_stack.at[t.free_top].set(dn),
        free_top=t.free_top + jnp.int32(1),
    )
    return t


# --------------------------------------------------------------------------
# ΔNode rebuild (Rebalance core, paper Fig. 10 BALANCETREE)
# --------------------------------------------------------------------------


def _rebuild_row(cfg: TreeConfig, sorted_vals: jax.Array, m: jax.Array,
                 force_bottom: bool = False) -> jax.Array:
    """Build a (UB,) vEB-order value row holding the first ``m`` entries of
    ``sorted_vals`` (packed) as a complete leaf-oriented BST at minimal leaf
    depth (or pinned to the bottom row for ΔNodes carrying child links)."""
    h = cfg.height
    tabs = layout.rebuild_tables(h)
    pos = _pos(cfg)
    mm = jnp.maximum(m, 1)
    d = jnp.ceil(jnp.log2(mm.astype(jnp.float32))).astype(jnp.int32)
    d = jnp.clip(d, 0, h - 1)
    if force_bottom:
        d = jnp.int32(h - 1)
    kind = jnp.asarray(tabs["kind"])[d]            # (2**h,)
    start = jnp.asarray(tabs["range_start"])[d]
    mid = jnp.asarray(tabs["range_mid"])[d]
    pad = jnp.full((1,), EMPTY, cfg.vdtype)
    xv = jnp.concatenate([sorted_vals.astype(cfg.vdtype), pad])
    cap = xv.shape[0] - 1
    empty_v = jnp.zeros((), cfg.vdtype)
    leaf = jnp.where(start < m, xv[jnp.clip(start, 0, cap)], empty_v)
    router = jnp.where(
        start >= m, empty_v,
        jnp.where(mid < m, xv[jnp.clip(mid, 0, cap)], cfg.route_left),
    )
    vals_b = jnp.where(kind == 1, leaf, jnp.where(kind == 2, router, empty_v))
    vals_b = jnp.where(m == 0, jnp.full_like(vals_b, EMPTY), vals_b)
    row = jnp.zeros((cfg.ub,), cfg.vdtype)
    return row.at[pos[1:]].set(vals_b[1:])


def _gather_live(cfg: TreeConfig, t: DeltaTree, dn):
    """Sorted live packed values of ΔNode ``dn`` (own leaves + buffer;
    child-link markers excluded).  Returns (sorted[UB+buf_cap] ascending
    with ROUTE_LEFT padding at the end, count)."""
    pos = _pos(cfg)
    h, bottom0 = cfg.height, cfg.bottom0
    bfs = jnp.arange(1, 2**h, dtype=jnp.int32)
    vals = t.value[dn, pos[bfs]]
    marks = t.mark[dn, pos[bfs]]
    at_bottom = bfs >= bottom0
    left = jnp.where(
        at_bottom, jnp.zeros((), cfg.vdtype),
        t.value[dn, pos[jnp.minimum(2 * bfs, 2 * bottom0 - 1)]],
    )
    is_leaf = at_bottom | (left == EMPTY)
    slot = jnp.where(at_bottom, bfs - bottom0, 0)
    is_marker = at_bottom & (t.child[dn, slot] >= 0)
    live = is_leaf & (vals != EMPTY) & ~marks & ~is_marker
    keep = jnp.where(live, vals, cfg.route_left)  # push pads to the end
    bkeep = jnp.where(t.buf[dn] != EMPTY, t.buf[dn], cfg.route_left)
    allv = jnp.sort(jnp.concatenate([keep, bkeep]))
    count = (jnp.sum(live.astype(jnp.int32)) + t.bcount[dn]).astype(jnp.int32)
    return allv, count


def _rebalance(cfg: TreeConfig, t: DeltaTree, dn) -> DeltaTree:
    """Paper REBALANCE: rebuild ``dn``'s (childless) tree at minimal height
    from its live leaves + buffer; functional mirror-swap."""
    allv, m = _gather_live(cfg, t, dn)
    row = _rebuild_row(cfg, allv, m)
    return t._replace(
        value=t.value.at[dn].set(row),
        mark=t.mark.at[dn].set(False),
        buf=t.buf.at[dn].set(EMPTY),
        nlive=t.nlive.at[dn].set(m),
        bcount=t.bcount.at[dn].set(0),
        ins_flag=t.ins_flag.at[dn].set(False),
    )


# --------------------------------------------------------------------------
# single-op primitives (paper Fig. 9) — applied in batch order
# --------------------------------------------------------------------------


def _buf_append(cfg: TreeConfig, t: DeltaTree, dn, pv):
    """Append packed value to dn's buffer (paper Fig. 9 line 89)."""
    slot_free = t.buf[dn] == EMPTY
    ok = jnp.any(slot_free)
    j = jnp.argmax(slot_free)
    t = t._replace(
        buf=t.buf.at[dn, j].set(jnp.where(ok, pv, t.buf[dn, j])),
        bcount=t.bcount.at[dn].add(jnp.where(ok, jnp.int32(1), jnp.int32(0))),
        ins_flag=t.ins_flag.at[dn].set(jnp.where(ok, True, t.ins_flag[dn])),
    )
    return t, ok


def _grow_leaf(cfg: TreeConfig, t: DeltaTree, dn, b, pv):
    """Paper Fig. 9 lines 50..72: leaf x grows into internal(router=max) with
    leaves (min, max). Preserves x's mark on x's new position."""
    pos = _pos(cfg)
    x = t.value[dn, pos[b]]
    xm = t.mark[dn, pos[b]]
    v_lt = cfg.key_of(pv) < cfg.key_of(x)
    lo = jnp.where(v_lt, pv, x)
    hi = jnp.where(v_lt, x, pv)
    x_is_lo = v_lt  # x is hi iff new value is smaller
    lpos, rpos = pos[2 * b], pos[2 * b + 1]
    t = t._replace(
        value=t.value.at[dn, lpos].set(lo).at[dn, rpos].set(hi)
        .at[dn, pos[b]].set(hi),
        mark=(
            t.mark.at[dn, lpos].set(jnp.where(x_is_lo, False, xm))
            .at[dn, rpos].set(jnp.where(x_is_lo, xm, False))
            .at[dn, pos[b]].set(False)
        ),
        nlive=t.nlive.at[dn].add(jnp.int32(1)),
    )
    return t


def _insert_op(cfg: TreeConfig, t: DeltaTree, key, payload,
               dn0=None, b0=None):
    """One INSERTNODE in batch order. Returns (t, success, pending).

    ``(dn0, b0)`` is an optional descent hint — a position known to be on
    the key's root descent path (the lockstep update path passes the
    round-start frontier position; within an op phase structure only grows
    downward, so descending from the hint reaches the true endpoint)."""
    pos = _pos(cfg)
    q = cfg.qpack(key)
    pv = cfg.pack(key, payload)
    if dn0 is None:
        dn0, b0 = t.root, 1
    dn, b, _ = _descend(cfg, t, q, dn0, b0)
    leaf_val = t.value[dn, pos[b]]
    leaf_mark = t.mark[dn, pos[b]]
    leaf_hit = (leaf_val != EMPTY) & (cfg.key_of(leaf_val) == key)
    in_buf = jnp.any((t.buf[dn] != EMPTY) & (cfg.key_of(t.buf[dn]) == key))

    def case_dup(t):  # leaf holds key: revive if deleted (payload refreshed)
        tt = t._replace(
            value=t.value.at[dn, pos[b]].set(
                jnp.where(leaf_mark, pv, leaf_val)),
            mark=t.mark.at[dn, pos[b]].set(False),
            nlive=t.nlive.at[dn].add(jnp.where(leaf_mark, jnp.int32(1), jnp.int32(0))),
        )
        return tt, leaf_mark, jnp.bool_(False)

    def case_place(t):  # unoccupied leaf position (incl. empty root)
        tt = t._replace(
            value=t.value.at[dn, pos[b]].set(pv),
            mark=t.mark.at[dn, pos[b]].set(False),
            nlive=t.nlive.at[dn].add(jnp.int32(1)),
        )
        return tt, jnp.bool_(True), jnp.bool_(False)

    def case_grow(t):
        return _grow_leaf(cfg, t, dn, b, pv), jnp.bool_(True), jnp.bool_(False)

    def case_buffer(t):
        def dup(t):
            return t, jnp.bool_(False), jnp.bool_(False)

        def app(t):
            tt, ok = _buf_append(cfg, t, dn, pv)
            # buffer full -> op stays pending, retried after maintenance
            return tt, ok, ~ok

        return jax.lax.cond(in_buf, dup, app, t)

    # a key resident in this ΔNode's buffer routes to case_buffer (dup)
    # whatever leaf kind the descent ended on — under I5' carried items
    # may surface at non-bottom or EMPTY leaves of an Expanded child
    branch = jnp.where(
        leaf_hit, 0,
        jnp.where(in_buf, 3,
                  jnp.where(leaf_val == EMPTY, 1,
                            jnp.where(b < cfg.bottom0, 2, 3))),
    )
    return jax.lax.switch(branch, [case_dup, case_place, case_grow, case_buffer], t)


def _delete_op(cfg: TreeConfig, t: DeltaTree, key, dn0=None, b0=None):
    """One DELETENODE in batch order (mark-delete, paper Fig. 9 l.18).
    ``(dn0, b0)`` is an optional descent hint, as in `_insert_op`."""
    pos = _pos(cfg)
    q = cfg.qpack(key)
    if dn0 is None:
        dn0, b0 = t.root, 1
    dn, b, _ = _descend(cfg, t, q, dn0, b0)
    leaf_val = t.value[dn, pos[b]]
    leaf_mark = t.mark[dn, pos[b]]
    leaf_hit = (leaf_val != EMPTY) & (cfg.key_of(leaf_val) == key)

    def case_leaf(t):
        ok = ~leaf_mark
        nl = t.nlive[dn] - jnp.where(ok, jnp.int32(1), jnp.int32(0))
        tt = t._replace(
            mark=t.mark.at[dn, pos[b]].set(True),
            nlive=t.nlive.at[dn].set(nl),
            del_flag=t.del_flag.at[dn].set(
                t.del_flag[dn] | (ok & (nl < cfg.half_cap // 2))
            ),
        )
        return tt, ok, jnp.bool_(False)

    def case_buf(t):
        hit = (t.buf[dn] != EMPTY) & (cfg.key_of(t.buf[dn]) == key)
        ok = jnp.any(hit)
        j = jnp.argmax(hit)
        tt = t._replace(
            buf=t.buf.at[dn, j].set(
                jnp.where(ok, jnp.zeros((), cfg.vdtype), t.buf[dn, j])),
            bcount=t.bcount.at[dn].add(jnp.where(ok, jnp.int32(-1), jnp.int32(0))),
        )
        return tt, ok, jnp.bool_(False)

    return jax.lax.cond(leaf_hit, case_leaf, case_buf, t)


# --------------------------------------------------------------------------
# maintenance — Rebalance / Expand (paper Fig. 9 lines 92..106)
# --------------------------------------------------------------------------


def _process_ins(cfg: TreeConfig, t: DeltaTree, dn):
    """Insert-side repair of ΔNode ``dn`` (Rebalance or Expand).  Returns
    (t, rebuilds, expands) — the int32 deltas feed ``MaintenanceStats``
    (expands counts child ΔNodes allocated)."""
    dn = jnp.asarray(dn, jnp.int32)
    pos = _pos(cfg)
    total = t.nlive[dn] + t.bcount[dn]
    childless_small = (t.nchild[dn] == 0) & (total <= cfg.half_cap)

    def do_rebalance(t):
        return _rebalance(cfg, t, dn), jnp.int32(1), jnp.int32(0)

    def do_expand(t):
        # Route every buffered value one hop toward its home: place/grow in
        # this ΔNode, move into a child's buffer, or EXPAND a full bottom
        # leaf into a fresh child ΔNode (paper Fig. 5b) and move into it.
        def body(i, t):
            pv = t.buf[dn, i]
            key = cfg.key_of(pv)
            qv = cfg.qpack(key)

            def handle(t):
                # drop from this buffer first; re-add below if it must stay
                t = t._replace(
                    buf=t.buf.at[dn, i].set(EMPTY),
                    bcount=t.bcount.at[dn].add(-1),
                )
                tdn, b, _ = _descend(cfg, t, qv, dn, 1)
                leaf_val = t.value[tdn, pos[b]]
                leaf_mark = t.mark[tdn, pos[b]]
                leaf_hit = (leaf_val != EMPTY) & (cfg.key_of(leaf_val) == key)

                def moved(t):  # landed in a descendant ΔNode -> its buffer
                    tt, ok = _buf_append(cfg, t, tdn, pv)

                    def keep(tt):
                        tt2, _ = _buf_append(cfg, tt, dn, pv)
                        return tt2

                    return jax.lax.cond(ok, lambda x: x, keep, tt)

                def local(t):
                    def dup(t):
                        return t._replace(
                            value=t.value.at[tdn, pos[b]].set(
                                jnp.where(leaf_mark, pv, leaf_val)),
                            mark=t.mark.at[tdn, pos[b]].set(False),
                            nlive=t.nlive.at[tdn].add(
                                jnp.where(leaf_mark, jnp.int32(1), jnp.int32(0))),
                        )

                    def place(t):
                        return t._replace(
                            value=t.value.at[tdn, pos[b]].set(pv),
                            mark=t.mark.at[tdn, pos[b]].set(False),
                            nlive=t.nlive.at[tdn].add(jnp.int32(1)),
                        )

                    def grow(t):
                        return _grow_leaf(cfg, t, tdn, b, pv)

                    def expand(t):
                        # occupied childless bottom leaf: allocate child
                        # seeded with the leaf's live value; pv moves into
                        # the child's (empty) buffer. Leaf stays as marker.
                        slot = b - cfg.bottom0
                        t, cid = _alloc(cfg, t)
                        x_live = ~leaf_mark
                        seed = jnp.where(x_live, leaf_val, cfg.route_left)
                        mseed = x_live.astype(jnp.int32)
                        row = _rebuild_row(
                            cfg, jnp.full((1,), seed, cfg.vdtype), mseed)
                        t = t._replace(
                            value=t.value.at[cid].set(row),
                            nlive=t.nlive.at[cid].set(mseed).at[tdn].add(-mseed),
                            parent=t.parent.at[cid].set(tdn),
                            pslot=t.pslot.at[cid].set(slot),
                            child=t.child.at[tdn, slot].set(cid),
                            nchild=t.nchild.at[tdn].add(jnp.int32(1)),
                            mark=t.mark.at[tdn, pos[b]].set(False),
                        )
                        t, _ = _buf_append(cfg, t, cid, pv)
                        return t

                    branch = jnp.where(
                        leaf_hit, 0,
                        jnp.where(
                            leaf_val == EMPTY, 1,
                            jnp.where(b < cfg.bottom0, 2, 3)),
                    )
                    return jax.lax.switch(branch, [dup, place, grow, expand], t)

                return jax.lax.cond(tdn != dn, moved, local, t)

            return jax.lax.cond(pv == EMPTY, lambda t: t, handle, t)

        ft0 = t.free_top
        t = jax.lax.fori_loop(0, cfg.buf_cap, body, t)
        t = t._replace(ins_flag=t.ins_flag.at[dn].set(t.bcount[dn] > 0))
        return t, jnp.int32(0), (ft0 - t.free_top).astype(jnp.int32)

    return jax.lax.cond(childless_small, do_rebalance, do_expand, t)


# --------------------------------------------------------------------------
# maintenance — Merge (paper Fig. 10 MERGETREE)
# --------------------------------------------------------------------------


def _process_del(cfg: TreeConfig, t: DeltaTree, dn):
    """Delete-side repair of ΔNode ``dn`` (Merge).  Returns (t, merged) —
    the int32 delta feeds ``MaintenanceStats``."""
    dn = jnp.asarray(dn, jnp.int32)
    pos = _pos(cfg)
    t = t._replace(del_flag=t.del_flag.at[dn].set(False))
    p = t.parent[dn]
    eligible = (
        t.alive[dn]
        & (p >= 0)
        & (t.nchild[dn] == 0)
        & (t.bcount[dn] == 0)
        & (t.nlive[dn] < cfg.half_cap)
    )

    def merge(t):
        s = t.pslot[dn]
        sib = s ^ 1
        even = s & ~1
        b_dn = cfg.bottom0 + s        # dn's slot, BFS in parent
        b_sib = cfg.bottom0 + sib
        b_par = b_dn // 2             # the depth H-2 router node
        sib_child = t.child[p, sib]
        sib_leaf_val = t.value[p, pos[b_sib]]
        sib_leaf_mark = t.mark[p, pos[b_sib]]
        sib_is_child = sib_child >= 0
        sib_ok = jnp.where(
            sib_is_child,
            (t.nchild[jnp.maximum(sib_child, 0)] == 0)
            & (t.bcount[jnp.maximum(sib_child, 0)] == 0),
            jnp.bool_(True),
        )
        my_vals, my_m = _gather_live(cfg, t, dn)
        sib_vals, sib_m = jax.lax.cond(
            sib_is_child,
            lambda: _gather_live(cfg, t, jnp.maximum(sib_child, 0)),
            lambda: (
                jnp.full_like(my_vals, cfg.route_left).at[0].set(
                    jnp.where(
                        (sib_leaf_val != EMPTY) & ~sib_leaf_mark,
                        sib_leaf_val,
                        cfg.route_left,
                    )
                ),
                ((sib_leaf_val != EMPTY) & ~sib_leaf_mark).astype(jnp.int32),
            ),
        )
        total = my_m + sib_m
        fits = sib_ok & (total <= cfg.half_cap)

        def do(t):
            union = jnp.sort(jnp.concatenate([my_vals, sib_vals]))
            row = _rebuild_row(cfg, union, total)
            # dn becomes the merged ΔNode, re-hung at the even slot; the odd
            # slot is cleared and the router re-set to ROUTE_LEFT — the
            # implicit-layout version of the paper's pointer splice.
            t = t._replace(
                value=t.value.at[dn].set(row),
                mark=t.mark.at[dn].set(False),
                nlive=t.nlive.at[dn].set(total),
            )
            free_sib = sib_is_child
            t = jax.lax.cond(
                free_sib,
                lambda t: _free(cfg, t, jnp.maximum(sib_child, 0)),
                lambda t: t,
                t,
            )
            b_even = cfg.bottom0 + even
            b_odd = b_even + 1
            marker = jnp.where(total > 0, union[0], jnp.ones((), cfg.vdtype))
            t = t._replace(
                child=t.child.at[p, even].set(dn).at[p, even ^ 1].set(-1),
                nchild=t.nchild.at[p].add(jnp.where(sib_is_child, jnp.int32(-1), jnp.int32(0))),
                pslot=t.pslot.at[dn].set(even),
                value=(
                    t.value.at[p, pos[b_even]].set(marker)
                    .at[p, pos[b_odd]].set(EMPTY)
                    .at[p, pos[b_par]].set(cfg.route_left)
                ),
                mark=t.mark.at[p, pos[b_even]].set(False)
                .at[p, pos[b_odd]].set(False),
                # a live sibling leaf value was absorbed downward
                nlive=t.nlive.at[p].add(-sib_m * (~sib_is_child).astype(jnp.int32)),
            )
            return t, jnp.int32(1)

        return jax.lax.cond(fits, do, lambda t: (t, jnp.int32(0)), t)

    return jax.lax.cond(eligible, merge, lambda t: (t, jnp.int32(0)), t)


# --------------------------------------------------------------------------
# batched update step
# --------------------------------------------------------------------------

OP_SEARCH, OP_INSERT, OP_DELETE = 0, 1, 2


def _parallel_fastpath(cfg: TreeConfig, t: DeltaTree, kinds, keys, payloads,
                       results, pending, dns, bs):
    """Vectorized first pass: apply all *non-conflicting* updates with
    batched scatters — the SPMD realization of the paper's non-blocking
    concurrency (ops in distinct ΔNodes/leaves proceed "in parallel";
    conflicting ops lose the CAS and retry via the sequential path).

    ``(dns, bs)`` are the batch's frontier leaf positions, computed by the
    scheduler once per round (one `kernels.ops.delta_walk` pass under the
    lockstep engine, the vmapped scalar descent otherwise).

    Handled vectorized: delete-mark, delete-miss, insert-place, insert-grow,
    insert-revive, insert-dup (leaf or buffer).  Left pending: bottom-leaf
    buffered inserts (the paper's lock/buffer path), ops on keys resident
    in the final ΔNode's overflow buffer (mid-batch inserts, or items
    carried by a non-eager maintenance policy — invariant I5' puts a
    buffered key's descent in its holder, so one probe of the final
    ΔNode's buffer row suffices), and any op conflicting on key or leaf
    position (the earliest-in-batch op wins, preserving a valid
    linearization).
    """
    pos = _pos(cfg)
    k = keys.shape[0]
    m = cfg.max_dnodes
    pv = jax.vmap(cfg.pack)(keys, payloads)

    # earliest-in-batch wins per duplicate key / duplicate leaf slot
    def later_duplicate(ids):
        order = jnp.argsort(ids, stable=True)
        sid = ids[order]
        dup_sorted = jnp.concatenate(
            [jnp.zeros((1,), bool), sid[1:] == sid[:-1]])
        return jnp.zeros((k,), bool).at[order].set(dup_sorted)

    key_loser = later_duplicate(keys)
    slot_loser = later_duplicate(dns * jnp.int32(2 ** cfg.height) + bs)
    elig = pending & ~key_loser & ~slot_loser

    leaf_val = t.value[dns, pos[bs]]
    leaf_mark = t.mark[dns, pos[bs]]
    leaf_hit = (leaf_val != EMPTY) & (cfg.key_of(leaf_val) == keys)
    at_bottom = bs >= cfg.bottom0
    is_ins = kinds == OP_INSERT
    is_del = kinds == OP_DELETE
    # final-ΔNode buffer probe: a buffered key may surface at ANY leaf
    # kind (a freshly-Expanded child seeds its buffer while its only leaf
    # sits at the root position), so every miss consults the buffer row
    brow = t.buf[dns]
    in_buf = jnp.any((brow != EMPTY) & (cfg.key_of(brow) == keys[:, None]),
                     axis=1)

    del_ok = elig & is_del & leaf_hit & ~leaf_mark
    # a buffered hit needs the sequential path (dynamic-slot clear); a miss
    # at a BOTTOM leaf may still race mid-round inserts — defer those too
    del_miss = elig & is_del & (leaf_hit & leaf_mark
                                | (~leaf_hit & ~at_bottom & ~in_buf))
    ins_dup = elig & is_ins & leaf_hit & ~leaf_mark
    ins_bufdup = elig & is_ins & ~leaf_hit & in_buf
    ins_revive = elig & is_ins & leaf_hit & leaf_mark
    ins_place = elig & is_ins & (leaf_val == EMPTY) & ~in_buf
    ins_grow = (elig & is_ins & ~leaf_hit & ~in_buf
                & (leaf_val != EMPTY) & ~at_bottom)

    drop = jnp.int32(m)  # OOB row -> scatter mode="drop"

    def sdn(mask):
        return jnp.where(mask, dns, drop)

    value, mark = t.value, t.mark
    vpos = pos[bs]
    mark = mark.at[sdn(del_ok), vpos].set(True, mode="drop")
    wmask = ins_revive | ins_place
    value = value.at[sdn(wmask), vpos].set(pv, mode="drop")
    mark = mark.at[sdn(wmask), vpos].set(False, mode="drop")
    # grow: leaf x -> internal(router=hi) + leaves (lo, hi); x's mark moves
    v_lt = cfg.key_of(pv) < cfg.key_of(leaf_val)
    lo = jnp.where(v_lt, pv, leaf_val)
    hi = jnp.where(v_lt, leaf_val, pv)
    bsafe = jnp.minimum(bs, cfg.bottom0 - 1)  # 2b in range; masked anyway
    lpos, rpos = pos[2 * bsafe], pos[2 * bsafe + 1]
    gdn = sdn(ins_grow)
    value = value.at[gdn, lpos].set(lo, mode="drop")
    value = value.at[gdn, rpos].set(hi, mode="drop")
    value = value.at[gdn, vpos].set(hi, mode="drop")
    mark = mark.at[gdn, lpos].set(jnp.where(v_lt, False, leaf_mark), mode="drop")
    mark = mark.at[gdn, rpos].set(jnp.where(v_lt, leaf_mark, False), mode="drop")
    mark = mark.at[gdn, vpos].set(False, mode="drop")

    dlt = (jnp.where(ins_revive | ins_place | ins_grow, 1, 0)
           + jnp.where(del_ok, -1, 0)).astype(jnp.int32)
    nlive = t.nlive + jax.ops.segment_sum(
        dlt, jnp.where(elig, dns, drop), num_segments=m + 1)[:m]
    del_flag = t.del_flag | ((nlive < cfg.half_cap // 2) & (nlive < t.nlive))

    done = (del_ok | del_miss | ins_dup | ins_bufdup | ins_revive
            | ins_place | ins_grow)
    ok = del_ok | ins_revive | ins_place | ins_grow
    results = jnp.where(done, ok, results)
    pending = pending & ~done
    # bottom-leaf (buffer-path) inserts and conflict losers stay pending

    t = t._replace(value=value, mark=mark, nlive=nlive, del_flag=del_flag)
    return t, results, pending


def update_batch_impl(cfg: TreeConfig, t: DeltaTree, kinds: jax.Array,
                      keys: jax.Array, payloads: jax.Array | None = None):
    """Apply a batch of update ops (insert/delete) in batch order, then run
    maintenance under ``cfg.maintenance`` (eager: to fixpoint, the paper
    semantics).  Returns (tree, results[K] bool, MaintenanceStats).

    The round loop lives in ``repro.maintenance.scheduler`` — this is the
    stable entry point.  The third element used to be a bare round count;
    ``MaintenanceStats`` still coerces via ``int()`` (DeprecationWarning)
    for old call sites, but new code should read ``stats.rounds`` etc.

    Searches are NOT taken here — use `search_batch` on the snapshot (they
    are wait-free and independent of update ordering within the step).

    This is the untraced body; call sites use the jitted/donating
    ``update_batch`` wrapper below, while the forest dispatcher
    (repro/distributed) lax.maps this impl per shard under shard_map.
    """
    from repro.maintenance import scheduler as MS  # deferred: imports us

    return MS.run_update(cfg, t, kinds, keys, payloads)


def flush_impl(cfg: TreeConfig, t: DeltaTree, budget: int = 64):
    """Drain all pending maintenance to fixpoint (restores invariant I5
    after ``deferred``/``budgeted`` update batches).  Returns
    (tree, MaintenanceStats).  A no-op round count of 0 when nothing is
    flagged — safe to call under any policy."""
    from repro.maintenance import scheduler as MS  # deferred: imports us

    return MS.flush(cfg, t, budget)


# the input tree is DONATED: .at[] updates run in place (callers must
# rebind `t = update_batch(...)[0]`, as all call sites do)
update_batch = functools.partial(
    jax.jit, static_argnums=0, donate_argnums=1)(update_batch_impl)

# flush donates too: rebind `t, stats = flush(cfg, t)`
flush = functools.partial(
    jax.jit, static_argnums=(0, 2), donate_argnums=1)(flush_impl)


def buffered_floor(cfg: TreeConfig, t: DeltaTree, keys: jax.Array):
    """Smallest *buffered* packed value strictly greater than each key
    (``cfg.route_left`` when none) — the successor contribution of pending
    overflow-buffer items under non-eager maintenance (I5' trees).

    One global sort of the buffer arena + a searchsorted per query; the
    engine dispatch folds this with the tree walk's candidate.  Buffered
    items are always live, so no tombstone chase is needed on this side.
    The common drained state (e.g. right after ``flush``) skips the sort
    entirely.
    """
    keys = jnp.asarray(keys, jnp.int32)

    def with_items(_):
        flat = jnp.where(t.buf != EMPTY, t.buf, cfg.route_left).reshape(-1)
        s = jnp.sort(flat)
        q = jax.vmap(cfg.qpack)(keys)
        # qpack packs an all-ones payload, so side="right" lands on the
        # first entry whose *key* is strictly greater (map and set alike)
        idx = jnp.searchsorted(s, q, side="right").astype(jnp.int32)
        safe = jnp.clip(idx, 0, s.shape[0] - 1)
        return jnp.where(idx < s.shape[0], s[safe], cfg.route_left)

    def drained(_):
        return jnp.full(keys.shape, cfg.route_left, cfg.vdtype)

    return jax.lax.cond(jnp.any(t.bcount > 0), with_items, drained, None)


def buffered_member(cfg: TreeConfig, t: DeltaTree, keys: jax.Array):
    """True per key iff the key is pending in some ΔNode's overflow
    buffer (I5' trees).  Leaves and buffers are disjoint (inserts dedup
    against both), so ``found & buffered_member`` is exactly "resolved
    via the buffer" — the ``SearchStats.buffer_hits`` column
    (``repro.obs``), computed in the engine dispatch so it cannot drift
    between engines.  Same shape as `buffered_floor`: one global sort of
    the buffer arena + a searchsorted per query, skipped entirely in the
    common drained state."""
    keys = jnp.asarray(keys, jnp.int32)
    in_domain = (keys >= layout.KEY_MIN) & (keys <= layout.KEY_MAX)

    def with_items(_):
        flat = jnp.where(t.buf != EMPTY, t.buf, cfg.route_left).reshape(-1)
        s = jnp.sort(flat)
        # pack with payload 0: the smallest packed value of this key, so
        # side="left" lands on the key's first stored entry if any
        qlow = cfg.pack(keys, jnp.zeros_like(keys))
        idx = jnp.searchsorted(s, qlow, side="left").astype(jnp.int32)
        safe = jnp.clip(idx, 0, s.shape[0] - 1)
        hit = (idx < s.shape[0]) & (cfg.key_of(s[safe]) == keys)
        return hit & in_domain

    def drained(_):
        return jnp.zeros(keys.shape, jnp.bool_)

    return jax.lax.cond(jnp.any(t.bcount > 0), with_items, drained, None)


@functools.partial(jax.jit, static_argnums=0)
def search_jit(cfg: TreeConfig, t: DeltaTree, keys: jax.Array):
    return search_batch(cfg, t, keys)


@functools.partial(jax.jit, static_argnums=0)
def lookup_jit(cfg: TreeConfig, t: DeltaTree, keys: jax.Array):
    return lookup_batch(cfg, t, keys)


# --------------------------------------------------------------------------
# bulk build (benchmark prefill) — host-side numpy, O(n)
# --------------------------------------------------------------------------


def bulk_build(cfg: TreeConfig, values: np.ndarray,
               payloads: np.ndarray | None = None) -> DeltaTree:
    """Build a half-dense ΔTree from unique keys (any order). Host-side."""
    values = np.asarray(values, dtype=np.int64)
    order = np.argsort(values)
    values = values[order]
    assert (np.diff(values) > 0).all(), "keys must be unique"
    if payloads is None:
        payloads = np.zeros(len(values), np.int64)
    else:
        payloads = np.asarray(payloads, np.int64)[order]
    assert values.size == 0 or (
        values[0] >= layout.KEY_MIN and values[-1] <= layout.KEY_MAX
    )
    if cfg.payload_bits:
        packed = (values << cfg.payload_bits) | (payloads & cfg.pmask)
        npdt = np.int64
        route_left = np.int64(1) << 62
    else:
        packed = values.astype(np.int32)
        npdt = np.int32
        route_left = np.int32(ROUTE_LEFT)

    m, ub, lc = cfg.max_dnodes, cfg.ub, cfg.leaf_cap
    g = max(cfg.half_cap, 1)

    value = np.full((m, ub), EMPTY, npdt)
    child = np.full((m, lc), -1, np.int32)
    nlive = np.zeros((m,), np.int32)
    nchild = np.zeros((m,), np.int32)
    parent = np.full((m,), -1, np.int32)
    pslot = np.zeros((m,), np.int32)
    alive = np.zeros((m,), bool)
    next_id = 0

    def new_node():
        nonlocal next_id
        i = next_id
        next_id += 1
        assert i < m, f"bulk_build: arena too small (need > {m} ΔNodes)"
        alive[i] = True
        return i

    def rebuild_np(run, force_bottom=False):
        return layout.rebuild_values_np(
            cfg.height, run, run.size, force_bottom=force_bottom,
            dtype=npdt, route_left=route_left,
        )

    if packed.size == 0:
        ids = [new_node()]
    else:
        ids, mins = [], []
        for s in range(0, packed.size, g):
            run = packed[s : s + g]
            i = new_node()
            value[i] = rebuild_np(run)
            nlive[i] = run.size
            ids.append(i)
            mins.append(run[0])
        while len(ids) > 1:
            nids, nmins = [], []
            for s in range(0, len(ids), g):
                kids = ids[s : s + g]
                kmins = np.asarray(mins[s : s + g], npdt)
                i = new_node()
                value[i] = rebuild_np(kmins, force_bottom=True)
                for slot, cid in enumerate(kids):
                    child[i, slot] = cid
                    parent[cid] = i
                    pslot[cid] = slot
                nchild[i] = len(kids)
                nids.append(i)
                nmins.append(kmins[0])
            ids, mins = nids, nmins

    root = ids[0]
    free = np.zeros(m, np.int32)
    nfree = m - next_id
    free[:nfree] = np.arange(m - 1, next_id - 1, -1, dtype=np.int32)
    return DeltaTree(
        value=jnp.asarray(value),
        mark=jnp.zeros((m, ub), jnp.bool_),
        child=jnp.asarray(child),
        buf=jnp.full((m, cfg.buf_cap), EMPTY, cfg.vdtype),
        nlive=jnp.asarray(nlive),
        bcount=jnp.zeros((m,), jnp.int32),
        nchild=jnp.asarray(nchild),
        parent=jnp.asarray(parent),
        pslot=jnp.asarray(pslot),
        alive=jnp.asarray(alive),
        free_stack=jnp.asarray(free),
        free_top=jnp.int32(nfree),
        root=jnp.int32(root),
        ins_flag=jnp.zeros((m,), jnp.bool_),
        del_flag=jnp.zeros((m,), jnp.bool_),
        alloc_fail=jnp.bool_(False),
    )


# --------------------------------------------------------------------------
# debug / verification helpers (host-side)
# --------------------------------------------------------------------------


def live_items(cfg: TreeConfig, t: DeltaTree):
    """All live (key, payload) pairs (host-side; for tests), key-sorted."""
    pos = np.asarray(layout.veb_pos_table(cfg.height))
    value = np.asarray(t.value)
    mark = np.asarray(t.mark)
    child = np.asarray(t.child)
    buf = np.asarray(t.buf)
    alive = np.asarray(t.alive)
    bottom0 = cfg.bottom0
    bits = cfg.payload_bits
    rl = int(np.asarray(cfg.route_left))
    out = []

    def unpack(v):
        v = int(v)
        return (v >> bits, v & cfg.pmask) if bits else (v, 0)

    for dn in range(cfg.max_dnodes):
        if not alive[dn]:
            continue
        for b in range(1, 2**cfg.height):
            v = value[dn, pos[b]]
            if v == EMPTY or v == rl:
                continue
            at_bottom = b >= bottom0
            left = EMPTY if at_bottom else value[dn, pos[2 * b]]
            is_leaf = at_bottom or left == EMPTY
            if not is_leaf:
                continue
            if at_bottom and child[dn, b - bottom0] >= 0:
                continue  # marker
            if mark[dn, pos[b]]:
                continue
            out.append(unpack(v))
        out.extend(unpack(x) for x in buf[dn] if x != EMPTY)
    return sorted(out)


def live_keys(cfg: TreeConfig, t: DeltaTree) -> np.ndarray:
    return np.asarray([k for k, _ in live_items(cfg, t)], dtype=np.int64)


# --------------------------------------------------------------------------
# ordered queries (beyond-paper: the ΔTree is an ordered dictionary)
# --------------------------------------------------------------------------


def successor_one(cfg: TreeConfig, t: DeltaTree, key, max_chase: int = 8):
    """Smallest live key strictly greater than ``key`` (wait-free read).

    Exploits the router invariant (router = min of its right subtree): on
    every left turn the router is a lower bound on the right subtree's
    minimum, so the final candidate is the smallest such router / final
    leaf > key.  A candidate may be stale (mark-deleted leaf still acting
    as router), in which case we chase `successor(candidate)` — bounded by
    ``max_chase`` (tombstone chains are short between Rebalances).

    Returns (found: bool, succ_key: int32 or 0).
    """
    pos = _pos(cfg)
    bottom0 = cfg.bottom0
    big = cfg.route_left

    def one_pass(qkey):
        q = cfg.qpack(qkey)

        def cond(s):
            return ~s[2]

        def body(s):
            dn, b, _, cand = s
            router = t.value[dn, pos[b]]
            at_bottom = b >= bottom0
            left_val = jnp.where(
                at_bottom, jnp.zeros((), cfg.vdtype),
                t.value[dn, pos[jnp.minimum(2 * b, 2 * bottom0 - 1)]],
            )
            internal = (~at_bottom) & (left_val != EMPTY)
            go_left = internal & (q < router)
            # left turn: router bounds the right subtree's min from below
            cand = jnp.where(go_left & (router < cand), router, cand)
            slot = jnp.where(at_bottom, b - bottom0, 0)
            ch = jnp.where(at_bottom, t.child[dn, slot], NONE)
            hop = at_bottom & (ch >= 0)
            nb = jnp.where(internal, 2 * b + (q >= router).astype(jnp.int32), b)
            nb = jnp.where(hop, jnp.int32(1), nb)
            ndn = jnp.where(hop, ch, dn)
            done = (~internal) & (~hop)
            return ndn, nb, done, cand

        dn, b, _, cand = jax.lax.while_loop(
            cond, body, (jnp.int32(t.root), jnp.int32(1), jnp.bool_(False),
                         big))
        leaf_val = t.value[dn, pos[b]]
        leaf_live = (leaf_val != EMPTY) & ~t.mark[dn, pos[b]]
        leaf_gt = leaf_live & (cfg.key_of(leaf_val) > qkey)
        cand = jnp.where(leaf_gt & (leaf_val < cand), leaf_val, cand)
        return cand

    def chase(s):
        qk, _, _, it = s
        cand = one_pass(qk)
        ck = cfg.key_of(cand)
        exists = cand < big
        # verify liveness: the candidate router may be a tombstone
        live, _, _ = search_one(cfg, t, ck)
        done = ~exists | live
        return (jnp.where(done, qk, ck), ck, done & exists, it + 1)

    def ccond(s):
        _, _, done, it = s
        return (~done) & (it < max_chase)

    init = (jnp.asarray(key, jnp.int32), jnp.int32(0), jnp.bool_(False),
            jnp.int32(0))
    _, ck, found, _ = jax.lax.while_loop(ccond, chase, init)
    return found, jnp.where(found, ck, 0)


def successor_batch(cfg: TreeConfig, t: DeltaTree, keys: jax.Array):
    """Vectorized wait-free successor queries via ``cfg.engine``."""
    from repro.core import engine as E  # deferred: engine imports this module

    return E.successor(cfg, t, keys)


@functools.partial(jax.jit, static_argnums=0)
def successor_jit(cfg: TreeConfig, t: DeltaTree, keys: jax.Array):
    """Jitted engine-dispatched successor queries."""
    return successor_batch(cfg, t, keys)


def scan_one(cfg: TreeConfig, t: DeltaTree, start, hi, max_out: int,
             chase_slack: int = 16):
    """Scalar reference for the emit-cursor scan: emit up to ``max_out``
    live *leaf* items with ``start < key <= hi`` in key order (wait-free
    read; overflow buffers are merged by the engine dispatch, where I5'
    correctness lives).

    The pass structure mirrors the lockstep scan kernel exactly
    (`kernels.ref.ref_delta_scan_fused`): alternate a FIND pass (the
    `successor_one` candidate walk, leaf fold included) with a VERIFY
    pass (exact walk for the candidate key — candidate routers may be
    tombstones; dead candidates are chased without emitting).  ``hops``
    counts ΔNode visits across every pass — bit-identical to the
    lockstep accounting.

    Returns (out (max_out,) packed ascending with ``cfg.route_left``
    padding, n int32, hops int32, more bool); ``more`` means the buffer
    filled with live items remaining — resume from ``key_of(out[n-1])``.
    """
    pos = _pos(cfg)
    bottom0 = cfg.bottom0
    big = cfg.route_left
    pm = jnp.asarray(cfg.pmask, cfg.vdtype)
    start_q = cfg.qpack(jnp.asarray(start, jnp.int32))
    hi_q = cfg.qpack(jnp.asarray(hi, jnp.int32))
    max_passes = 2 * (max_out + chase_slack)

    def walk_pass(q):
        # one full root-to-leaf walk: (cand fold, leaf_val, leaf_live,
        # ΔNodes visited) — the eager-descent twin of one kernel pass
        def cond(s):
            return ~s[2]

        def body(s):
            dn, b, _, cand, hops = s
            router = t.value[dn, pos[b]]
            at_bottom = b >= bottom0
            left_val = jnp.where(
                at_bottom, jnp.zeros((), cfg.vdtype),
                t.value[dn, pos[jnp.minimum(2 * b, 2 * bottom0 - 1)]],
            )
            internal = (~at_bottom) & (left_val != EMPTY)
            go_left = internal & (q < router)
            cand = jnp.where(go_left & (router < cand), router, cand)
            slot = jnp.where(at_bottom, b - bottom0, 0)
            ch = jnp.where(at_bottom, t.child[dn, slot], NONE)
            hop = at_bottom & (ch >= 0)
            nb = jnp.where(internal, 2 * b + (q >= router).astype(jnp.int32), b)
            nb = jnp.where(hop, jnp.int32(1), nb)
            ndn = jnp.where(hop, ch, dn)
            done = (~internal) & (~hop)
            return ndn, nb, done, cand, hops + hop.astype(jnp.int32)

        dn, b, _, cand, hops = jax.lax.while_loop(
            cond, body,
            (jnp.int32(t.root), jnp.int32(1), jnp.bool_(False), big,
             jnp.int32(1)))
        leaf_val = t.value[dn, pos[b]]
        leaf_live = (leaf_val != EMPTY) & ~t.mark[dn, pos[b]]
        return cand, leaf_val, leaf_live, hops

    def outer_cond(s):
        return (~s["done"]) & (s["passes"] < max_passes)

    def outer_body(s):
        cand, lv, live, h1 = walk_pass(s["cursor"])
        leaf_fold = live & (lv > s["cursor"]) & (lv < cand)
        cand = jnp.where(leaf_fold, lv, cand)
        none = (cand == big) | (cand > hi_q)
        pending = cand | pm

        def verify(_):
            _, lv2, live2, h2 = walk_pass(pending)
            hit = live2 & ((lv2 | pm) == pending)
            return lv2, hit, h2

        lv2, hit, h2 = jax.lax.cond(
            none,
            lambda _: (jnp.zeros((), cfg.vdtype), jnp.bool_(False),
                       jnp.int32(0)),
            verify, None)
        can_emit = s["n"] < max_out
        emit = (~none) & hit & can_emit
        full = (~none) & hit & ~can_emit
        upd = s["out"].at[jnp.minimum(s["n"], max_out - 1)].set(lv2)
        return dict(
            cursor=jnp.where(emit | ((~none) & ~hit), pending, s["cursor"]),
            out=jnp.where(emit, upd, s["out"]),
            n=s["n"] + emit.astype(jnp.int32),
            hops=s["hops"] + h1 + h2,
            more=s["more"] | full,
            done=s["done"] | none | full,
            passes=s["passes"] + 1,
        )

    init = dict(cursor=start_q,
                out=jnp.full((max_out,), big, cfg.vdtype),
                n=jnp.int32(0), hops=jnp.int32(0),
                more=jnp.bool_(False), done=jnp.bool_(False),
                passes=jnp.int32(0))
    s = jax.lax.while_loop(outer_cond, outer_body, init)
    return s["out"], s["n"], s["hops"], s["more"]


def scan_batch(cfg: TreeConfig, t: DeltaTree, starts: jax.Array,
               his: jax.Array, max_out: int):
    """Vectorized ordered scans via ``cfg.engine`` (buffered items merged
    under non-eager maintenance — see `engine.scan`)."""
    from repro.core import engine as E  # deferred: engine imports this module

    return E.scan(cfg, t, starts, his, max_out=max_out)


@functools.partial(jax.jit, static_argnums=(0, 4))
def scan_jit(cfg: TreeConfig, t: DeltaTree, starts: jax.Array,
             his: jax.Array, max_out: int):
    """Jitted engine-dispatched range scans."""
    return scan_batch(cfg, t, starts, his, max_out)


def successor_k_batch(cfg: TreeConfig, t: DeltaTree, keys: jax.Array,
                      k: int):
    """Bulk ordered reads: the ``k`` smallest live keys strictly greater
    than each query key — a scan with an unbounded upper band."""
    keys = jnp.asarray(keys, jnp.int32)
    his = jnp.full(keys.shape, layout.KEY_MAX, jnp.int32)
    return scan_batch(cfg, t, keys, his, k)


@functools.partial(jax.jit, static_argnums=(0, 3))
def successor_k_jit(cfg: TreeConfig, t: DeltaTree, keys: jax.Array, k: int):
    """Jitted engine-dispatched successor_k queries."""
    return successor_k_batch(cfg, t, keys, k)
