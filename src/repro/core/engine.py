"""SearchEngine layer — pluggable read path for the ΔTree (DESIGN.md §6).

Every wait-free read (search / lookup / contains / successor) on a
``DeltaTree`` goes through one of the registered engines; ``cfg.engine``
(a static ``TreeConfig`` field, threaded from ``make_index(..., engine=)``
down to the per-shard forest dispatch and the serving pager) picks which:

- ``"scalar"``  — the reference walk: ``vmap`` of a per-query
  ``lax.while_loop`` descent (`deltatree._descend`).  Correct everywhere,
  but the vmap scalarizes the ΔNode visit into per-level gathers — the
  paper's one-block-transfer-per-ΔNode discipline is lost.
- ``"lockstep"`` — frontier-synchronized rounds driving the Pallas vEB
  walk kernel (`kernels.ops.delta_walk`): each round gathers every active
  query's current ΔNode row with one contiguous DMA and descends it fully
  in VMEM, so a round *is* the paper's memory transfer and the round count
  is the O(log_B N) bound.  Pallas lowers compiled on TPU; elsewhere the
  kernel runs in interpret mode, and packed int64 rows outside interpret
  mode take the compiled jnp mirror (`kernels.ref.ref_veb_walk_rows`).

Both engines implement full paper SEARCHNODE semantics — packed
key/payload handling (``cfg.qpack``/``key_of``/``payload_of``), mark-bit
liveness, overflow-buffer membership + payload extraction — and both
report the identical per-query ``hops`` transfer statistic (scalar: ΔNode
boundary crossings counted by `_descend`; lockstep: rounds the query
stayed active).  The conformance suite asserts bit-for-bit equality.

An engine is a table of pure functions over ``(cfg, tree, keys)``; new
read paths (e.g. a fused update-aware walk) register with
``register_engine`` and become selectable everywhere by name.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import deltatree as DT
from repro.core import layout
from repro.core.layout import EMPTY


@dataclasses.dataclass(frozen=True)
class SearchEngine:
    """One registered read path: pure functions over (cfg, tree, keys).

    lookup:    (cfg, t, keys[K]) -> (found[K], payload[K], hops[K])
               — map-mode read; set mode returns payload 0/-1.  ``search``
               and ``contains`` are this minus the payload column.
    successor: (cfg, t, keys[K]) -> (found[K], succ[K])
    """

    name: str
    lookup: Callable[..., Any]
    successor: Callable[..., Any]


_ENGINES: dict[str, SearchEngine] = {}


def register_engine(engine: SearchEngine, *, overwrite: bool = False
                    ) -> SearchEngine:
    """Install ``engine`` under ``engine.name``; re-registration opts in."""
    if engine.name in _ENGINES and not overwrite:
        raise ValueError(f"engine {engine.name!r} already registered")
    _ENGINES[engine.name] = engine
    return engine


def get_engine(name: str) -> SearchEngine:
    try:
        return _ENGINES[name]
    except KeyError:
        raise KeyError(
            f"unknown engine {name!r}; registered: {available_engines()}"
        ) from None


def available_engines() -> list[str]:
    return sorted(_ENGINES)


# --------------------------------------------------------------------------
# dispatch helpers (the entry points deltatree/forest delegate to)
# --------------------------------------------------------------------------


def lookup(cfg, t, keys: jax.Array):
    """Engine-dispatched map-mode read: (found[K], payload[K], hops[K])."""
    return get_engine(cfg.engine).lookup(cfg, t, keys)


def search(cfg, t, keys: jax.Array):
    """Engine-dispatched membership read: (found[K], hops[K])."""
    found, _, hops = lookup(cfg, t, keys)
    return found, hops


def successor(cfg, t, keys: jax.Array):
    """Engine-dispatched ordered read: (found[K], succ[K]).

    Under a non-eager maintenance policy the tree may carry pending items
    in overflow buffers (invariant I5'); those are invisible to the router
    walk, so the dispatch folds the buffered successor floor
    (`deltatree.buffered_floor`) with the engine's tree-side result.  The
    live set is (tree-live ∪ buffered) and the two sides are disjoint, so
    the min of the two successors is the successor over the union.  Eager
    trees skip the fold (buffers are empty between steps — I5), keeping
    the pre-subsystem read bit-identical.
    """
    found, succ = get_engine(cfg.engine).successor(cfg, t, keys)
    policy = getattr(cfg, "maintenance", "eager")
    if policy == "eager" or not hasattr(cfg, "route_left"):
        return found, succ
    bf = DT.buffered_floor(cfg, t, keys)
    bfound = bf < cfg.route_left
    bkey = cfg.key_of(bf).astype(succ.dtype)
    better = bfound & (~found | (bkey < succ))
    return found | bfound, jnp.where(better, bkey, succ)


# --------------------------------------------------------------------------
# "scalar" — the reference engine (vmap of the per-query while_loop walk)
# --------------------------------------------------------------------------


def _scalar_lookup(cfg, t, keys: jax.Array):
    return jax.vmap(lambda k: DT.search_one(cfg, t, k))(keys)


def _scalar_successor(cfg, t, keys: jax.Array):
    return jax.vmap(lambda k: DT.successor_one(cfg, t, k))(keys)


register_engine(SearchEngine(
    name="scalar",
    lookup=_scalar_lookup,
    successor=_scalar_successor,
))


# --------------------------------------------------------------------------
# "lockstep" — frontier rounds driving the Pallas vEB walk kernel
# --------------------------------------------------------------------------


def _lockstep_walk(cfg, t, qpacked: jax.Array):
    from repro.kernels import ops as OPS

    return OPS.delta_walk(t.value, t.child, t.root, qpacked,
                          height=cfg.height, max_rounds=cfg.max_rounds,
                          q_tile=cfg.q_tile or None)


def _lockstep_lookup(cfg, t, keys: jax.Array):
    keys = jnp.asarray(keys, jnp.int32)
    lv, lb, dn, hops, _ = _lockstep_walk(cfg, t, cfg.qpack(keys))
    # SEARCHNODE resolution shared verbatim with the scalar engine
    found, payload = DT.searchnode(cfg, t, keys, lv, lb, dn)
    return found, payload, hops


def _lockstep_successor(cfg, t, keys: jax.Array, max_chase: int = 8):
    """Lockstep successor: the walk kernel folds the min left-turn router
    per round (router = min of its right subtree); a final leaf check and a
    bounded liveness chase mirror `DT.successor_one` lane for lane."""
    keys = jnp.asarray(keys, jnp.int32)
    k = keys.shape[0]
    pos = jnp.asarray(layout.veb_pos_table(cfg.height))
    big = cfg.route_left

    def one_pass(qk):
        lv, lb, dn, _, cand = _lockstep_walk(cfg, t, cfg.qpack(qk))
        leaf_live = (lv != EMPTY) & ~t.mark[dn, pos[lb]]
        leaf_gt = leaf_live & (cfg.key_of(lv) > qk)
        return jnp.where(leaf_gt & (lv < cand), lv, cand)

    def chase(s):
        qk, ck, found, active, it = s
        cand = one_pass(qk)
        cknew = cfg.key_of(cand)
        exists = cand < big
        # candidate routers may be tombstones: verify liveness in lockstep
        live, _, _ = _lockstep_lookup(cfg, t, cknew)
        done_now = ~exists | live
        return (
            jnp.where(active & ~done_now, cknew, qk),
            jnp.where(active, cknew, ck),
            jnp.where(active, done_now & exists, found),
            active & ~done_now,
            it + 1,
        )

    def cond(s):
        return jnp.any(s[3]) & (s[4] < max_chase)

    init = (keys, jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.bool_),
            jnp.ones((k,), jnp.bool_), jnp.int32(0))
    _, ck, found, _, _ = jax.lax.while_loop(cond, chase, init)
    return found, jnp.where(found, ck, 0)


register_engine(SearchEngine(
    name="lockstep",
    lookup=_lockstep_lookup,
    successor=_lockstep_successor,
))
