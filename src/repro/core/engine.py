"""SearchEngine layer — pluggable read path for the ΔTree (DESIGN.md §6).

Every wait-free read (search / lookup / contains / successor) on a
``DeltaTree`` goes through one of the registered engines; ``cfg.engine``
(a static ``TreeConfig`` field, threaded from ``make_index(..., engine=)``
down to the per-shard forest dispatch and the serving pager) picks which:

- ``"scalar"``  — the reference walk: ``vmap`` of a per-query
  ``lax.while_loop`` descent (`deltatree._descend`).  Correct everywhere,
  but the vmap scalarizes the ΔNode visit into per-level gathers — the
  paper's one-block-transfer-per-ΔNode discipline is lost.
- ``"lockstep"`` — frontier-synchronized rounds driving the Pallas vEB
  walk kernel (`kernels.ops.delta_walk`): each round gathers every active
  query's current ΔNode row with one contiguous DMA and descends it fully
  in VMEM, so a round *is* the paper's memory transfer and the round count
  is the O(log_B N) bound.  Pallas lowers compiled on TPU; elsewhere the
  kernel runs in interpret mode, and packed int64 rows outside interpret
  mode take the compiled jnp mirror (`kernels.ref.ref_veb_walk_rows`).

Both engines implement full paper SEARCHNODE semantics — packed
key/payload handling (``cfg.qpack``/``key_of``/``payload_of``), mark-bit
liveness, overflow-buffer membership + payload extraction — and both
report the identical per-query ``hops`` transfer statistic (scalar: ΔNode
boundary crossings counted by `_descend`; lockstep: rounds the query
stayed active).  The conformance suite asserts bit-for-bit equality.

An engine is a table of pure functions over ``(cfg, tree, keys)``; new
read paths (e.g. a fused update-aware walk) register with
``register_engine`` and become selectable everywhere by name.

An engine may additionally declare a ``forest_batch`` entry point
(``ForestBatch``): fused cross-shard reads over a base-offset view of
co-resident shard arenas — one multi-root ``delta_walk`` frontier for
the whole routed batch instead of a vmap over (S, K) dense lanes.  The
forest dispatch (`repro.distributed.forest`) selects it automatically
via ``TreeConfig.engine`` (DESIGN.md §8); the scalar engine declares
none and keeps the dense vmap dispatch as the reference.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import deltatree as DT
from repro.core import layout
from repro.core.layout import EMPTY
from repro.obs import trace as TR


@dataclasses.dataclass(frozen=True)
class ForestBatch:
    """An engine's fused cross-shard forest entry point (DESIGN.md §8).

    Both hooks run over the *device-local* stacked arena pytree ``trees``
    (leading (S_loc,) axis — the shards co-resident on one device) fused
    into a single base-offset arena view, with every query seeded at its
    owner shard's root (``lid`` = per-query local shard index).  One
    kernel launch per frontier round serves all co-resident shards — no
    dense (S, K) scatter, no vmap over shards.

    lookup:    (cfg, trees, lid[K], keys[K], *, view=None)
               -> (found, payload, hops)
    successor: (cfg, trees, lid[K], keys[K], *, view=None)
               -> (found[K], succ[K], has_min[S_loc], mins[S_loc])
               — the per-shard minimum probes (successor of KEY_MIN-1,
               one per local shard) ride the same chase as S_loc extra
               lanes; the forest's cross-shard suffix-min combine
               consumes them.
    make_view: optional (cfg, trees) -> view — precompute the fused
               base-offset view the hooks would otherwise build inline.
               A caller holding an unchanged arena across many reads
               (the serve decode loop) builds it once and passes it back
               through the hooks' ``view=`` keyword; ``None`` (and a
               ``view=None`` call) mean build-per-call, the original
               semantics.  The view is pure data derived from ``trees``
               — passing a stale one is the caller's bug, which is why
               the forest layer keys its cache on the update epoch.

    Results must be bit-identical to the dense per-shard vmap dispatch
    (found/payload/succ and per-query hops) — the fused-conformance suite
    asserts it.
    """

    lookup: Callable[..., Any]
    successor: Callable[..., Any]
    make_view: Callable[..., Any] | None = None
    # scan: (cfg, trees, starts[S_loc], his[S_loc], max_out, *, view=None)
    #       -> (out[S_loc, max_out], n, hops, more) — one emit-cursor lane
    #       per co-resident shard over the fused view (each lane scans its
    #       own arena band), per-shard I5' buffered merge included; None
    #       means the forest falls back to the dense per-shard dispatch
    scan: Callable[..., Any] | None = None


@dataclasses.dataclass(frozen=True)
class SearchEngine:
    """One registered read path: pure functions over (cfg, tree, keys).

    lookup:    (cfg, t, keys[K]) -> (found[K], payload[K], hops[K])
               — map-mode read; set mode returns payload 0/-1.  ``search``
               and ``contains`` are this minus the payload column.
    successor: (cfg, t, keys[K]) -> (found[K], succ[K])
    scan_batch: optional ordered bulk read — (cfg, t, starts[K], his[K],
               max_out, root=None) -> (out[K, max_out] packed, n[K],
               hops[K], more[K]) — up to ``max_out`` live *leaf* items per
               lane with start < key <= hi, key ascending; tree side only
               (the `scan` dispatch merges I5' buffered items).  None
               means the engine cannot serve range_scan/successor_k.
    forest_batch: optional fused cross-shard read entry point
               (``ForestBatch``); None means the forest falls back to the
               dense per-shard vmap dispatch for this engine.
    """

    name: str
    lookup: Callable[..., Any]
    successor: Callable[..., Any]
    scan_batch: Callable[..., Any] | None = None
    forest_batch: ForestBatch | None = None


_ENGINES: dict[str, SearchEngine] = {}


def register_engine(engine: SearchEngine, *, overwrite: bool = False
                    ) -> SearchEngine:
    """Install ``engine`` under ``engine.name``; re-registration opts in."""
    if engine.name in _ENGINES and not overwrite:
        raise ValueError(f"engine {engine.name!r} already registered")
    _ENGINES[engine.name] = engine
    return engine


def get_engine(name: str) -> SearchEngine:
    try:
        return _ENGINES[name]
    except KeyError:
        raise KeyError(
            f"unknown engine {name!r}; registered: {available_engines()}"
        ) from None


def available_engines() -> list[str]:
    return sorted(_ENGINES)


# --------------------------------------------------------------------------
# "auto" engine resolution — pick the bench-table winner per execution mode
# --------------------------------------------------------------------------

# Which engine won the committed engine_compare read-path rows, keyed by
# (backend, compiled).  compiled=True = real XLA/Pallas compilation
# (REPRO_PALLAS_INTERPRET=0 — on CPU the fused walk runs through the
# XLA-compiled `ref_delta_walk_fused`); compiled=False = the Pallas
# interpreter, where lockstep pays the interpreter tax and loses.  Baked
# from the compiled BENCH_*.json at the repo root (run_compiled.sh +
# benchmarks/run.py --compiled): forest lockstep beats scalar outright
# (2-2.6x on the mixed read suite); single-arena deltatree is parity-
# within-noise on compiled CPU (fused single-launch vs XLA's vmap'd
# scalar descent) and lockstep takes the tie — it is the paper's read
# path, runs ONE launch per dispatch (`walk_launches=1` vs the scalar
# engine's fat gather program), and is the form that lowers to the
# Pallas kernel on TPU.  Re-bake when new hardware rows land.
AUTO_TABLE: dict[tuple[str, bool], str] = {
    ("deltatree", True): "lockstep",
    ("forest", True): "lockstep",
}


def resolve_engine(name: str | None, backend: str, *,
                   compiled: bool | None = None) -> str | None:
    """Resolve ``engine="auto"`` to a concrete registered engine.

    Non-"auto" names (including None) pass through untouched.  "auto"
    looks up ``AUTO_TABLE[backend, compiled]`` — ``compiled=None`` reads
    the process execution mode (`ops.default_interpret`) at call time —
    and falls back to "scalar" (the everywhere-correct reference) on a
    table miss, so new backends resolve safely.  ``make_index`` calls
    this before the TreeConfig is built; the resolved name then flows
    through the normal per-backend engine validation.
    """
    if name != "auto":
        return name
    if compiled is None:
        from repro.kernels.ops import default_interpret

        compiled = not default_interpret()
    return AUTO_TABLE.get((backend, bool(compiled)), "scalar")


# --------------------------------------------------------------------------
# dispatch helpers (the entry points deltatree/forest delegate to)
# --------------------------------------------------------------------------


def collecting(cfg) -> bool:
    """Static observability gate (``TreeConfig.collect_stats``): checked
    in Python at trace time, so the False path traces *exactly* the
    pre-obs graph — the HLO-identity contract tests/test_obs.py holds us
    to.  Configs without the field (baselines) never collect."""
    return bool(getattr(cfg, "collect_stats", False))


def collecting_transfers(cfg) -> bool:
    """Static sub-gate for measured ``TransferStats`` (the device-side
    descent replay): only active when ``collect_stats`` already is, so
    the collect_stats=False HLO-identity contract is untouched and the
    replay's extra work is opt-in per config."""
    return collecting(cfg) and bool(getattr(cfg, "collect_transfers", False))


def _read_stats(cfg, t, keys, found, hops):
    """The trailing ``ReadStats`` of a stats-collecting read, derived
    from the dispatch's own outputs: both engines produce bit-identical
    (found, hops) columns (the conformance contract), so the histogram /
    occupancy / buffer-hit parity between engines is structural.  The
    measured-transfer leg replays the descent from (arena, root, keys)
    alone — engine-independent by construction for the same reason."""
    from repro.obs.stats import ReadStats, SearchStats

    keys32 = jnp.asarray(keys, jnp.int32)
    pad = keys32 == layout.ROUTE_LEFT
    bhit = found & DT.buffered_member(cfg, t, keys32)
    transfers = None
    if collecting_transfers(cfg):
        from repro.obs import transfers as OTR

        transfers = OTR.measure(cfg, t, keys32)
    return ReadStats(search=SearchStats.of(hops, pad, bhit),
                     transfers=transfers)


def lookup_cols(cfg, t, keys: jax.Array):
    """The bare engine hook call — always the 3-tuple, never stats.  The
    forest's dense per-shard dispatch reads through this so stats are
    derived exactly once, in the forest's own dispatch layer (mirroring
    the fused path, which also calls raw hooks)."""
    with TR.annotate(f"engine.{cfg.engine}.lookup"):
        return get_engine(cfg.engine).lookup(cfg, t, keys)


def lookup(cfg, t, keys: jax.Array):
    """Engine-dispatched map-mode read: (found[K], payload[K], hops[K]),
    plus a trailing ``ReadStats`` when ``cfg.collect_stats``."""
    out = lookup_cols(cfg, t, keys)
    if not collecting(cfg):
        return out
    found, payload, hops = out
    return found, payload, hops, _read_stats(cfg, t, keys, found, hops)


def search(cfg, t, keys: jax.Array):
    """Engine-dispatched membership read: (found[K], hops[K]), plus a
    trailing ``ReadStats`` when ``cfg.collect_stats``."""
    if not collecting(cfg):
        found, _, hops = lookup(cfg, t, keys)
        return found, hops
    found, _, hops, stats = lookup(cfg, t, keys)
    return found, hops, stats


def successor(cfg, t, keys: jax.Array):
    """Engine-dispatched ordered read: (found[K], succ[K]) — no stats
    variant: ``ReadStats`` rides the hop-bearing reads only (successor
    reports no transfer column to derive them from).

    Under a non-eager maintenance policy the tree may carry pending items
    in overflow buffers (invariant I5'); those are invisible to the router
    walk, so the dispatch folds the buffered successor floor
    (`deltatree.buffered_floor`) with the engine's tree-side result.  The
    live set is (tree-live ∪ buffered) and the two sides are disjoint, so
    the min of the two successors is the successor over the union.  Eager
    trees skip the fold (buffers are empty between steps — I5), keeping
    the pre-subsystem read bit-identical.
    """
    with TR.annotate(f"engine.{cfg.engine}.successor"):
        found, succ = get_engine(cfg.engine).successor(cfg, t, keys)
    policy = getattr(cfg, "maintenance", "eager")
    if policy == "eager" or not hasattr(cfg, "route_left"):
        return found, succ
    return _fold_floor(cfg, DT.buffered_floor(cfg, t, keys), found, succ)


def _fold_floor(cfg, bf, found, succ):
    """Fold a buffered-floor column into a tree-side successor result:
    the live set is (tree-live ∪ buffered) and the sides are disjoint, so
    the min of the two successors is the successor over the union."""
    bfound = bf < cfg.route_left
    bkey = cfg.key_of(bf).astype(succ.dtype)
    better = bfound & (~found | (bkey < succ))
    return found | bfound, jnp.where(better, bkey, succ)


def scan(cfg, t, starts: jax.Array, his: jax.Array, *, max_out: int,
         root=None):
    """Engine-dispatched ordered bulk read: per lane, up to ``max_out``
    live items with ``start < key <= hi`` in key order.

    Returns (out (K, max_out) packed ascending with ``cfg.route_left``
    padding, n (K,), hops (K,), more (K,) bool); ``more`` marks lanes that
    filled their buffer with live items remaining — the continuation
    cursor is ``key_of(out[lane, n-1])``.

    Under a non-eager maintenance policy the engines' tree-side run
    misses pending overflow-buffer items (invariant I5'); the dispatch
    merges them here — ONE shared sorted-buffer merge above both engines
    (`_merge_buffered_run`), so scalar/lockstep bit-parity of the merged
    run is structural, exactly like `successor`'s `_fold_floor`.  Eager
    trees skip the merge (buffers drain every step — I5).
    """
    eng = get_engine(cfg.engine)
    if eng.scan_batch is None:
        raise NotImplementedError(
            f"engine {cfg.engine!r} declares no scan_batch hook")
    with TR.annotate(f"engine.{cfg.engine}.scan"):
        out, n, hops, more = eng.scan_batch(cfg, t, starts, his, max_out,
                                            root=root)
    policy = getattr(cfg, "maintenance", "eager")
    if policy == "eager" or not hasattr(cfg, "route_left"):
        return out, n, hops, more
    out, n, more = _merge_buffered_run(cfg, t, starts, his, out, n, more,
                                       max_out)
    return out, n, hops, more


def successor_k(cfg, t, keys: jax.Array, k: int):
    """Engine-dispatched bulk successors: the ``k`` smallest live keys
    strictly greater than each query key — `scan` with an unbounded upper
    band (same return contract; ``more`` = more than ``k`` successors)."""
    keys = jnp.asarray(keys, jnp.int32)
    his = jnp.full(keys.shape, layout.KEY_MAX, jnp.int32)
    return scan(cfg, t, keys, his, max_out=k)


def _merge_buffered_lane(cfg, sorted_buf, start, hi, out, n, more,
                         max_out: int):
    """Merge one lane's I5' buffered items into its emitted tree run.

    ``sorted_buf`` is a packed ascending buffer arena view (``big``
    padding); the lane's eligible band is (start, cap] where ``cap`` is
    the last tree-emitted key when the tree side overflowed (items past
    the truncation point belong to the continuation — unseen *tree* items
    there could precede them) and ``hi`` otherwise.  Leaves and buffers
    are key-disjoint (inserts dedup against both), so the union of two
    sorted runs is strictly sorted and a concat+sort merge is exact.
    """
    big = cfg.route_left
    pm = jnp.asarray(cfg.pmask, cfg.vdtype)
    nb = sorted_buf.shape[0]
    idx0 = jnp.searchsorted(sorted_buf, cfg.qpack(start),
                            side="right").astype(jnp.int32)
    last = out[jnp.clip(n - 1, 0, max_out - 1)]
    cap = jnp.where(more, last | pm, cfg.qpack(hi))
    idxc = jnp.searchsorted(sorted_buf, cap, side="right").astype(jnp.int32)
    bic = idxc - idx0                     # buffered count in (start, cap]
    span = jnp.arange(max_out, dtype=jnp.int32)
    win = jnp.clip(idx0 + span, 0, nb - 1)
    cands = jnp.where(span < bic, sorted_buf[win], big)
    union = jnp.sort(jnp.concatenate([out, cands]))
    return (union[:max_out],
            jnp.minimum(jnp.int32(max_out), n + bic),
            more | (n + bic > max_out))


def _merge_buffered_run(cfg, t, starts, his, out, n, more, max_out: int):
    """Per-lane `_merge_buffered_lane` over one arena's buffers: one
    global sort of the buffer arena + searchsorted windows per lane,
    skipped entirely in the common drained state (`buffered_floor`'s
    shape)."""
    starts = jnp.asarray(starts, jnp.int32)
    his = jnp.asarray(his, jnp.int32)
    big = cfg.route_left

    def with_items(_):
        flat = jnp.where(t.buf != EMPTY, t.buf, big).reshape(-1)
        s = jnp.sort(flat)
        return jax.vmap(
            lambda st, hb, o, nn, mm: _merge_buffered_lane(
                cfg, s, st, hb, o, nn, mm, max_out)
        )(starts, his, out, n, more)

    def drained(_):
        return out, n, more

    return jax.lax.cond(jnp.any(t.bcount > 0), with_items, drained, None)


def forest_batch(cfg) -> ForestBatch | None:
    """``cfg.engine``'s fused forest entry point (None = vmap dispatch)."""
    return get_engine(cfg.engine).forest_batch


# --------------------------------------------------------------------------
# "scalar" — the reference engine (vmap of the per-query while_loop walk)
# --------------------------------------------------------------------------


def _scalar_lookup(cfg, t, keys: jax.Array):
    found, payload, hops = jax.vmap(lambda k: DT.search_one(cfg, t, k))(keys)
    # the reserved ROUTE_LEFT key (router pad lanes, clamped above-domain
    # probes) is born resolved under the lockstep walk sentinel contract:
    # mirror it here — deterministic miss, payload -1, hops 0 — so the
    # engines' bit-identical per-query hops contract holds for every
    # representable query, reserved keys included
    pad = jnp.asarray(keys, jnp.int32) == layout.ROUTE_LEFT
    return (found & ~pad, jnp.where(pad, -1, payload),
            jnp.where(pad, 0, hops))


def _scalar_successor(cfg, t, keys: jax.Array):
    return jax.vmap(lambda k: DT.successor_one(cfg, t, k))(keys)


def _scalar_scan(cfg, t, starts: jax.Array, his: jax.Array, max_out: int,
                 root=None):
    """vmap of the per-lane reference scan (`DT.scan_one`).  ``root`` is
    the fused-view multi-root seed — lockstep-only; the scalar engine has
    no fused forest path so it must stay None."""
    assert root is None, "scalar scan_batch takes no multi-root seeds"
    starts = jnp.asarray(starts, jnp.int32)
    his = jnp.asarray(his, jnp.int32)
    out, n, hops, more = jax.vmap(
        lambda s, h: DT.scan_one(cfg, t, s, h, max_out))(starts, his)
    # reserved ROUTE_LEFT starts are born done under the lockstep pad-lane
    # sentinel contract: mirror it (empty run, hops 0) for bit parity
    pad = starts == layout.ROUTE_LEFT
    big = jnp.asarray(cfg.route_left, cfg.vdtype)
    return (jnp.where(pad[:, None], big, out),
            jnp.where(pad, 0, n), jnp.where(pad, 0, hops), more & ~pad)


register_engine(SearchEngine(
    name="scalar",
    lookup=_scalar_lookup,
    successor=_scalar_successor,
    scan_batch=_scalar_scan,
))


# --------------------------------------------------------------------------
# "lockstep" — frontier rounds driving the Pallas vEB walk kernel
# --------------------------------------------------------------------------


def _walk_queries(cfg, keys: jax.Array) -> jax.Array:
    """``cfg.qpack`` for the walk kernel, with the reserved ROUTE_LEFT
    key mapped to the packed walk sentinel (``walk_big``) so router pad
    lanes are born resolved — terminate in round 0, miss, no successor
    candidate — in map mode too (in set mode ``qpack(ROUTE_LEFT)`` *is*
    the sentinel already).  ROUTE_LEFT is outside the key domain
    (``layout.KEY_MAX`` < INT32_MAX), so no legitimate query is affected.
    """
    from repro.kernels.veb_search import walk_big

    big = jnp.asarray(walk_big(cfg.vdtype), cfg.vdtype)
    return jnp.where(jnp.asarray(keys, jnp.int32) == layout.ROUTE_LEFT,
                     big, cfg.qpack(keys))


def _lockstep_walk(cfg, t, qpacked: jax.Array, root=None):
    """The kernel driver: ``root`` defaults to the tree's root; a (K,)
    array seeds each query at its own root (fused multi-shard view).
    ``cfg.walk_fused`` picks the driver (fused single-launch vs
    per-round) and ``cfg.walk_round_cap`` the geometry-derived round
    bound — both default-safe for configs predating the knobs."""
    from repro.kernels import ops as OPS

    cap = getattr(cfg, "walk_round_cap", None) or cfg.max_rounds
    return OPS.delta_walk(t.value, t.child,
                          t.root if root is None else root, qpacked,
                          height=cfg.height, max_rounds=cap,
                          q_tile=cfg.q_tile or None,
                          fused=getattr(cfg, "walk_fused", None))


def _lockstep_lookup(cfg, t, keys: jax.Array):
    keys = jnp.asarray(keys, jnp.int32)
    lv, lb, dn, hops, _ = _lockstep_walk(cfg, t, _walk_queries(cfg, keys))
    # SEARCHNODE resolution shared verbatim with the scalar engine
    found, payload = DT.searchnode(cfg, t, keys, lv, lb, dn)
    return found, payload, hops


def _successor_chase(cfg, t, keys: jax.Array, root=None, max_chase: int = 8):
    """Lockstep successor core: the walk kernel folds the min left-turn
    router per round (router = min of its right subtree); a final leaf
    check and a bounded liveness chase mirror `DT.successor_one` lane for
    lane.  ``root`` as in `_lockstep_walk` — per-lane seeds let the same
    chase serve the fused multi-shard view (each lane chases entirely
    within its own shard: candidates are routers/leaves of the seed
    arena, and the liveness re-walk starts from the same seed)."""
    keys = jnp.asarray(keys, jnp.int32)
    k = keys.shape[0]
    pos = jnp.asarray(layout.veb_pos_table(cfg.height))
    big = cfg.route_left

    def one_pass(qk):
        lv, lb, dn, _, cand = _lockstep_walk(cfg, t, _walk_queries(cfg, qk),
                                             root)
        leaf_live = (lv != EMPTY) & ~t.mark[dn, pos[lb]]
        leaf_gt = leaf_live & (cfg.key_of(lv) > qk)
        return jnp.where(leaf_gt & (lv < cand), lv, cand)

    def live_of(qk):
        lv, lb, dn, _, _ = _lockstep_walk(cfg, t, _walk_queries(cfg, qk),
                                          root)
        found, _ = DT.searchnode(cfg, t, qk, lv, lb, dn)
        return found

    def chase(s):
        qk, ck, found, active, it = s
        cand = one_pass(qk)
        cknew = cfg.key_of(cand)
        exists = cand < big
        # candidate routers may be tombstones: verify liveness in lockstep
        live = live_of(cknew)
        done_now = ~exists | live
        return (
            jnp.where(active & ~done_now, cknew, qk),
            jnp.where(active, cknew, ck),
            jnp.where(active, done_now & exists, found),
            active & ~done_now,
            it + 1,
        )

    def cond(s):
        return jnp.any(s[3]) & (s[4] < max_chase)

    init = (keys, jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.bool_),
            jnp.ones((k,), jnp.bool_), jnp.int32(0))
    _, ck, found, _, _ = jax.lax.while_loop(cond, chase, init)
    return found, jnp.where(found, ck, 0)


def _lockstep_successor(cfg, t, keys: jax.Array, max_chase: int = 8):
    return _successor_chase(cfg, t, keys, max_chase=max_chase)


def _lockstep_scan(cfg, t, starts: jax.Array, his: jax.Array, max_out: int,
                   root=None):
    """The emit-cursor scan frontier: ONE `delta_scan` dispatch for the
    whole scan — every FIND/VERIFY pass of every lane inside a single
    launch (`veb_scan_fused`, or its XLA mirror where Pallas cannot
    lower).  ``root`` as in `_lockstep_walk`: per-lane seeds drive the
    fused multi-shard view, each lane scanning its own arena."""
    from repro.kernels import ops as OPS

    starts = jnp.asarray(starts, jnp.int32)
    his = jnp.asarray(his, jnp.int32)
    return OPS.delta_scan(
        t.value, t.mark, t.child, t.root if root is None else root,
        _walk_queries(cfg, starts), cfg.qpack(his),
        height=cfg.height, max_out=max_out, pmask=int(cfg.pmask),
        q_tile=cfg.q_tile or None)


# ---- fused cross-shard frontier (the forest_batch entry point) ----


def _fused_trees_view(cfg, trees):
    """Stacked (S, M, ...) shard arenas -> one base-offset arena view.

    value/child/root fuse through `kernels.veb_search.fuse_arenas` (the
    shard base is applied to child links once here, never per round); the
    SEARCHNODE/floor-side arrays (mark, buf, per-ΔNode stats) flatten
    alongside so `DT.searchnode` indexes fused ΔNode ids directly.
    Shard-scoped fields (root, freelist, alloc_fail) keep shard 0's value
    and must not be read through the view — walks always pass explicit
    per-query roots.  Returns (view, fused_roots (S,))."""
    from repro.kernels.veb_search import fuse_arenas

    # loud trace-time guard: a future per-ΔNode field kept at its stacked
    # (S, M, ...) shape would be gather-clamped silently by fused ids —
    # new fields must be taught to this view explicitly
    assert set(DT.DeltaTree._fields) == {
        "value", "mark", "child", "buf", "nlive", "bcount", "nchild",
        "parent", "pslot", "alive", "free_stack", "free_top", "root",
        "ins_flag", "del_flag", "alloc_fail",
    }, "new DeltaTree field: teach _fused_trees_view how it fuses"
    s, m = trees.value.shape[0], trees.value.shape[1]
    value, child, roots = fuse_arenas(trees.value, trees.child, trees.root)
    base = jnp.arange(s, dtype=jnp.int32) * jnp.int32(m)

    def flat(x):
        return x.reshape((s * m,) + x.shape[2:])

    view = trees._replace(
        value=value, child=child,
        mark=flat(trees.mark), buf=flat(trees.buf),
        nlive=flat(trees.nlive), bcount=flat(trees.bcount),
        nchild=flat(trees.nchild),
        parent=flat(jnp.where(trees.parent >= 0,
                              trees.parent + base[:, None], trees.parent)),
        pslot=flat(trees.pslot), alive=flat(trees.alive),
        ins_flag=flat(trees.ins_flag), del_flag=flat(trees.del_flag),
        free_stack=flat(trees.free_stack), free_top=trees.free_top[0],
        root=trees.root[0], alloc_fail=trees.alloc_fail[0],
    )
    return view, roots


def _fused_lockstep_lookup(cfg, trees, lid, keys: jax.Array, *, view=None):
    keys = jnp.asarray(keys, jnp.int32)
    view, roots = _fused_trees_view(cfg, trees) if view is None else view
    lv, lb, dn, hops, _ = _lockstep_walk(cfg, view, _walk_queries(cfg, keys),
                                         roots[lid])
    found, payload = DT.searchnode(cfg, view, keys, lv, lb, dn)
    return found, payload, hops


def _fused_fold_buffered(cfg, trees, lid, keys, found, succ):
    """The I5' buffered-floor fold of `successor`, restricted per lane to
    its owner shard: a later shard's pending item must reach a query
    through the cross-shard fallback (shard-min probes), exactly as on
    the vmap dispatch, or the suffix-min combine would double-count it.

    The per-shard vmap + lid pick computes an (S_loc, K) floor matrix and
    keeps one entry per lane — deliberately the *same* per-shard
    `buffered_floor` calls as the vmap dispatch, so the fold stays
    bit-identical by construction.  It only runs under non-eager
    maintenance, and a searchsorted matrix is cheap next to the S× walk
    work the fused frontier removes; a shard-keyed single-sort variant is
    a possible future win (needs a (shard, packed) composite key, which
    set mode can't widen without x64)."""
    policy = getattr(cfg, "maintenance", "eager")
    if policy == "eager":
        return found, succ
    floors = jax.vmap(lambda t: DT.buffered_floor(cfg, t, keys))(trees)
    bf = floors[lid, jnp.arange(keys.shape[0])]
    return _fold_floor(cfg, bf, found, succ)


def _fused_lockstep_successor(cfg, trees, lid, keys: jax.Array,
                              max_chase: int = 8, *, view=None):
    """Fused successor: K query lanes plus one shard-minimum probe lane
    per co-resident shard (successor of KEY_MIN-1 seeded at that shard's
    root — replacing the vmap path's per-shard appended lane) share one
    chase.  Returns (found[K], succ[K], has_min[S_loc], mins[S_loc])."""
    keys = jnp.asarray(keys, jnp.int32)
    k = keys.shape[0]
    s_loc = trees.value.shape[0]
    view, roots = _fused_trees_view(cfg, trees) if view is None else view
    qk = jnp.concatenate(
        [keys, jnp.full((s_loc,), layout.KEY_MIN - 1, jnp.int32)])
    lid_all = jnp.concatenate(
        [jnp.asarray(lid, jnp.int32), jnp.arange(s_loc, dtype=jnp.int32)])
    found, succ = _successor_chase(cfg, view, qk, roots[lid_all],
                                   max_chase=max_chase)
    found, succ = _fused_fold_buffered(cfg, trees, lid_all, qk, found, succ)
    return found[:k], succ[:k], found[k:], succ[k:]


def _fused_lockstep_scan(cfg, trees, lid, starts: jax.Array, his: jax.Array,
                         max_out: int, *, view=None):
    """Fused cross-shard scan: every lane scans inside one shard of the
    base-offset view — lane ``j`` is seeded at shard ``lid[j]``'s fused
    root, so its run is exactly that shard's band of the range and ONE
    `delta_scan` dispatch serves every (lane, shard) pair the forest
    tiles out.  The I5' buffered merge runs per lane against its *own*
    shard's buffers (shards partition the key space, so a pending item is
    only ever mergeable into its owner shard's band) — the same
    `_merge_buffered_lane` the single-arena dispatch uses, so fused/vmap
    bit-parity of the merged run is structural."""
    starts = jnp.asarray(starts, jnp.int32)
    his = jnp.asarray(his, jnp.int32)
    lid = jnp.asarray(lid, jnp.int32)
    view, roots = _fused_trees_view(cfg, trees) if view is None else view
    out, n, hops, more = _lockstep_scan(cfg, view, starts, his, max_out,
                                        root=roots[lid])
    policy = getattr(cfg, "maintenance", "eager")
    if policy == "eager":
        return out, n, hops, more
    big = cfg.route_left

    def with_items(_):
        flat = jnp.where(trees.buf != EMPTY, trees.buf, big)
        per_shard = jnp.sort(flat.reshape(trees.buf.shape[0], -1), axis=1)
        return jax.vmap(
            lambda s_id, st, hb, o, nn, mm: _merge_buffered_lane(
                cfg, per_shard[s_id], st, hb, o, nn, mm, max_out)
        )(lid, starts, his, out, n, more)

    def drained(_):
        return out, n, more

    out, n, more = jax.lax.cond(jnp.any(trees.bcount > 0), with_items,
                                drained, None)
    return out, n, hops, more


register_engine(SearchEngine(
    name="lockstep",
    lookup=_lockstep_lookup,
    successor=_lockstep_successor,
    scan_batch=_lockstep_scan,
    forest_batch=ForestBatch(
        lookup=_fused_lockstep_lookup,
        successor=_fused_lockstep_successor,
        make_view=_fused_trees_view,
        scan=_fused_lockstep_scan,
    ),
))
