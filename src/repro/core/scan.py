"""Host-facing range-scan result types, shared by the api and serve
layers.

The engine layer speaks packed rows: ``engine.scan`` returns
``(out, n, hops, more)`` with ``out`` holding qpacked (key | payload)
values padded with the walk sentinel.  The API layer unpacks that into a
``ScanResult`` per lane — plain numpy views plus an optional
``ScanCursor`` continuation when the caller's ``max_items`` buffer
filled before the range was exhausted.

A ``ScanCursor`` is deliberately tiny and deliberately *not* part of any
tree pytree (``engine._fused_trees_view`` pins the exact DeltaTree field
set): it records the last key the previous call emitted plus the
original inclusive upper bound.  Because the kernel's start bound is
exclusive in key space, resuming is just "scan again from
``last_key``" — no tree state, no snapshot, and concurrent maintenance
between pages is harmless (the page boundary is a key, not a pointer).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class ScanCursor(NamedTuple):
    """Continuation token for a truncated ``range_scan`` page.

    ``last_key`` is the largest key the previous page emitted (the next
    page starts strictly after it); ``hi`` is the original inclusive
    upper bound, carried so ``Index.range_scan(..., cursor=c)`` callers
    don't have to re-thread it.
    """

    last_key: int
    hi: int


class ScanResult(NamedTuple):
    """One lane's unpacked range-scan page.

    ``keys``/``payloads`` are length-``count`` numpy views in ascending
    key order.  ``more`` is True when the page filled ``max_items``
    before exhausting ``[lo, hi]``; ``cursor`` is then the continuation
    token (``None`` on the final page).
    """

    keys: np.ndarray
    payloads: np.ndarray
    more: bool
    cursor: ScanCursor | None

    @property
    def count(self) -> int:
        return int(self.keys.shape[0])

    def items(self) -> list[tuple[int, int]]:
        """Host-side (key, payload) pairs, key-sorted — the same shape
        ``Index.live_items`` returns, for oracle-style comparisons."""
        return [(int(k), int(p)) for k, p in zip(self.keys, self.payloads)]
