"""Dynamic van Emde Boas layout math (paper §2).

A ΔNode is a size-fixed container holding a complete binary tree of height
``H`` (``UB = 2**H - 1`` node slots) stored in **vEB order**: the tree is
recursively split at half height into a top subtree and bottom subtrees, each
laid out contiguously (paper Fig. 1/2).  We address tree nodes by their
1-based **BFS index** ``b`` (root=1, children ``2b``/``2b+1``) and translate
to the storage position with a precomputed permutation table — the TPU
adaptation of the paper's layout: the complete-tree *shape* is implicit
(position arithmetic in registers), only *occupancy* is dynamic, so no child
pointers are stored inside a ΔNode (fewer bytes transferred than the paper's
explicit-pointer nodes; see DESIGN.md §2).

Everything in this module is static numpy executed at trace time; the tables
become compile-time constants inside jitted ΔTree ops and Pallas kernels.
"""

from __future__ import annotations

import functools

import numpy as np

# Reserved key values (paper reserves 0 as EMPTY; we additionally reserve the
# int32 max as the "route-everything-left" router used by Merge splicing).
EMPTY = np.int32(0)
ROUTE_LEFT = np.int32(2**31 - 1)  # INT32_MAX
KEY_MIN = 1
KEY_MAX = 2**31 - 2


def veb_order(h: int) -> list[int]:
    """BFS indices (1-based, within a height-``h`` subtree) in vEB storage order.

    Recursive split: top subtree of height ``h//2``, ``2**(h//2)`` bottom
    subtrees of height ``h - h//2``, laid out top-first then bottoms
    left-to-right (paper §2.2).  Works for any ``h >= 1`` (the paper assumes
    ``h`` a power of two "for simplicity"; the recursion does not need it).
    """
    if h == 1:
        return [1]
    ht = h // 2          # top height
    hb = h - ht          # bottom height
    top = veb_order(ht)
    bot = veb_order(hb)
    order = list(top)
    # Bottom subtree roots are the BFS nodes at depth ht: indices 2**ht .. 2**(ht+1)-1.
    for r in range(2**ht, 2 ** (ht + 1)):
        for j in bot:
            # local BFS index j (root=1) inside subtree rooted at global BFS r:
            # j at local depth d with offset (j - 2**d)  ->  global r*2**d + offset.
            d = j.bit_length() - 1
            order.append(r * (2**d) + (j - 2**d))
    return order


@functools.lru_cache(maxsize=None)
def veb_pos_table(h: int) -> np.ndarray:
    """``pos[b]`` = storage index (0-based) of BFS node ``b``; shape (2**h,).

    Index 0 is unused (BFS is 1-based) and set to -1.
    """
    order = veb_order(h)
    pos = np.full(2**h, -1, dtype=np.int32)
    for storage_idx, b in enumerate(order):
        pos[b] = storage_idx
    assert (pos[1:] >= 0).all()
    return pos


@functools.lru_cache(maxsize=None)
def veb_inverse_table(h: int) -> np.ndarray:
    """``bfs[s]`` = BFS index stored at storage position ``s``; shape (2**h - 1,)."""
    return np.asarray(veb_order(h), dtype=np.int32)


def num_nodes(h: int) -> int:
    return 2**h - 1


def leaf_capacity(h: int) -> int:
    """Max leaves of a complete tree of height ``h`` (bottom row)."""
    return 2 ** (h - 1)


def bottom_first(h: int) -> int:
    """BFS index of the first bottom-row node."""
    return 2 ** (h - 1)


# ---------------------------------------------------------------------------
# Complete leaf-oriented BST (re)build tables (used by Rebalance / Expand /
# Merge / bulk build).  Given m sorted leaf values placed contiguously at
# depth d (0-based; leaves at BFS 2**d .. 2**d + m - 1), every internal node
# at depth dd < d covers the leaf range [j*2**(d-dd), (j+1)*2**(d-dd)) where
# j is its offset within its row, and its *router* is the minimum of its right
# half ( = leaf x[j*c + c/2] ), with the leaf-oriented rule "v < router goes
# left, else right" (paper Fig. 8 semantics — see DESIGN.md for the min-of-
# right-subtree derivation from the paper's grow-leaf, Fig. 9 lines 52..66).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def rebuild_tables(h: int) -> dict[str, np.ndarray]:
    """Static tables for rebuilding a ΔNode at any leaf depth d in 0..h-1.

    Returns arrays of shape (h, 2**h):
      - ``range_start[d, b]``: first covered leaf index of BFS node b when
        leaves live at depth d (or a large sentinel when b is below depth d).
      - ``range_mid[d, b]``:   leaf index whose value is the router of b.
      - ``kind[d, b]``: 0 = below-leaf-row (always EMPTY), 1 = leaf row,
        2 = internal row.
    All indexed by BFS node; callers translate with :func:`veb_pos_table`.
    """
    n = 2**h
    range_start = np.full((h, n), 2**30, dtype=np.int32)
    range_mid = np.full((h, n), 2**30, dtype=np.int32)
    kind = np.zeros((h, n), dtype=np.int32)
    for d in range(h):
        for b in range(1, n):
            dd = b.bit_length() - 1  # depth of b
            j = b - 2**dd            # offset within its row
            if dd > d:
                kind[d, b] = 0
            elif dd == d:
                kind[d, b] = 1
                range_start[d, b] = j
            else:
                kind[d, b] = 2
                c = 2 ** (d - dd)    # leaves covered
                range_start[d, b] = j * c
                range_mid[d, b] = j * c + c // 2
    return {"range_start": range_start, "range_mid": range_mid, "kind": kind}


def rebuild_values_np(h: int, sorted_vals: np.ndarray, m: int,
                      force_bottom: bool = False, dtype=np.int32,
                      route_left=None) -> np.ndarray:
    """Numpy oracle of the ΔNode rebuild (mirrors the jnp version in
    deltatree.py).  Returns the (2**h - 1,) storage array in vEB order.

    ``sorted_vals`` holds the m live (packed) keys in ascending order (padded
    arbitrarily beyond m).  Leaves are placed at the minimal depth
    ``d = ceil(log2(max(m,1)))`` unless ``force_bottom`` (ΔNodes that carry
    child links keep their leaf row pinned at the bottom; DESIGN.md §2).
    """
    if route_left is None:
        route_left = ROUTE_LEFT
    n = 2**h
    if m <= 0:
        return np.full(n - 1, EMPTY, dtype=dtype)
    d = int(np.ceil(np.log2(max(m, 1)))) if m > 1 else 0
    d = min(d, h - 1)
    if force_bottom:
        d = h - 1
    assert m <= 2**d or m == 1
    t = rebuild_tables(h)
    pos = veb_pos_table(h)
    out = np.full(n - 1, EMPTY, dtype=dtype)
    for b in range(1, n):
        k = t["kind"][d, b]
        if k == 1:
            idx = t["range_start"][d, b]
            if idx < m:
                out[pos[b]] = sorted_vals[idx]
        elif k == 2:
            start = t["range_start"][d, b]
            mid = t["range_mid"][d, b]
            if start >= m:
                continue  # whole subtree empty
            if mid < m:
                out[pos[b]] = sorted_vals[mid]   # min of right subtree
            else:
                out[pos[b]] = route_left         # right subtree empty
    return out
