"""Baselines the paper compares ΔTree against (§5), adapted to batched JAX.

- PointerBST  — analog of the concurrent AVL/RB/speculation-friendly trees:
  explicit left/right child indices, nodes scattered in allocation order (no
  locality). Insert = leaf append (randomly-built ⇒ expected O(log n) height,
  same assumption as the paper's Lemma 4.5); delete = logical mark.
- StaticVEB   — the paper's VTMtree: one monolithic complete BST in static
  vEB order, values at internal nodes. Search-optimal, but ANY update
  rebuilds the whole layout (the paper's motivating weakness).
- SortedArray — binary search; batched updates = sort-merge rebuild.
- HashTable   — open-addressing linear probing (not in the paper; extra
  locality point of reference, labeled as such in benchmarks).

Every structure exposes:
  build(values) -> state            (host)
  search(state, keys) -> found[K]   (jitted)
  update(state, kinds, keys) -> (state, results[K])   (jitted or host)
  touched(state, key) -> list[int]  (host; flat element indices read on the
                                     search path, for ideal-cache transfer
                                     counting — Table 1 analog)

`count_block_transfers` converts touched-index traces into the number of
distinct size-B memory blocks transferred (the ideal-cache model the paper
analyses; B in elements).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core.layout import EMPTY

OP_SEARCH, OP_INSERT, OP_DELETE = 0, 1, 2


def count_block_transfers(touch_fn, keys, block_elems: int) -> float:
    """Mean number of distinct B-element blocks touched per search."""
    total = 0
    for k in keys:
        idxs = touch_fn(int(k))
        total += len({i // block_elems for i in idxs})
    return total / max(len(keys), 1)


# --------------------------------------------------------------------------
# Sorted array
# --------------------------------------------------------------------------


class SortedArrayState(NamedTuple):
    vals: jax.Array  # (cap,) int32 ascending, padded with INT32_MAX
    n: jax.Array     # () int32


class SortedArray:
    name = "sorted_array"

    @staticmethod
    def build(values: np.ndarray, cap: int | None = None) -> SortedArrayState:
        values = np.unique(np.asarray(values, np.int32))
        cap = cap or max(16, 2 * len(values))
        pad = np.full(cap, np.iinfo(np.int32).max, np.int32)
        pad[: len(values)] = values
        return SortedArrayState(jnp.asarray(pad), jnp.int32(len(values)))

    @staticmethod
    @jax.jit
    def search(state: SortedArrayState, keys: jax.Array):
        i = jnp.searchsorted(state.vals, keys)
        i = jnp.clip(i, 0, state.vals.shape[0] - 1)
        return state.vals[i] == keys

    @staticmethod
    @jax.jit
    def update(state: SortedArrayState, kinds: jax.Array, keys: jax.Array):
        # batched rebuild: results computed sequentially against a bitmap
        def body(i, s):
            vals, n, res = s
            v = keys[i]
            idx = jnp.clip(jnp.searchsorted(vals, v), 0, vals.shape[0] - 1)
            present = vals[idx] == v

            def ins(args):
                vals, n = args
                # shift right from idx (O(cap) dynamic slice emulation)
                shifted = jnp.where(
                    jnp.arange(vals.shape[0]) > idx, jnp.roll(vals, 1), vals
                )
                return shifted.at[idx].set(v), n + 1

            def dele(args):
                vals, n = args
                rolled = jnp.roll(vals, -1)
                newv = jnp.where(jnp.arange(vals.shape[0]) >= idx, rolled, vals)
                return newv.at[vals.shape[0] - 1].set(jnp.iinfo(jnp.int32).max), n - 1

            is_ins = kinds[i] == OP_INSERT
            ok = jnp.where(is_ins, ~present, present)
            do = jnp.where(is_ins, ok, jnp.bool_(False))
            vals, n = jax.lax.cond(is_ins & ok, ins, lambda a: a, (vals, n))
            vals, n = jax.lax.cond((~is_ins) & ok, dele, lambda a: a, (vals, n))
            return vals, n, res.at[i].set(ok)

        vals, n, res = jax.lax.fori_loop(
            0, keys.shape[0], body, (state.vals, state.n, jnp.zeros(keys.shape, bool))
        )
        return SortedArrayState(vals, n), res

    @staticmethod
    def touch_fn(state: SortedArrayState):
        vals = np.asarray(state.vals)
        n = int(state.n)

        def touched(key: int) -> list[int]:
            lo, hi, out = 0, n, []
            while lo < hi:
                mid = (lo + hi) // 2
                out.append(mid)
                if vals[mid] < key:
                    lo = mid + 1
                elif vals[mid] > key:
                    hi = mid
                else:
                    break
            return out

        return touched


# --------------------------------------------------------------------------
# Static vEB monolith (VTMtree analog)
# --------------------------------------------------------------------------


class StaticVEBState(NamedTuple):
    store: jax.Array   # (2**h - 1,) int32 in vEB order, node-oriented BST
    height: int        # static


class StaticVEB:
    name = "static_veb"

    @staticmethod
    def _bst_values(values: np.ndarray, h: int) -> np.ndarray:
        """Place sorted values into a complete node-oriented BST (BFS index),
        in-order = sorted; empty slots get EMPTY."""
        n = 2**h
        out = np.full(n, EMPTY, np.int32)
        def fill(b, lo, hi):  # values[lo:hi] in subtree rooted at BFS b
            if lo >= hi:
                return
            # in-order position of root: size of a complete left subtree
            depth_left = h - (b.bit_length())  # height below b
            cap_left = 2**depth_left - 1 if depth_left > 0 else 0
            size = hi - lo
            left = min(cap_left, max(size - 1 - min(cap_left, size - 1), 0))
            # standard: fill left subtree as full as possible
            left = min(cap_left, size - 1)
            # keep right subtree non-degenerate: classic balanced split
            left = (size - 1) // 2 if cap_left >= (size - 1) // 2 else cap_left
            root = lo + left
            out[b] = values[root]
            fill(2 * b, lo, root)
            fill(2 * b + 1, root + 1, hi)
        import sys
        old = sys.getrecursionlimit()
        sys.setrecursionlimit(10000)
        try:
            fill(1, 0, len(values))
        finally:
            sys.setrecursionlimit(old)
        return out

    @staticmethod
    def build(values: np.ndarray, height: int | None = None) -> StaticVEBState:
        values = np.unique(np.asarray(values, np.int32))
        h = height or max(1, int(np.ceil(np.log2(len(values) + 2))))
        while 2**h - 1 < len(values):
            h += 1
        bfs_vals = StaticVEB._bst_values(values, h)
        pos = layout.veb_pos_table(h)
        store = np.full(2**h - 1, EMPTY, np.int32)
        for b in range(1, 2**h):
            store[pos[b]] = bfs_vals[b]
        return StaticVEBState(jnp.asarray(store), h)

    @staticmethod
    @functools.partial(jax.jit, static_argnums=2)
    def _search(store: jax.Array, keys: jax.Array, h: int):
        pos = jnp.asarray(layout.veb_pos_table(h))

        def one(v):
            def cond(s):
                b, found, dead = s
                return (~found) & (~dead)

            def body(s):
                b, found, dead = s
                x = store[pos[b]]
                found = x == v
                nb = 2 * b + (v > x).astype(jnp.int32)
                dead = (x == EMPTY) | (nb >= 2**h)
                return jnp.where(found | dead, b, nb), found, dead

            _, found, _ = jax.lax.while_loop(
                cond, body, (jnp.int32(1), jnp.bool_(False), jnp.bool_(False))
            )
            return found

        return jax.vmap(one)(keys)

    @staticmethod
    def search(state: StaticVEBState, keys: jax.Array):
        return StaticVEB._search(state.store, keys, state.height)

    @staticmethod
    def update(state: StaticVEBState, kinds, keys):
        """The paper's point: a static vEB layout cannot update in place —
        the whole layout is rebuilt (host-side), blocking everything."""
        vals_np = StaticVEB.to_sorted(state)
        s = set(vals_np.tolist())
        res = np.zeros(len(keys), bool)
        for i, (k, v) in enumerate(zip(np.asarray(kinds), np.asarray(keys))):
            v = int(v)
            if k == OP_INSERT:
                res[i] = v not in s
                s.add(v)
            elif k == OP_DELETE:
                res[i] = v in s
                s.discard(v)
        return StaticVEB.build(np.asarray(sorted(s), np.int32), None), jnp.asarray(res)

    @staticmethod
    def to_sorted(state: StaticVEBState) -> np.ndarray:
        store = np.asarray(state.store)
        vals = store[store != EMPTY]
        return np.sort(vals)

    @staticmethod
    def touch_fn(state: StaticVEBState):
        store = np.asarray(state.store)
        h = state.height
        pos = layout.veb_pos_table(h)

        def touched(key: int) -> list[int]:
            b, out = 1, []
            while b < 2**h:
                p = int(pos[b])
                out.append(p)
                x = store[p]
                if x == key or x == EMPTY:
                    break
                b = 2 * b + (1 if key > x else 0)
            return out

        return touched


# --------------------------------------------------------------------------
# Pointer BST (concurrent AVL/RB/SF-tree analog: no locality)
# --------------------------------------------------------------------------


class PointerBSTState(NamedTuple):
    val: jax.Array    # (cap,) int32
    left: jax.Array   # (cap,) int32, -1 none
    right: jax.Array  # (cap,) int32
    mark: jax.Array   # (cap,) bool
    n: jax.Array      # () int32 — nodes allocated
    root: jax.Array   # () int32


class PointerBST:
    name = "pointer_bst"

    @staticmethod
    def build(values: np.ndarray, cap: int | None = None,
              shuffle_layout: bool = True, seed: int = 0) -> PointerBSTState:
        """Insert in random order (expected O(log n) height), node ids in
        *allocation order* — i.e., memory layout uncorrelated with tree
        structure, like heap-allocated nodes of the Synchrobench trees."""
        values = np.unique(np.asarray(values, np.int32))
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(values))
        cap = cap or max(16, 2 * len(values) + 16)
        val = np.zeros(cap, np.int32)
        left = np.full(cap, -1, np.int32)
        right = np.full(cap, -1, np.int32)
        n = 0
        root = -1
        for i in order:
            v = values[i]
            if root < 0:
                root = n
            else:
                c = root
                while True:
                    if v < val[c]:
                        if left[c] < 0:
                            left[c] = n
                            break
                        c = left[c]
                    else:
                        if right[c] < 0:
                            right[c] = n
                            break
                        c = right[c]
            val[n] = v
            n += 1
        return PointerBSTState(
            jnp.asarray(val), jnp.asarray(left), jnp.asarray(right),
            jnp.zeros(cap, jnp.bool_), jnp.int32(n), jnp.int32(root),
        )

    @staticmethod
    @jax.jit
    def search(state: PointerBSTState, keys: jax.Array):
        def one(v):
            def cond(s):
                c, found = s
                return (c >= 0) & (~found)

            def body(s):
                c, _ = s
                x = state.val[c]
                hit = (x == v) & ~state.mark[c]
                stop = x == v
                nc = jnp.where(v < x, state.left[c], state.right[c])
                return jnp.where(stop, jnp.int32(-1), nc), hit

            _, found = jax.lax.while_loop(cond, body, (state.root, jnp.bool_(False)))
            return found

        return jax.vmap(one)(keys)

    @staticmethod
    @jax.jit
    def update(state: PointerBSTState, kinds: jax.Array, keys: jax.Array):
        def body(i, s):
            st, res = s
            v = keys[i]

            # descend to the match or the attach point
            def cond(x):
                c, parent, went_left, done = x
                return ~done

            def bd(x):
                c, parent, went_left, done = x
                xv = st.val[c]
                hit = xv == v
                nl = jnp.where(v < xv, st.left[c], st.right[c])
                done = hit | (nl < 0)
                return (
                    jnp.where(done, c, nl),
                    jnp.where(done, parent, c),
                    jnp.where(done, went_left, v < xv),
                    done,
                )

            c, parent, went_left, _ = jax.lax.while_loop(
                cond, bd, (st.root, jnp.int32(-1), jnp.bool_(False), st.n == 0)
            )
            xv = st.val[c]
            hit = (st.n > 0) & (xv == v)
            is_ins = kinds[i] == OP_INSERT

            def do_ins(st):
                def revive(st):
                    return st._replace(mark=st.mark.at[c].set(False))

                def attach(st):
                    nid = st.n
                    stv = st._replace(
                        val=st.val.at[nid].set(v),
                        n=st.n + 1,
                        root=jnp.where(st.n == 0, nid, st.root),
                    )
                    go_left = v < xv
                    stv = stv._replace(
                        left=jnp.where(
                            (st.n > 0) & go_left, stv.left.at[c].set(nid), stv.left
                        ),
                        right=jnp.where(
                            (st.n > 0) & ~go_left, stv.right.at[c].set(nid), stv.right
                        ),
                    )
                    return stv

                return jax.lax.cond(hit, revive, attach, st)

            def do_del(st):
                return st._replace(
                    mark=jnp.where(hit, st.mark.at[c].set(True), st.mark)
                )

            ok = jnp.where(
                is_ins, jnp.where(hit, st.mark[c], True), hit & ~st.mark[c]
            )
            st = jax.lax.cond(is_ins & ok, do_ins, lambda s: s, st)
            st = jax.lax.cond((~is_ins) & ok, do_del, lambda s: s, st)
            return st, res.at[i].set(ok)

        st, res = jax.lax.fori_loop(
            0, keys.shape[0], body, (state, jnp.zeros(keys.shape, bool))
        )
        return st, res

    @staticmethod
    def touch_fn(state: PointerBSTState):
        val = np.asarray(state.val)
        left = np.asarray(state.left)
        right = np.asarray(state.right)
        root = int(state.root)
        n = int(state.n)

        def touched(key: int) -> list[int]:
            # each node = val + 2 pointers; model 4 elements per node
            out, c = [], root if n > 0 else -1
            while c >= 0:
                out.extend([4 * c, 4 * c + 1, 4 * c + 2])
                if val[c] == key:
                    break
                c = left[c] if key < val[c] else right[c]
            return out

        return touched


# --------------------------------------------------------------------------
# Open-addressing hash table (extra baseline, not in the paper)
# --------------------------------------------------------------------------


class HashState(NamedTuple):
    slots: jax.Array  # (cap,) int32, EMPTY free, -1 tombstone... use 0 free
    cap: int


class HashTable:
    name = "hash"
    TOMB = -1

    @staticmethod
    def _h(v, cap):
        return (v.astype(jnp.uint32) * jnp.uint32(2654435761) % jnp.uint32(cap)).astype(
            jnp.int32
        )

    @staticmethod
    def build(values: np.ndarray, cap: int | None = None) -> HashState:
        values = np.unique(np.asarray(values, np.int32))
        cap = cap or int(2 ** np.ceil(np.log2(max(4 * len(values), 16))))
        slots = np.full(cap, EMPTY, np.int32)
        for v in values:
            i = int((int(v) * 2654435761) % (2**32) % cap)
            while slots[i] != EMPTY:
                i = (i + 1) % cap
            slots[i] = v
        return HashState(jnp.asarray(slots), cap)

    @staticmethod
    @functools.partial(jax.jit, static_argnums=1)
    def _search(slots, cap, keys):
        def one(v):
            def cond(s):
                i, found, dead, steps = s
                return (~found) & (~dead) & (steps < cap)

            def body(s):
                i, found, dead, steps = s
                x = slots[i]
                found = x == v
                dead = x == EMPTY
                return (i + 1) % cap, found, dead, steps + 1

            i0 = HashTable._h(v, cap)
            _, found, _, _ = jax.lax.while_loop(
                cond, body, (i0, jnp.bool_(False), jnp.bool_(False), jnp.int32(0))
            )
            return found

        return jax.vmap(one)(keys)

    @staticmethod
    def search(state: HashState, keys: jax.Array):
        return HashTable._search(state.slots, state.cap, keys)

    @staticmethod
    def touch_fn(state: HashState):
        slots = np.asarray(state.slots)
        cap = state.cap

        def touched(key: int) -> list[int]:
            i = int((int(key) * 2654435761) % (2**32) % cap)
            out = []
            for _ in range(cap):
                out.append(i)
                if slots[i] == key or slots[i] == EMPTY:
                    break
                i = (i + 1) % cap
            return out

        return touched


ALL_BASELINES = [SortedArray, StaticVEB, PointerBST, HashTable]
