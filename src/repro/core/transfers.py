"""Ideal-cache transfer accounting for the ΔTree (Table 1 / Lemma 2.1 analog).

The paper measures cache misses with Valgrind; on TPU (and in this CPU
container) we instead count memory transfers *exactly* in the ideal-cache
model the paper's analysis uses: replay the search path host-side, record
every element index read, and count distinct B-element blocks.

The flat address space models the arena layout: ΔNode ``dn`` occupies
elements ``[dn*stride, dn*stride + UB)`` with the vEB permutation inside —
i.e., exactly the bytes a TPU DMA of that ΔNode row would move.
"""

from __future__ import annotations

import numpy as np

from repro.core import layout
from repro.core.deltatree import DeltaTree, TreeConfig
from repro.core.layout import EMPTY


def delta_touch_fn(cfg: TreeConfig, t: DeltaTree):
    """Host-side replay of `deltatree._descend` returning touched flat
    element indices (for `baselines.count_block_transfers`)."""
    pos = np.asarray(layout.veb_pos_table(cfg.height))
    value = np.asarray(t.value)
    child = np.asarray(t.child)
    root = int(t.root)
    bottom0 = cfg.bottom0
    stride = cfg.ub  # contiguous rows; block-aligned per ΔNode

    def touched(key: int) -> list[int]:
        dn, b, out = root, 1, []
        while True:
            out.append(dn * stride + int(pos[b]))
            at_bottom = b >= bottom0
            if at_bottom:
                ch = child[dn, b - bottom0]
                if ch >= 0:
                    dn, b = int(ch), 1
                    continue
                break
            left_val = value[dn, pos[2 * b]]
            if left_val == EMPTY:
                break  # leaf
            out.append(dn * stride + int(pos[2 * b]))  # leaf-test read
            b = 2 * b + (1 if key >= value[dn, pos[b]] else 0)
        return out

    return touched


def delta_hops_fn(cfg: TreeConfig, t: DeltaTree):
    """ΔNode-visit count per search (each visit ≤ 2 block transfers of size
    ≥ UB, Lemma 2.1)."""
    touch = delta_touch_fn(cfg, t)
    stride = cfg.ub

    def hops(key: int) -> int:
        return len({i // stride for i in touch(key)})

    return hops
