"""The train step: value_and_grad + AdamW (+ optional grad accumulation).

Under jit with sharded params/batch, gradient all-reduces are inserted by
the SPMD partitioner (intra-pod over "data", cross-pod over "pod"); the
DiLoCo-style compressed cross-pod sync lives in launch/train.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.registry import api
from repro.optim import AdamWConfig, adamw_update


def make_train_step(cfg, ocfg: AdamWConfig, accum_steps: int = 1):
    m = api(cfg)

    def single(params, batch):
        return jax.value_and_grad(m.loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = single(params, batch)
        else:
            # microbatch over the leading axis: batch leaves (A, b/A, ...)
            def body(carry, micro):
                loss_acc, grads_acc = carry
                loss, grads = single(params, micro)
                return (
                    loss_acc + loss / accum_steps,
                    jax.tree.map(
                        lambda a, g: a + g.astype(a.dtype) / accum_steps,
                        grads_acc, grads,
                    ),
                ), None

            z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            # (B,...) -> (B/A, A, ...) -> (A, B/A, ...): micro a takes rows
            # {b*A+a}, so each device's rows stay local under batch sharding
            micro = jax.tree.map(
                lambda x: x.reshape((x.shape[0] // accum_steps, accum_steps)
                                    + x.shape[1:]).swapaxes(0, 1),
                batch,
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), z), micro)
        params, opt_state, metrics = adamw_update(ocfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
