"""starcoder2-15b [dense] — GQA kv=4, RoPE [arXiv:2402.19173]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense",
    num_layers=3, d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512,
    dtype="float32", param_dtype="float32", remat=False,
)
