"""deepseek-v2-236b [moe] — MLA kv_lora=512(+64 rope), 2 shared + 160 routed
top-6, leading dense layer [arXiv:2405.04434].

Note: d_ff=12288 is the dense (layer-0) FFN width; the assigned d_ff=1536 is
the per-expert width (moe_d_ff)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128, head_dim=128,
    d_ff=12288, vocab_size=102400,
    moe_experts=160, moe_top_k=6, moe_shared=2, moe_d_ff=1536, dense_layers=1,
    mla=True, q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
    qk_rope_dim=64, v_head_dim=128,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=160, vocab_size=512,
    moe_experts=8, moe_top_k=2, moe_shared=2, moe_d_ff=48, dense_layers=1,
    mla=True, q_lora_rank=32, kv_lora_rank=24, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16,
    dtype="float32", param_dtype="float32", remat=False,
)
