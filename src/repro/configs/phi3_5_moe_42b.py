"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2, GQA kv=8
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    moe_experts=16, moe_top_k=2, moe_d_ff=6400,
)

SMOKE = ModelConfig(
    name="phi35-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512,
    moe_experts=4, moe_top_k=2, moe_d_ff=96,
    dtype="float32", param_dtype="float32", remat=False,
)
