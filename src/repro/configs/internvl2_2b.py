"""internvl2-2b [vlm] — InternViT frontend STUB (input_specs provides patch
embeddings) + InternLM2 backbone [arXiv:2404.16821]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92672,  # 92553 padded to a 256 multiple
    vision_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=512, vision_tokens=8,
    dtype="float32", param_dtype="float32", remat=False,
)
