"""qwen1.5-110b [dense] — GQA kv=8, QKV bias [hf:Qwen/Qwen1.5-110B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=49152, vocab_size=152064, qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen-smoke", family="dense",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=512, qkv_bias=True,
    dtype="float32", param_dtype="float32", remat=False,
)
