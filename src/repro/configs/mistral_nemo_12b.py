"""mistral-nemo-12b [dense] — GQA kv=8, head_dim=128 (not d/H), 128k ctx
[hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="nemo-smoke", family="dense",
    num_layers=3, d_model=80, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=512, rope_theta=1e6,
    dtype="float32", param_dtype="float32", remat=False,
)
