"""mamba2-370m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=1, num_kv_heads=1, head_dim=64,
    d_ff=0, vocab_size=50432,  # 50280 padded to a 256 multiple (TP divisibility)
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=4, d_model=64, num_heads=1, num_kv_heads=1, head_dim=16,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
    dtype="float32", param_dtype="float32", remat=False,
)
