"""whisper-base [audio] — enc-dec, conv frontend STUB (input_specs provides
frame embeddings) [arXiv:2212.04356]. 6 encoder + 6 decoder layers."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=52224,  # 51865 padded to a 256 multiple (TP divisibility)
    encoder_layers=6, encoder_seq=1500, cross_attention=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    encoder_layers=2, encoder_seq=24, cross_attention=True,
    dtype="float32", param_dtype="float32", remat=False,
)
