"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
every other layer [arXiv:2403.19887]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    moe_experts=16, moe_top_k=2, moe_every=2, moe_offset=1, moe_d_ff=24576,
    attn_every=8, attn_offset=4,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    moe_experts=4, moe_top_k=2, moe_every=2, moe_offset=1, moe_d_ff=96,
    attn_every=8, attn_offset=4,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
    dtype="float32", param_dtype="float32", remat=False,
)
