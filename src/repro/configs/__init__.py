"""Assigned architecture configs (--arch <id>).

Each module defines CONFIG (the exact assigned full-scale config) and SMOKE
(a reduced same-family config for CPU smoke tests).  Full configs are only
ever lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "jamba_1_5_large_398b",
    "mamba2_370m",
    "qwen1_5_110b",
    "starcoder2_15b",
    "mistral_nemo_12b",
    "granite_8b",
    "internvl2_2b",
    "whisper_base",
    "phi3_5_moe_42b",
    "deepseek_v2_236b",
]

# accept dashed/dotted public ids too
ALIASES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-370m": "mamba2_370m",
    "qwen1.5-110b": "qwen1_5_110b",
    "starcoder2-15b": "starcoder2_15b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "granite-8b": "granite_8b",
    "internvl2-2b": "internvl2_2b",
    "whisper-base": "whisper_base",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}


def _module(name: str):
    name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke_config(name: str):
    return _module(name).SMOKE
