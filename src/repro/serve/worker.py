"""Background index-maintenance worker (DESIGN.md §10).

The lockstep engine drained maintenance on a fixed ``flush_every``
stride, *on* the decode path.  The worker inverts that: with a non-eager
maintenance policy the decode path's staged updates only append/mark
(I5′ keeps reads correct over the buffered items), and the structural
work — Rebalance / Expand / Merge to fixpoint — runs here, triggered by
the ``MaintenanceStats.pending`` high-water mark instead of a stride.

"Background" in this single-process reproduction means *off the
per-update decode path, at the step barrier*: the scheduler calls
``maybe_drain`` after each step's decode completes and before the next
step's reads are issued, so no read is in flight while the drain
restores I5 — the same quiescent-point argument the forest's ``flush``
makes.  An async-actor deployment would run the identical drain on a
worker thread under the same barrier.
"""

from __future__ import annotations

__all__ = ["MaintenanceWorker"]


class MaintenanceWorker:
    """Owns the drain policy over one pager's index.

    ``high_water``: drain when ``pager.pending`` (buffered items awaiting
    maintenance, the I5′ carry) reaches this mark; <= 0 disables the
    trigger (``force=True`` still drains — the final barrier / tests).
    """

    def __init__(self, pager, high_water: int | None = None):
        self.pager = pager
        self.high_water = (pager.cfg.maint_high_water
                           if high_water is None else high_water)
        self.drains = 0
        self.rounds = 0
        self.rebuilds = 0
        self.expands = 0
        self.merges = 0
        self.last_drain_step = -1

    def maybe_drain(self, step: int = 0, force: bool = False) -> bool:
        """Drain to fixpoint if pending crossed the high-water mark (or
        ``force``).  Returns whether a drain ran.  Must be called at a
        step barrier — no reads in flight."""
        if not force and (self.high_water <= 0
                          or self.pager.pending < self.high_water):
            return False
        ms = self.pager.flush()
        self.drains += 1
        self.last_drain_step = step
        if ms is not None:
            self.rounds += int(ms.rounds)
            self.rebuilds += int(ms.rebuilds)
            self.expands += int(ms.expands)
            self.merges += int(ms.merges)
        return True

    def stats(self) -> dict:
        return {"drains": self.drains, "rounds": self.rounds,
                "rebuilds": self.rebuilds, "expands": self.expands,
                "merges": self.merges,
                "last_drain_step": self.last_drain_step}
