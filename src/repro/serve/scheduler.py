"""Continuous-batching serve scheduler (DESIGN.md §10).

Replaces the lockstep ``ServeEngine`` loop: instead of stepping a fixed
set of sequences and flushing maintenance on a stride, each step
composes its batch from the live decode lanes plus whatever the
admission queue can fill into free slots, runs every staged index op as
one combined update, and leaves structural index maintenance to the
``MaintenanceWorker`` at the step barrier.

One ``step()``:

  1. reap departures — cancelled live lanes release their slot and stage
     page frees (plus frees staged by last step's finishers are still
     pending here);
  2. admit — free slots fill FIFO from the waiting queue; each admission
     prefills (dense prefill, K/V scattered into staged-allocated pages)
     and joins this step's decode batch;
  3. grow — live lanes crossing a page boundary stage tail allocations;
  4. apply — all staged ops (admission inserts + growth inserts + the
     departures' deletes) run the same-key elimination pass and hit the
     index as ONE update batch (`DeltaPager.apply_staged`);
  5. decode — one `paged_decode_step` over the live lanes (block tables
     via wait-free lookup — with a forest index the hoisted fused view
     makes consecutive steps reuse one `fuse_arenas` build);
  6. finish — lanes reaching ``max_new`` release their slot and stage
     frees, then a second admission pass re-fills the freed lanes the
     same step (slot recycling; these prefill now, decode next step);
  7. barrier — ``MaintenanceWorker.maybe_drain`` runs off the decode
     path, triggered by the pending high-water mark.  No read is in
     flight at the barrier, so draining to fixpoint preserves the I5′
     read-correctness argument.

Under "no churn + eager maintenance" the pipeline degenerates to the
lockstep loop's behavior exactly (the static-trace parity test holds the
two bit-identical); churn and deferred maintenance are where the
scheduler earns its keep.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.api import Index
from repro.distributed import forest as DF
from repro.models.config import ModelConfig
from repro.obs import trace as OT
from repro.obs.stats import ScanStats, ServeStats
from repro.serve import decode as D
from repro.serve.combine import dedupe_lookups
from repro.serve.queue import RequestQueue, ServeRequest
from repro.serve.worker import MaintenanceWorker
from repro.serving.pager import DeltaPager, PagerConfig, make_pager

__all__ = ["SchedulerConfig", "ServeScheduler"]


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Static scheduler knobs (the model/pager configs ride separately).

    max_live:    decode-lane count — the bounded live-batch size.
    max_waiting: admission-control bound on the waiting FIFO (0 = none;
                 rejected submissions count in ``queue.rejected``).
    maint_high_water: overrides the pager config's field when not None.
    combine:     run the same-key elimination pass over staged batches.
    """

    max_live: int = 8
    max_waiting: int = 0
    maint_high_water: int | None = None
    combine: bool = True


class ServeScheduler:
    """Continuous-batching scheduler over the paged-KV DeltaPager.

    Compat surface (what the legacy lockstep engine exposed and the
    tests/benchmarks consume): ``submit() -> sid``, ``step() -> {sid:
    tok}``, ``active[sid].out``, ``pager``, ``obs``.  New surface:
    ``cancel``, ``probe``, ``queue``, ``worker``, ``run_trace``.
    """

    def __init__(self, cfg: ModelConfig, params, pager_cfg: PagerConfig,
                 sched: SchedulerConfig | None = None, *,
                 index: Index | None = None, pager: DeltaPager | None = None):
        assert cfg.family in ("dense", "moe", "vlm"), cfg.family
        assert not cfg.mla, "scheduler supports GQA caches"
        self.cfg = cfg
        self.params = params
        self.sched = sched if sched is not None else SchedulerConfig()
        self.pager = pager if pager is not None else make_pager(pager_cfg,
                                                                index)
        pager_cfg = self.pager.cfg
        self.ps = pager_cfg.page_size
        self.queue = RequestQueue(self.sched.max_live,
                                  self.sched.max_waiting)
        self.worker = MaintenanceWorker(
            self.pager, high_water=self.sched.maint_high_water)
        if not self.sched.combine:
            self.pager.apply_staged = self._apply_uncombined  # type: ignore
        L, NP = cfg.num_layers, pager_cfg.num_pages
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.dtype)
        self.k_pages = jnp.zeros((L, NP, self.ps, kvh, hd), dt)
        self.v_pages = jnp.zeros((L, NP, self.ps, kvh, hd), dt)
        self.active: dict[int, ServeRequest] = {}   # every request ever
        self.lengths: dict[int, int] = {}
        self._next_id = 0
        self._steps = 0
        self._probe_combined = 0
        self._combined_mark = 0   # combined ops already folded into obs
        self.obs = ServeStats.zero()
        self.scan_obs = ScanStats.zero()
        self.last_step_info: dict = {}

    def _apply_uncombined(self):
        """combine=False: same staged protocol, elimination pass skipped
        (ablation / conformance baseline)."""
        pg = self.pager
        if not pg._staged:
            return {"applied": 0, "combined": 0, "inline_maint": 0}
        kinds, keys, pays = (np.asarray(c) for c in zip(*pg._staged))
        pg._staged.clear()
        inline0 = pg.stats["inline_maint"]
        res = pg._update(kinds.astype(np.int32), keys.astype(np.int32),
                         pays.astype(np.int32))
        assert bool(np.asarray(res).all())
        return {"applied": int(len(kinds)), "combined": 0,
                "inline_maint": pg.stats["inline_maint"] - inline0}

    # ------------------------------------------------------------- arrival ---

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> int:
        """Enqueue a request (admission happens inside ``step``).
        Returns its seq id; a rejected submission (bounded waiting FIFO)
        still gets an id, with ``active[sid].cancelled`` set."""
        sid = self._next_id
        self._next_id += 1
        req = ServeRequest(sid, np.asarray(prompt, np.int32), max_new,
                           submit_step=self._steps)
        self.active[sid] = req
        self.queue.submit(req)
        return sid

    def cancel(self, sid: int) -> str:
        """Departure mid-flight; live lanes are reaped at the next step."""
        return self.queue.cancel(sid)

    # ---------------------------------------------------------------- step ---

    def step(self) -> dict[int, int]:
        """One scheduler step; returns {sid: token} for decoded lanes.

        Records one ``ServeStats`` sample whenever any work happened —
        latency, queue depth, admission waits, combined ops, fused-view
        cache hits, pending high-water, worker drains."""
        t0 = time.perf_counter()
        v0 = DF.fused_view_cache_stats()
        with OT.span("serve.sched_step"):
            out, info = self._step()
        v1 = DF.fused_view_cache_stats()
        # combining is cumulative across the staged batches AND the probe
        # service (which runs between steps): report everything since the
        # last recorded step, not just what this step's apply eliminated
        total_combined = self.pager.stats["combined"] + self._probe_combined
        info.update(
            queue_depth=self.queue.depth,
            combined=total_combined - self._combined_mark,
            view_hits=v1["hits"] - v0["hits"],
            view_builds=v1["builds"] - v0["builds"],
        )
        self._combined_mark = total_combined
        self.last_step_info = info
        if out or info["admitted"] or info["applied"]:
            self.obs = self.obs.record(
                time.perf_counter() - t0,
                pending=self.pager.pending,
                flushed=info["drained"],
                queue_depth=info["queue_depth"],
                admitted=info["admitted"],
                admit_wait=info["admit_wait"],
                combined=info["combined"],
                view_hits=info["view_hits"],
                view_builds=info["view_builds"],
            )
        return out

    def _admit(self) -> list[tuple[int, ServeRequest]]:
        """One admission pass: fill free slots, stage page allocations,
        prefill (dense prefill + K/V scatter into the staged pages)."""
        admitted = self.queue.admit(self._steps)
        for _, req in admitted:
            n_blocks = -(-len(req.prompt) // self.ps)
            pages = self.pager.stage_allocate(req.seq_id, n_blocks)
            with OT.span("serve.prefill"):
                self.k_pages, self.v_pages, s, tok = D.prefill_to_pages(
                    self.cfg, self.params, self.ps, self.k_pages,
                    self.v_pages, req.prompt, pages)
            self.lengths[req.seq_id] = s
            req.out.append(tok)
        return admitted

    def _retire(self, slot: int, req: ServeRequest) -> None:
        """Departure: release the lane, stage the sequence's page frees
        (deletes ride the next combined batch; pages recycle now)."""
        self.queue.release(slot)
        self.pager.stage_free(req.seq_id)
        self.lengths.pop(req.seq_id, None)

    def _step(self):
        # 1. reap departures marked since the last barrier
        for slot, req in self.queue.live():
            if req.cancelled:
                self._retire(slot, req)
        # 2. admission: freed/initial slots join this step's decode
        admitted = self._admit()
        # 3. growth: lanes whose next token crosses a page boundary
        for _, req in self.queue.live():
            sid = req.seq_id
            needed = self.lengths[sid] // self.ps + 1
            have = self.pager.seq_blocks[sid]
            if needed > have:
                self.pager.stage_allocate(sid, needed - have)
        # 4. one combined index update for everything staged
        applied = self.pager.apply_staged()
        # 5. decode all live lanes (slot order)
        out: dict[int, int] = {}
        lanes = self.queue.live()
        if lanes:
            sids = [r.seq_id for _, r in lanes]
            lens = np.asarray([self.lengths[s] for s in sids], np.int32)
            maxp = int(max(lens)) // self.ps + 1
            bt = self.pager.block_tables(sids, maxp)   # ΔTree hot path
            tokens = jnp.asarray([[self.active[s].out[-1]] for s in sids],
                                 jnp.int32)
            with OT.span("serve.decode"):
                logits, self.k_pages, self.v_pages = D.paged_decode_step(
                    self.params, self.cfg, D.layer_params(self.cfg,
                                                          self.params),
                    tokens, self.k_pages, self.v_pages, jnp.asarray(bt),
                    jnp.asarray(lens), self.ps)
            for bi, (slot, req) in enumerate(lanes):
                tok = int(jnp.argmax(logits[bi, 0]))
                req.out.append(tok)
                out[req.seq_id] = tok
                self.lengths[req.seq_id] += 1
                # 6a. finish check after the decode append (legacy rule:
                # the prefill token alone never finishes a request)
                if len(req.out) >= req.max_new:
                    req.done = True
                    self._retire(slot, req)
        self._steps += 1
        # 6b. slot recycling: re-fill lanes freed by this step's
        # finishers now (prefill this step, decode joins the next)
        admitted += self._admit()
        # 7. step barrier: background maintenance off the decode path
        drained = self.worker.maybe_drain(self._steps)
        info = dict(
            admitted=len(admitted),
            admit_wait=sum(r.wait_steps for _, r in admitted),
            applied=applied["applied"],
            inline_maint=applied["inline_maint"],
            drained=drained,
        )
        return out, info

    # ------------------------------------------------------- read service ---

    def probe(self, seq_ids) -> np.ndarray:
        """Read-side service traffic: resolve the head-block page of each
        referenced sequence (−1 when unmapped) through one wait-free
        lookup.  Duplicate references — the common case under zipfian
        traffic — collapse to one shard op each (`dedupe_lookups`)."""
        keys = self.pager._key(np.asarray(seq_ids, np.int64),
                               np.zeros(len(seq_ids), np.int64))
        uniq, inverse, combined = dedupe_lookups(keys)
        self._probe_combined += combined
        with OT.span("serve.probe"):
            found, pages, hops = self.pager._lookup(uniq)
        self.pager.stats["searches"] += len(uniq)
        self.pager.stats["hops"] += int(np.asarray(hops).sum())
        out = np.where(np.asarray(found), np.asarray(pages), -1)[inverse]
        # probe reads previously bypassed ServeStats entirely; count the
        # caller-visible traffic (pre-dedupe refs, resolved mappings)
        self.obs = self.obs.record_probe(len(seq_ids),
                                         int((out >= 0).sum()))
        return out

    def scan(self, seq_ids, max_items: int | None = None):
        """Ordered read service: each referenced sequence's full
        block -> page mapping in block order, resolved through ONE
        engine scan dispatch (one emit-cursor lane per sequence over the
        pager index's contiguous per-sequence key band) — the bulk
        companion to ``probe``'s point lookups.  Like ``probe`` it runs
        between steps against the current wait-free snapshot; staged
        (unapplied) allocations are invisible until the step barrier's
        combined update lands.

        Returns ``{seq_id: np.ndarray of page ids in block order}``
        (empty array for unmapped sequences).  Folds one ``ScanStats``
        sample into ``self.scan_obs`` (exported by ``metrics()``)."""
        pg = self.pager
        ix = pg.index
        ix._require("range_scan", ix.spec.backend.scan)
        if max_items is None:
            max_items = pg.cfg.max_blocks
        sids = np.asarray(seq_ids, np.int64)
        # per-sequence key band: blocks of sid pack contiguously, so the
        # band (key(sid, -1), key(sid, max_blocks - 1)] is exactly its
        # block table (start bound is exclusive in the scan contract)
        starts = jnp.asarray(pg._key(sids, np.full(sids.shape, -1)),
                             jnp.int32)
        his = jnp.asarray(pg._key(sids, np.full(sids.shape,
                                                pg.cfg.max_blocks - 1)),
                          jnp.int32)
        with OT.span("serve.scan"):
            _, pages, n, hops, more = ix.spec.backend.scan(
                ix.spec.cfg, ix.state, starts, his, max_items)
        pg.stats["searches"] += len(sids)
        pg.stats["hops"] += int(np.asarray(hops).sum())
        self.scan_obs = self.scan_obs.merge(ScanStats.of(n, hops, more))
        pages, n = np.asarray(pages), np.asarray(n)
        return {int(s): pages[i, : n[i]] for i, s in enumerate(sids)}

    # ---------------------------------------------------------- metrics ---

    def metrics(self, fmt: str = "dict"):
        """Point-in-time metrics snapshot across every stats source the
        scheduler touches: the decode loop's ``ServeStats``, the
        maintenance worker's drain counters, the pager's host-side op
        counters, the read path's last ``ReadStats`` legs (search /
        router / measured transfers — present when the underlying index
        was built with ``collect_stats``), and any ``REPRO_TRACE`` span
        counters.  ``fmt``: "dict" (nested plain dict), "prometheus"
        (text exposition), or "json"."""
        from repro.obs import export as OX

        rs = self.pager.last_read_stats
        tr = OT.counters()
        snap = OX.snapshot(
            serve=self.obs,
            scan=self.scan_obs,
            maintenance=self.worker.stats(),
            pager=self.pager.stats,
            search=rs.search if rs is not None else None,
            router=rs.router if rs is not None else None,
            transfers=rs.transfers if rs is not None else None,
            trace=tr or None,
        )
        if fmt == "prometheus":
            return OX.to_prometheus(snap)
        if fmt == "json":
            return OX.to_json(snap)
        assert fmt == "dict", f"unknown metrics fmt {fmt!r}"
        return snap

    # ------------------------------------------------------------ trace ---

    def run_trace(self, plans, *, drain: bool = True) -> dict:
        """Replay a ``synth_trace`` plan: per step submit the arrivals,
        issue the cancels and zipf probe traffic, then ``step()``.
        Submission-order indices in the plan map 1:1 onto seq ids (ids
        are handed out sequentially).  Returns a summary dict."""
        tokens = 0
        for plan in plans:
            for prompt, max_new in plan.arrivals:
                self.submit(prompt, max_new=max_new)
            for ref in plan.cancels:
                self.cancel(ref)
            if len(plan.probe_refs):
                self.probe(plan.probe_refs)
            tokens += len(self.step())
        if drain:
            self.drain()
        finished = sum(r.done for r in self.active.values())
        return {
            "submitted": self._next_id,
            "finished": finished,
            "rejected": self.queue.rejected,
            "decode_tokens": tokens,
            "steps": self._steps,
        }

    # ------------------------------------------------------------ drain ---

    def drain(self, max_steps: int = 10_000) -> None:
        """Step until every submitted request departed, then apply any
        staged frees and force a final maintenance drain."""
        for _ in range(max_steps):
            if not self.queue.live() and not self.queue.waiting:
                break
            self.step()
        self.pager.apply_staged()
        self.worker.maybe_drain(self._steps, force=True)
