"""repro.serve — continuous-batching serve scheduler (DESIGN.md §10).

The inference-stack shape over the DeltaTree machinery: an
admission-controlled request queue with slot recycling
(`queue.RequestQueue`), a step scheduler composing live decode lanes
with admitted prefills (`scheduler.ServeScheduler`), a same-key
op-combining pass over each step's staged index ops (`combine`), and
index maintenance as a background worker off the decode path
(`worker.MaintenanceWorker`).  ``repro.serving.ServeEngine`` is a thin
compat shim over `ServeScheduler`; the legacy lockstep loop survives as
``repro.serving.engine.LockstepServeEngine`` (the parity oracle).
"""

from repro.serve.combine import combine_ops, dedupe_lookups
from repro.serve.queue import RequestQueue, ServeRequest
from repro.serve.scheduler import SchedulerConfig, ServeScheduler
from repro.serve.trace import StepPlan, synth_trace
from repro.serve.worker import MaintenanceWorker

__all__ = [
    "MaintenanceWorker",
    "RequestQueue",
    "SchedulerConfig",
    "ServeRequest",
    "ServeScheduler",
    "StepPlan",
    "combine_ops",
    "dedupe_lookups",
    "synth_trace",
]
