"""Admission-controlled request queue with slot recycling (DESIGN.md §10).

``RequestQueue`` separates the two populations the lockstep engine
conflated: a bounded FIFO of *waiting* requests (arrival order
preserved; admission control rejects past ``max_waiting``) and a fixed
array of ``max_live`` *slots* — the decode lanes.  A request occupies
exactly one slot from admission to departure; a departing request's slot
is handed straight back to the admission pass, so a finishing lane is
re-filled the same step the finisher leaves (continuous batching's slot
recycling).  Requests can also depart mid-flight via ``cancel`` —
waiting requests leave the FIFO immediately, live ones are marked and
reaped by the scheduler at its next step boundary.

Pure host-side bookkeeping: no jax, no pager — the scheduler composes
this with the pager's staged ops and the decode machinery.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

__all__ = ["RequestQueue", "ServeRequest"]


@dataclasses.dataclass
class ServeRequest:
    """One request's whole life: submitted → (waiting) → admitted/live →
    done or cancelled.  ``out`` accumulates tokens (prefill argmax first,
    then one per decode step) — the compat surface the legacy engine's
    ``Request`` exposed."""

    seq_id: int
    prompt: np.ndarray
    max_new: int
    submit_step: int = 0
    admit_step: int = -1       # -1 while waiting
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    cancelled: bool = False

    @property
    def wait_steps(self) -> int:
        """Steps spent in the waiting FIFO before admission."""
        return max(self.admit_step - self.submit_step, 0)


class RequestQueue:
    def __init__(self, max_live: int, max_waiting: int = 0):
        assert max_live > 0, max_live
        self.max_live = max_live
        self.max_waiting = max_waiting  # 0 = unbounded
        self.waiting: collections.deque[ServeRequest] = collections.deque()
        self.slots: list[ServeRequest | None] = [None] * max_live
        self.rejected = 0

    # ---- arrival / departure ----

    def submit(self, req: ServeRequest) -> bool:
        """Enqueue; False (and ``rejected`` bumps) when admission control
        bounds the FIFO and it is full."""
        if self.max_waiting and len(self.waiting) >= self.max_waiting:
            self.rejected += 1
            req.cancelled = True
            return False
        self.waiting.append(req)
        return True

    def cancel(self, seq_id: int) -> str:
        """Departure mid-flight: "waiting" requests leave the FIFO now,
        "live" ones are marked for the scheduler's next reap; returns
        which population the request was in ("missing" otherwise)."""
        for req in self.waiting:
            if req.seq_id == seq_id:
                req.cancelled = True
                self.waiting.remove(req)
                return "waiting"
        for req in self.slots:
            if req is not None and req.seq_id == seq_id:
                req.cancelled = True
                return "live"
        return "missing"

    # ---- admission / recycling ----

    def admit(self, step: int) -> list[tuple[int, ServeRequest]]:
        """Fill every free slot FIFO-first; returns [(slot, request)].
        Ran twice per scheduler step: once at the top (slots freed while
        the queue was empty) and once after departures (same-step slot
        recycling)."""
        admitted = []
        for slot in range(self.max_live):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting.popleft()
            req.admit_step = step
            self.slots[slot] = req
            admitted.append((slot, req))
        return admitted

    def release(self, slot: int) -> None:
        assert self.slots[slot] is not None, slot
        self.slots[slot] = None

    # ---- views ----

    def live(self) -> list[tuple[int, ServeRequest]]:
        """Occupied slots in slot order — the decode batch composition."""
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    @property
    def depth(self) -> int:
        return len(self.waiting)

    @property
    def n_live(self) -> int:
        return sum(r is not None for r in self.slots)
