"""Same-key op combining — the elimination pass at admission (DESIGN.md §10).

The elimination (a,b)-trees line of work (PAPERS.md, Srivastava) shows
same-key operation pairs can annihilate *before* they reach the
structure: an insert immediately followed by a delete of the same key is
a no-op at the linearization boundary, and N identical lookups cost one
shard op plus a fan-out.  The serve scheduler stages every step's index
ops host-side and runs this pass once per step, so a hot key costs one
shard op instead of many.

``combine_ops`` operates under the pager's batch discipline (asserted at
apply time): within one staged batch an INSERT row always targets a key
absent from the index and a DELETE row a key present in it *or inserted
earlier in the same batch*.  Under that precondition an (INSERT k,
DELETE k) pair in batch order has no observable effect on any read after
the batch — the item is never visible at a step boundary — so dropping
both rows is a valid linearization.  Without the discipline the pair
would NOT be a no-op (an insert on a pre-existing key fails and the
delete then removes the *old* item), which is why this lives in the
serve layer and not inside the index.

Host-side numpy throughout: staged batches are small (a step's admission
+ growth + departures) and the pass runs once per step, off any jitted
path.
"""

from __future__ import annotations

import numpy as np

from repro.api.opbatch import OP_DELETE, OP_INSERT, OP_SEARCH

__all__ = ["combine_ops", "dedupe_lookups"]


def combine_ops(kinds, keys, payloads):
    """Annihilate (INSERT k, DELETE k) pairs and collapse duplicate
    SEARCH rows within one staged batch.

    Returns ``(kinds, keys, payloads, combined)`` with the surviving rows
    in their original batch order; ``combined`` counts the rows
    eliminated.  Per key, update rows cancel as a stack in batch order —
    a DELETE annihilates the closest preceding uncancelled INSERT (the
    admitted-then-departed-same-step case; repeated join/leave on one key
    cancels pairwise) — and SEARCH rows keep only the first occurrence.
    """
    kinds = np.asarray(kinds, np.int32)
    keys = np.asarray(keys)
    payloads = np.asarray(payloads, np.int32)
    n = len(kinds)
    keep = np.ones(n, bool)
    open_inserts: dict = {}   # key -> stack of uncancelled INSERT rows
    seen_search: set = set()
    for i in range(n):
        k = int(keys[i])
        if kinds[i] == OP_INSERT:
            open_inserts.setdefault(k, []).append(i)
        elif kinds[i] == OP_DELETE:
            stack = open_inserts.get(k)
            if stack:
                keep[stack.pop()] = False
                keep[i] = False
        else:
            assert kinds[i] == OP_SEARCH, int(kinds[i])
            if k in seen_search:
                keep[i] = False
            seen_search.add(k)
    combined = int(n - keep.sum())
    return kinds[keep], keys[keep], payloads[keep], combined


def dedupe_lookups(keys):
    """Collapse duplicate lookup keys to one shard op each.

    Returns ``(unique_keys, inverse, combined)``: probe ``unique_keys``
    once, then ``result[inverse]`` restores the per-caller fan-out.
    ``combined`` counts the lookups eliminated."""
    keys = np.asarray(keys)
    uniq, inverse = np.unique(keys, return_inverse=True)
    return uniq, inverse, int(len(keys) - len(uniq))
