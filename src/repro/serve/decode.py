"""Model-side decode machinery shared by every serve loop (DESIGN.md §10).

Extracted from the legacy lockstep engine so the scheduler and the
compat engine drive the exact same compute: per-layer parameter
unstacking, dense prefill with K/V scatter into allocated pages, and the
single paged decode step (per layer: scatter the new token's K/V into
each sequence's tail page slot, then run the Pallas paged
decode-attention kernel over the block table).

Everything here is pure over its inputs — no pager, no queue, no index.
The scheduler owns *which* lanes decode; this module owns *how* a lane's
tokens turn into logits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.delta_paged_attention import paged_decode_attention
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers.attention import attn_out, qkv_proj
from repro.models.layers.basic import (
    embed_apply,
    logits_apply,
    mlp_apply,
    rmsnorm_apply,
)
from repro.models.layers.moe import moe_apply


def layer_params(cfg: ModelConfig, params):
    """Unstack scan-stacked params into a per-layer list."""
    n_pro, period, reps = T._layout(cfg)
    out = list(params["prologue"])
    for r in range(reps):
        for j in range(period):
            out.append(jax.tree.map(lambda x: x[r], params["slots"][j]))
    return out


def prefill_to_pages(cfg: ModelConfig, params, page_size: int,
                     k_pages, v_pages, prompt, pages):
    """Dense prefill of one prompt, K/V scattered into ``pages``.

    Returns (k_pages, v_pages, seq_len, first_token) — the first decoded
    token is the argmax over the prompt's last logit, exactly the legacy
    engine's submit-time behavior."""
    toks = jnp.asarray(prompt)[None]
    s = toks.shape[1]
    caches = T.init_caches(cfg, 1, -(-s // page_size) * page_size)
    logits, caches = T.prefill(params, cfg, toks, caches)
    # flatten slot caches to per-layer order
    n_pro, period, reps = T._layout(cfg)
    layer_caches = list(caches["prologue"])
    for r in range(reps):
        for j in range(period):
            layer_caches.append(
                jax.tree.map(lambda x: x[r], caches["slots"][j]))
    for li, c in enumerate(layer_caches):
        k = c["k"][0]  # (Smax, KVH, HD)
        v = c["v"][0]
        for bi, page in enumerate(pages):
            sl = slice(bi * page_size, (bi + 1) * page_size)
            k_pages = k_pages.at[li, page].set(k[sl])
            v_pages = v_pages.at[li, page].set(v[sl])
    return k_pages, v_pages, s, int(jnp.argmax(logits[0, -1]))


def paged_decode_step(params, cfg: ModelConfig, layer_params, tokens,
                      k_pages, v_pages, block_tables, lengths, page_size):
    """One decode step over paged caches: per layer, scatter the new token's
    K/V into each sequence's tail page slot, then run the Pallas paged
    decode-attention kernel over the block table."""
    x = embed_apply(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = lengths[:, None].astype(jnp.int32)
    b = tokens.shape[0]
    rows = jnp.arange(b)
    tail_page = block_tables[rows, lengths // page_size]
    tail_off = lengths % page_size
    for li, lp in enumerate(layer_params):
        kinds = (cfg.layer_kind(li), cfg.ffn_kind(li))
        h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
        q, k, v = qkv_proj(lp["mixer"], cfg, h, positions)
        k_pages = k_pages.at[li, tail_page, tail_off].set(
            k[:, 0].astype(k_pages.dtype))
        v_pages = v_pages.at[li, tail_page, tail_off].set(
            v[:, 0].astype(v_pages.dtype))
        o = paged_decode_attention(
            q[:, 0], k_pages[li], v_pages[li], block_tables, lengths + 1)
        x = x + attn_out(lp["mixer"], o[:, None])
        if "ffn" in lp:
            h2 = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
            if kinds[1] == "moe":
                x = x + moe_apply(lp["ffn"], cfg, h2)
            else:
                x = x + mlp_apply(lp["ffn"], h2)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = logits_apply(params["embed"], x, cfg.logits_softcap)
    return logits, k_pages, v_pages
