"""Synthesized sustained mixed arrival traces (DESIGN.md §10).

A trace is the scheduler's workload: per step, how many requests arrive
(Bernoulli-thinned Poisson-ish arrivals with bursts), their prompt
lengths and decode budgets, which earlier requests cancel mid-flight,
and which sequences the read-side probe traffic references (zipfian —
the hot-key shape the op-combining pass exists for).

Everything is precomputed from one seed so a trace replays identically
across engines and processes (the churn-parity test and the
``serve_trace`` benchmark replay the same plan).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StepPlan", "synth_trace"]


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """One step's workload: arrivals [(prompt, max_new)], indices (into
    the submission order) of requests cancelling this step, and probe
    references (indices into the submission order, zipf-skewed)."""

    arrivals: list
    cancels: list
    probe_refs: np.ndarray


def synth_trace(steps: int, seed: int = 0, *, arrive_p: float = 0.7,
                burst: int = 2, prompt_lens=(3, 17), max_new=(4, 12),
                cancel_p: float = 0.0, probes_per_step: int = 0,
                zipf_a: float = 1.3, vocab: int = 128) -> list[StepPlan]:
    """Build a ``steps``-long replayable plan.

    arrive_p / burst:   each step draws Binomial(burst, arrive_p) arrivals.
    prompt_lens/max_new: inclusive [lo, hi) ranges per request.
    cancel_p:           per step, probability one not-yet-finished earlier
                        request cancels (uniform over submissions so far).
    probes_per_step:    zipf(zipf_a)-ranked references into the submission
                        order — duplicates are the point.
    """
    rng = np.random.default_rng(seed)
    plans = []
    submitted = 0
    for _ in range(steps):
        n_arrive = int(rng.binomial(burst, arrive_p))
        arrivals = []
        for _ in range(n_arrive):
            plen = int(rng.integers(*prompt_lens))
            arrivals.append(
                (rng.integers(1, vocab, size=plen).astype(np.int32),
                 int(rng.integers(*max_new))))
        cancels = []
        if submitted and rng.random() < cancel_p:
            cancels.append(int(rng.integers(0, submitted)))
        submitted += n_arrive
        if probes_per_step and submitted:
            refs = np.minimum(rng.zipf(zipf_a, size=probes_per_step) - 1,
                              submitted - 1).astype(np.int64)
        else:
            refs = np.zeros((0,), np.int64)
        plans.append(StepPlan(arrivals, cancels, refs))
    return plans
