"""String-keyed backend registry + the ``make_index`` factory.

``register_backend`` installs a ``BackendSpec`` under a name;
``make_index("deltatree", initial=keys, height=7, ...)`` builds the
backend's (cfg, state) pair and wraps it in an ``Index`` handle.  New
comparison structures (non-blocking interpolation search trees,
elimination (a,b)-trees, ...) plug in as registry entries — no new façade,
no call-site changes.
"""

from __future__ import annotations

from repro.api.index import BackendSpec, Index, IndexSpec

_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(spec: BackendSpec, *, overwrite: bool = False) -> BackendSpec:
    """Install ``spec`` under ``spec.name``; re-registration must opt in."""
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def supported_maintenance(backend: str) -> tuple[str, ...]:
    """Maintenance policy *kinds* ``backend`` accepts via ``maintenance=``.

    ``("*",)`` expands to every kind the scheduler knows
    (``repro.maintenance.KINDS``); literal entries pass through."""
    spec = get_backend(backend)
    if "*" not in spec.maintenance:
        return spec.maintenance
    from repro.maintenance import KINDS

    literal = [m for m in spec.maintenance if m != "*"]
    return tuple(dict.fromkeys(literal + list(KINDS)))


def supported_engines(backend: str) -> tuple[str, ...]:
    """Live SearchEngine names ``backend`` accepts via ``engine=``.

    A declared ``"*"`` entry expands to the ``repro.core.engine`` registry
    *at call time* (engines registered after import are selectable);
    literal names pass through unchanged, so a backend with a private
    read path is pinned to exactly what it declared."""
    spec = get_backend(backend)
    if "*" not in spec.engines:
        return spec.engines
    from repro.core.engine import available_engines

    literal = [e for e in spec.engines if e != "*"]
    return tuple(dict.fromkeys(literal + available_engines()))


def make_index(backend: str = "deltatree", *, initial=None, payloads=None,
               engine: str | None = None, maintenance: str | None = None,
               **kwargs) -> Index:
    """Build an Index: ``backend`` picks the registry entry, ``initial``
    (unique keys) and ``payloads`` seed a bulk build (empty when None),
    ``engine`` selects the read-path SearchEngine ("scalar" / "lockstep";
    validated against the backend's declared ``engines``; the sentinel
    ``"auto"`` resolves to the committed bench-table winner for this
    backend + execution mode first — ``core.engine.resolve_engine``),
    ``maintenance`` the scheduler policy ("eager" / "deferred" /
    "budgeted:K"; validated against the backend's declared policy kinds),
    remaining kwargs go to the backend's config (e.g. ``height=7`` or a
    prebuilt ``cfg=...``)."""
    from repro.maintenance import parse_policy

    spec = get_backend(backend)
    if engine == "auto":
        from repro.core.engine import resolve_engine

        engine = resolve_engine(engine, backend)
        if engine not in supported_engines(backend):
            engine = "scalar"  # table winner the backend can't run
    if engine is not None:
        engines = supported_engines(backend)
        if engine not in engines:
            raise ValueError(
                f"backend {backend!r} supports engines {engines}, "
                f"not {engine!r}")
        if spec.engines != ("scalar",):
            # engine-aware backends thread the name into their TreeConfig;
            # single-engine backends just validated the default above
            kwargs["engine"] = engine
    if maintenance is not None:
        pol = parse_policy(maintenance)   # ValueError on garbage specs
        kinds = supported_maintenance(backend)
        if pol.kind not in kinds:
            raise ValueError(
                f"backend {backend!r} supports maintenance policies "
                f"{kinds}, not {maintenance!r}")
        if spec.maintenance != ("eager",):
            kwargs["maintenance"] = str(pol)
    cfg, state = spec.make(initial, payloads, **kwargs)
    ix = Index(IndexSpec(backend=spec, cfg=cfg), state)
    if ix.engine not in supported_engines(backend):
        # catches engine typos smuggled in via a prebuilt cfg= (e.g.
        # TreeConfig(engine=...) / PagerConfig.engine) at construction
        # time instead of as a KeyError at the first read
        raise ValueError(
            f"backend {backend!r} config names engine {ix.engine!r}; "
            f"supported: {supported_engines(backend)}")
    # same early validation for policies smuggled in via a prebuilt cfg=
    ix_pol = parse_policy(ix.maintenance)
    if ix_pol.kind not in supported_maintenance(backend):
        raise ValueError(
            f"backend {backend!r} config names maintenance policy "
            f"{ix.maintenance!r}; supported kinds: "
            f"{supported_maintenance(backend)}")
    if payloads is not None and not ix.capability.map_mode:
        raise ValueError(
            f"backend {backend!r} with {ix.capability} stores no payloads; "
            f"drop payloads= or configure map mode (e.g. payload_bits > 0)")
    return ix
