"""String-keyed backend registry + the ``make_index`` factory.

``register_backend`` installs a ``BackendSpec`` under a name;
``make_index("deltatree", initial=keys, height=7, ...)`` builds the
backend's (cfg, state) pair and wraps it in an ``Index`` handle.  New
comparison structures (non-blocking interpolation search trees,
elimination (a,b)-trees, ...) plug in as registry entries — no new façade,
no call-site changes.
"""

from __future__ import annotations

from repro.api.index import BackendSpec, Index, IndexSpec

_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(spec: BackendSpec, *, overwrite: bool = False) -> BackendSpec:
    """Install ``spec`` under ``spec.name``; re-registration must opt in."""
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def make_index(backend: str = "deltatree", *, initial=None, payloads=None,
               **kwargs) -> Index:
    """Build an Index: ``backend`` picks the registry entry, ``initial``
    (unique keys) and ``payloads`` seed a bulk build (empty when None),
    remaining kwargs go to the backend's config (e.g. ``height=7`` or a
    prebuilt ``cfg=...``)."""
    spec = get_backend(backend)
    cfg, state = spec.make(initial, payloads, **kwargs)
    ix = Index(IndexSpec(backend=spec, cfg=cfg), state)
    if payloads is not None and not ix.capability.map_mode:
        raise ValueError(
            f"backend {backend!r} with {ix.capability} stores no payloads; "
            f"drop payloads= or configure map mode (e.g. payload_bits > 0)")
    return ix
