"""The Index handle: one method-style surface over every backend.

An ``Index`` is (static spec, dynamic state):

- ``spec`` — the registered ``BackendSpec`` (a table of pure functions)
  plus the backend's hashable static config.  Static: it is the pytree
  aux_data, so jitted functions closing over an ``Index`` specialize on
  backend + config exactly like they specialize on ``TreeConfig`` today.
- ``state`` — the backend's array state (a ``DeltaTree``, ``Forest``,
  ``SortedArrayState``, ...).  Dynamic: it is the pytree child, so an
  ``Index`` flows through ``jit`` / ``donate_argnums`` / ``shard_map``.

Methods delegate through the spec; ``capability`` says which ones a
backend supports (``CapabilityError`` otherwise).  ``insert_delete``
returns a *new* handle — backends may donate the old state's buffers, so
callers must rebind: ``ix, res = ix.insert_delete(batch)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.opbatch import OpBatch


@dataclasses.dataclass(frozen=True)
class Capability:
    """What an Index backend supports (conformance tests skip on these)."""

    map_mode: bool = False    # key -> payload lookups (else set semantics)
    successor: bool = False   # ordered successor queries
    sharded: bool = False     # state fans out over a device mesh
    updates: bool = True      # insert_delete supported at all
    deferred_maintenance: bool = False  # non-eager policies + flush()
    fused_forest: bool = False  # sharded reads share one fused frontier
    #                             (engine provides forest_batch + enabled)
    range_scan: bool = False  # ordered range pages (range_scan + cursors)
    successor_k: bool = False  # bulk k-successor reads (successor_k)


class CapabilityError(NotImplementedError):
    """Raised when an Index method is not in the backend's Capability."""


def cfg_attr(cfg, name: str, default=None):
    """Probe a config knob on ``cfg`` or its nested ``cfg.tree`` (the
    forest/pager configs wrap a TreeConfig) — the one resolution rule for
    ``engine`` / ``maintenance`` / ``q_tile`` style knobs."""
    v = getattr(cfg, name, None)
    if v is None:
        v = getattr(getattr(cfg, "tree", None), name, None)
    return default if v is None else v


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Registry entry: a table of pure functions over (cfg, state).

    Required hooks: ``make``, ``capability``, ``search``, ``update``,
    ``live_items``, ``size``.  Optional hooks may be None and are gated by
    ``capability(cfg)``: ``lookup`` (map_mode), ``successor``.  ``touch``
    (ideal-cache touch traces, Table 1) and ``alloc_failed`` (sticky
    arena-exhaustion flag) are optional diagnostics.

    ``engines`` lists the SearchEngine names the backend's read path can
    run under.  The special entry ``"*"`` means the backend dispatches
    reads through the ``repro.core.engine`` registry and accepts every
    engine registered there *at selection time* (so engines registered
    after import are selectable) — the ΔTree-core backends declare this.
    Single-read-path backends keep the default ``("scalar",)`` and
    ``make_index(..., engine=)`` rejects anything else; a backend with
    its own private engines declares them literally.  Resolve with
    ``repro.api.supported_engines``.

    ``maintenance`` analogously lists the scheduler policy *kinds*
    (``repro.maintenance.KINDS``) the backend accepts via
    ``make_index(maintenance=)``; ``("*",)`` = every kind the scheduler
    knows.  ``update`` returns a third element — a ``MaintenanceStats``
    pytree, or None for backends without a maintenance scheduler — and
    ``flush`` (optional) drains deferred maintenance to fixpoint.
    """

    name: str
    make: Callable[..., tuple[Any, Any]]        # (initial, payloads, **kw)
    capability: Callable[[Any], Capability]     # cfg -> Capability
    search: Callable[..., Any]                  # (cfg, state, keys) -> (found, hops)
    update: Callable[..., Any]                  # (cfg, state, OpBatch) -> (state, results, stats|None)
    live_items: Callable[..., Any]              # (cfg, state) -> [(key, payload)]
    size: Callable[..., int]                    # (cfg, state) -> int
    lookup: Callable[..., Any] | None = None    # (cfg, state, keys) -> (found, payload, hops)
    successor: Callable[..., Any] | None = None  # (cfg, state, keys) -> (found, succ)
    scan: Callable[..., Any] | None = None      # (cfg, state, starts, his, max_items)
    #                                             -> (keys, payloads, n, hops, more);
    #                                             starts EXCLUSIVE / his INCLUSIVE,
    #                                             (K, max_items) rows ascending,
    #                                             zero-padded past n; hops 0 for
    #                                             backends with no tree walk
    successor_k: Callable[..., Any] | None = None  # (cfg, state, keys, k) -> same contract
    touch: Callable[..., Any] | None = None     # (cfg, state) -> (key -> [flat indices])
    alloc_failed: Callable[..., bool] | None = None  # (cfg, state) -> bool
    flush: Callable[..., Any] | None = None     # (cfg, state) -> (state, stats)
    engines: tuple[str, ...] = ("scalar",)      # selectable read engines
    maintenance: tuple[str, ...] = ("eager",)   # selectable policy kinds


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Hashable static half of an Index (pytree aux_data)."""

    backend: BackendSpec
    cfg: Any


class Index:
    """Handle over one backend instance. Pytree: state child, spec static."""

    __slots__ = ("spec", "state")

    def __init__(self, spec: IndexSpec, state: Any):
        object.__setattr__(self, "spec", spec)
        object.__setattr__(self, "state", state)

    def __setattr__(self, name, value):
        raise AttributeError(
            "Index is immutable; rebind the handle returned by insert_delete")

    def __repr__(self):
        return (f"Index(backend={self.spec.backend.name!r}, "
                f"cfg={self.spec.cfg!r})")

    # ---- static introspection ----

    @property
    def backend(self) -> str:
        return self.spec.backend.name

    @property
    def cfg(self) -> Any:
        return self.spec.cfg

    @property
    def capability(self) -> Capability:
        return self.spec.backend.capability(self.spec.cfg)

    @property
    def engine(self) -> str:
        """Active SearchEngine name ("scalar" for single-engine backends)."""
        return cfg_attr(self.spec.cfg, "engine") or "scalar"

    @property
    def maintenance(self) -> str:
        """Active maintenance policy string ("eager" when the backend has
        no maintenance scheduler)."""
        return cfg_attr(self.spec.cfg, "maintenance") or "eager"

    @property
    def collect_stats(self) -> bool:
        """True when this handle's hop-bearing reads return a trailing
        ``repro.obs.stats.ReadStats`` (``TreeConfig.collect_stats``;
        always False for backends without the knob)."""
        return bool(cfg_attr(self.spec.cfg, "collect_stats", False))

    def _require(self, flag: str, hook) -> None:
        if not getattr(self.capability, flag) or hook is None:
            raise CapabilityError(
                f"backend {self.backend!r} does not support {flag!r} "
                f"(capability: {self.capability})")

    # ---- wait-free reads ----

    def search(self, keys: jax.Array):
        """Membership on the current snapshot. Returns (found[K], hops[K])
        — plus a trailing ``ReadStats`` when ``self.collect_stats``."""
        return self.spec.backend.search(self.spec.cfg, self.state, keys)

    def lookup(self, keys: jax.Array):
        """Map-mode read. Returns (found[K], payload[K], hops[K]) — plus
        a trailing ``ReadStats`` when ``self.collect_stats``."""
        self._require("map_mode", self.spec.backend.lookup)
        return self.spec.backend.lookup(self.spec.cfg, self.state, keys)

    def successor(self, keys: jax.Array):
        """Smallest stored key strictly greater. Returns (found[K], succ[K])."""
        self._require("successor", self.spec.backend.successor)
        return self.spec.backend.successor(self.spec.cfg, self.state, keys)

    def range_scan(self, lo: int, hi: int, *, max_items: int = 128,
                   cursor: "ScanCursor | None" = None) -> "ScanResult":
        """One ordered page of the live set: up to ``max_items`` (key,
        payload) rows with ``lo <= key <= hi``, ascending.  Host-facing
        (returns numpy views).  When the page fills before the range is
        exhausted, ``result.more`` is True and ``result.cursor`` resumes
        the next page: ``ix.range_scan(lo, hi, cursor=result.cursor)``
        (the cursor's bounds override ``lo``/``hi``).  Each page reads
        the *current* snapshot — concurrent updates between pages are
        seen from their page boundary onward, like any wait-free read."""
        from repro.core import layout
        from repro.core.scan import ScanCursor, ScanResult

        self._require("range_scan", self.spec.backend.scan)
        if cursor is not None:
            lo, hi = cursor.last_key + 1, cursor.hi
        hi = min(int(hi), layout.KEY_MAX)
        start = jnp.asarray([max(int(lo) - 1, 0)], jnp.int32)
        his = jnp.asarray([hi], jnp.int32)
        ks, ps, n, _, more = self.spec.backend.scan(
            self.spec.cfg, self.state, start, his, max_items)
        count = int(n[0])
        truncated = bool(more[0]) and count > 0
        keys = np.asarray(ks[0])[:count]
        pays = np.asarray(ps[0])[:count]
        cur = (ScanCursor(last_key=int(keys[-1]), hi=hi)
               if truncated else None)
        return ScanResult(keys=keys, payloads=pays, more=truncated,
                          cursor=cur)

    def successor_k(self, keys: jax.Array, k: int):
        """Bulk ordered read: per query, the ``k`` smallest live keys
        strictly greater.  Returns (keys (K, k) int32 ascending rows,
        payloads (K, k) int32, n (K,) int32, hops (K,) int32, more (K,)
        bool) — rows are zero-padded past ``n``; ``more`` marks queries
        with further successors beyond the ``k`` returned; ``hops`` is 0
        for backends with no tree walk."""
        self._require("successor_k", self.spec.backend.successor_k)
        return self.spec.backend.successor_k(
            self.spec.cfg, self.state, keys, k)

    # ---- updates ----

    def insert_delete(self, batch: OpBatch):
        """Apply one OpBatch in batch order. Returns (new Index, results[K]).

        OP_SEARCH rows are no-ops with result False.  The old handle's
        state may be donated — always rebind to the returned Index.
        (`update` is the same call keeping the MaintenanceStats.)
        """
        ix, results, _ = self.update(batch)
        return ix, results

    def update(self, batch: OpBatch):
        """`insert_delete` returning telemetry: (new Index, results[K],
        MaintenanceStats | None) — stats is None for backends without a
        maintenance scheduler (baselines)."""
        self._require("updates", self.spec.backend.update)
        state, results, stats = self.spec.backend.update(
            self.spec.cfg, self.state, batch)
        return Index(self.spec, state), results, stats

    def flush(self):
        """Drain pending maintenance to fixpoint (restores invariant I5
        after ``deferred``/``budgeted`` update batches).  Returns
        (new Index, MaintenanceStats | None); a no-op (stats None) for
        backends without a maintenance scheduler.  The old handle's state
        may be donated — always rebind."""
        if self.spec.backend.flush is None:
            return self, None
        state, stats = self.spec.backend.flush(self.spec.cfg, self.state)
        return Index(self.spec, state), stats

    # ---- host-side diagnostics ----

    def size(self) -> int:
        """Number of live keys (host-side)."""
        return int(self.spec.backend.size(self.spec.cfg, self.state))

    def live_items(self) -> list[tuple[int, int]]:
        """All live (key, payload) pairs in ascending GLOBAL key order
        (host-side, for tests).  The ordering is a contract, not a
        convenience: sharded backends must return split-order shard
        outputs concatenated (shard order == key order), so this list is
        the conformance oracle `range_scan`/`successor_k` pages are
        checked against — a full scan replays ``live_items`` exactly."""
        return list(self.spec.backend.live_items(self.spec.cfg, self.state))

    def touch_fn(self):
        """Host touch-trace fn (ideal-cache transfer counting) or None."""
        if self.spec.backend.touch is None:
            return None
        return self.spec.backend.touch(self.spec.cfg, self.state)

    def alloc_failed(self) -> bool:
        """Sticky arena-exhaustion flag (False for unbounded backends)."""
        if self.spec.backend.alloc_failed is None:
            return False
        return bool(self.spec.backend.alloc_failed(self.spec.cfg, self.state))


def _flatten(ix: Index):
    return (ix.state,), ix.spec


def _unflatten(spec: IndexSpec, children) -> Index:
    return Index(spec, children[0])


jax.tree_util.register_pytree_node(Index, _flatten, _unflatten)
