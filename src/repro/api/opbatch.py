"""OpBatch — first-class batched op representation for the Index API.

One SPMD step applies one ``OpBatch``: ``kinds[i]`` says what op row ``i``
is (OP_SEARCH rows are no-ops inside ``insert_delete`` — they exist so a
mixed workload batch can ride one fixed-shape update step), ``keys[i]`` the
int32 key, ``payloads[i]`` the int32 payload (ignored by set-mode
backends).  An ``OpBatch`` is a plain NamedTuple of arrays, so it is a
pytree and can be built, split, and consumed under ``jit`` / ``vmap`` /
``shard_map`` without host round-trips.

Row order is the linearization order: backends apply update rows in batch
order, and per-op results are reported in the same order.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

OP_SEARCH, OP_INSERT, OP_DELETE = 0, 1, 2


class OpBatch(NamedTuple):
    """A batch of dictionary ops in linearization order (all (K,) int32)."""

    kinds: jax.Array     # OP_SEARCH | OP_INSERT | OP_DELETE per row
    keys: jax.Array      # int32 keys (>= 1; 0 is the EMPTY sentinel)
    payloads: jax.Array  # int32 payloads (map-mode backends only)

    @classmethod
    def mixed(cls, kinds, keys, payloads=None) -> "OpBatch":
        """Wrap parallel (kinds, keys[, payloads]) arrays; payloads default 0."""
        keys = jnp.asarray(keys, jnp.int32)
        kinds = jnp.asarray(kinds, jnp.int32)
        if payloads is None:
            payloads = jnp.zeros_like(keys)
        return cls(kinds, keys, jnp.asarray(payloads, jnp.int32))

    @classmethod
    def inserts(cls, keys, payloads=None) -> "OpBatch":
        keys = jnp.asarray(keys, jnp.int32)
        return cls.mixed(jnp.full(keys.shape, OP_INSERT, jnp.int32), keys,
                         payloads)

    @classmethod
    def deletes(cls, keys) -> "OpBatch":
        keys = jnp.asarray(keys, jnp.int32)
        return cls.mixed(jnp.full(keys.shape, OP_DELETE, jnp.int32), keys)

    @property
    def size(self) -> int:
        return self.keys.shape[0]

    def mask_searches(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """(kinds, keys, is_update) with OP_SEARCH rows turned into no-op
        deletes of key 0 (never stored — 0 is the EMPTY sentinel).  For
        backends whose update kernel only understands insert/delete rows."""
        is_update = self.kinds != OP_SEARCH
        kinds = jnp.where(is_update, self.kinds, OP_DELETE)
        keys = jnp.where(is_update, self.keys, 0)
        return kinds, keys, is_update
