"""Built-in Index backends: deltatree, forest, sorted_array (+ the paper's
comparison structures pointer_bst and static_veb).

Each entry adapts one existing engine to the uniform ``BackendSpec``
contract — (cfg, state) construction, wait-free reads, batch-order
``OpBatch`` updates with OP_SEARCH rows as no-ops, host-side debug views.
Backends whose update kernel only understands insert/delete rows
(``sorted_array``, ``pointer_bst``, ``static_veb``) neutralize search rows
via ``OpBatch.mask_searches`` (a delete of key 0, which is never stored).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.index import BackendSpec, Capability
from repro.api.opbatch import OpBatch
from repro.api.registry import register_backend
from repro.core import baselines as BL
from repro.core import layout
from repro.core import deltatree as DT
from repro.core import transfers as TR
from repro.core.deltatree import TreeConfig
from repro.distributed import forest as F
from repro.distributed.forest import ForestConfig

_TREE_FIELDS = {f.name for f in dataclasses.fields(TreeConfig)}


def _as_cfg(cls, cfg, kw):
    if cfg is None:
        return cls(**kw)
    return dataclasses.replace(cfg, **kw) if kw else cfg


# --------------------------------------------------------------------------
# deltatree — the paper's structure (repro.core single arena)
# --------------------------------------------------------------------------


def _dt_make(initial, payloads, cfg=None, **kw):
    cfg = _as_cfg(TreeConfig, cfg, kw)
    if initial is None:
        return cfg, DT.empty(cfg)
    return cfg, DT.bulk_build(cfg, np.asarray(initial), payloads)


def _dt_update(cfg, t, batch: OpBatch):
    return DT.update_batch(cfg, t, batch.kinds, batch.keys, batch.payloads)


def _unpack_scan(cfg, out, n, hops, more):
    """Packed engine-scan rows -> the BackendSpec scan contract: (keys,
    payloads, n, hops, more) with (K, max_items) int32 rows zero-padded
    past ``n`` (0 is outside the key domain, so the pad is unambiguous)."""
    span = jnp.arange(out.shape[1], dtype=jnp.int32)
    valid = span[None, :] < n[:, None]
    keys = jnp.where(valid, cfg.key_of(out).astype(jnp.int32), 0)
    pays = jnp.where(valid, cfg.payload_of(out).astype(jnp.int32), 0)
    return keys, pays, n, hops, more


def _dt_scan(cfg, t, starts, his, max_items):
    return _unpack_scan(cfg, *DT.scan_jit(cfg, t, starts, his, max_items))


def _dt_successor_k(cfg, t, keys, k):
    return _unpack_scan(cfg, *DT.successor_k_jit(cfg, t, keys, k))


def _dt_size(cfg, t) -> int:
    # I5/I5': between steps every live item is a live leaf or a buffered
    # entry (never both — inserts dedup against the buffer), so
    # nlive+bcount over live arenas is exact under every maintenance
    # policy (cross-checked vs the oracle by the conformance suite).
    return int(jnp.sum(jnp.where(t.alive, t.nlive + t.bcount, 0)))


register_backend(BackendSpec(
    name="deltatree",
    make=_dt_make,
    capability=lambda cfg: Capability(
        map_mode=cfg.payload_bits > 0, successor=True, sharded=False,
        deferred_maintenance=True, range_scan=True, successor_k=True),
    search=DT.search_jit,
    lookup=DT.lookup_jit,
    update=_dt_update,
    successor=DT.successor_jit,
    scan=_dt_scan,
    successor_k=_dt_successor_k,
    live_items=DT.live_items,
    size=_dt_size,
    touch=TR.delta_touch_fn,
    alloc_failed=lambda cfg, t: bool(t.alloc_fail),
    flush=DT.flush,
    engines=("*",),   # reads dispatch on cfg.engine: any registered engine
    maintenance=("*",),   # scheduler dispatch on cfg.maintenance: any policy
))


# --------------------------------------------------------------------------
# forest — key-range-sharded DeltaForest (repro.distributed)
# --------------------------------------------------------------------------


def _forest_make(initial, payloads, cfg=None, splits=None, **kw):
    if cfg is None:
        tree_kw = {k: kw.pop(k) for k in list(kw) if k in _TREE_FIELDS}
        tree = kw.pop("tree", None)
        tree = (dataclasses.replace(tree, **tree_kw) if tree is not None
                else TreeConfig(**tree_kw))
        cfg = ForestConfig(tree=tree, **kw)
    else:
        # TreeConfig knobs (notably ``engine``) land on cfg.tree, the rest
        # on the ForestConfig itself
        tree_kw = {k: kw.pop(k) for k in list(kw) if k in _TREE_FIELDS}
        if tree_kw:
            cfg = dataclasses.replace(
                cfg, tree=dataclasses.replace(cfg.tree, **tree_kw))
        if kw:
            cfg = dataclasses.replace(cfg, **kw)
    if initial is None:
        return cfg, F.empty(cfg, splits)
    return cfg, F.bulk_build(cfg, np.asarray(initial), payloads, splits)


def _forest_fused(cfg: ForestConfig) -> bool:
    """True when this config's forest reads run the fused cross-shard
    frontier (``cfg.fused`` enabled AND the selected engine provides a
    ``forest_batch`` entry point — see ``repro.core.engine``)."""
    from repro.core import engine as E

    try:
        eng = E.get_engine(cfg.tree.engine)
    except KeyError:
        return False   # bad engine names fail later in make_index
    return bool(cfg.fused) and eng.forest_batch is not None


def _forest_update(cfg, f, batch: OpBatch):
    return F.update_batch(cfg, f, batch.kinds, batch.keys, batch.payloads)


def _forest_scan(cfg, f, starts, his, max_items):
    return _unpack_scan(
        cfg.tree, *F.scan_batch(cfg, f, starts, his, max_items=max_items))


def _forest_successor_k(cfg, f, keys, k):
    return _unpack_scan(cfg.tree, *F.successor_k(cfg, f, keys, k))


def _forest_size(cfg, f) -> int:
    t = f.trees
    return int(jnp.sum(jnp.where(t.alive, t.nlive + t.bcount, 0)))


register_backend(BackendSpec(
    name="forest",
    make=_forest_make,
    capability=lambda cfg: Capability(
        map_mode=cfg.tree.payload_bits > 0, successor=True, sharded=True,
        deferred_maintenance=True, fused_forest=_forest_fused(cfg),
        range_scan=True, successor_k=True),
    search=F.search_batch,
    lookup=F.lookup_batch,
    update=_forest_update,
    successor=F.successor_jit,
    scan=_forest_scan,
    successor_k=_forest_successor_k,
    live_items=F.live_items,
    size=_forest_size,
    alloc_failed=lambda cfg, f: F.alloc_failed(f),
    flush=F.flush,
    engines=("*",),   # per-shard reads dispatch on cfg.tree.engine
    maintenance=("*",),   # per-shard scheduler dispatch on cfg.tree.maintenance
))


# --------------------------------------------------------------------------
# sorted_array — binary search + sort-merge rebuild (core.baselines)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SortedArrayConfig:
    cap: int | None = None   # None: build auto-sizes to 2x the initial keys


def _sa_make(initial, payloads, cfg=None, **kw):
    cfg = _as_cfg(SortedArrayConfig, cfg, kw)
    vals = np.asarray(initial) if initial is not None else np.zeros(0, np.int32)
    return cfg, BL.SortedArray.build(vals, cap=cfg.cap)


@jax.jit
def _sa_search(state, keys):
    found = BL.SortedArray.search(state, keys)
    return found, jnp.zeros_like(keys)


def _sa_update(cfg, state, batch: OpBatch):
    kinds, keys, is_update = batch.mask_searches()
    state, res = BL.SortedArray.update(state, kinds, keys)
    return state, res & is_update, None  # no maintenance scheduler


@jax.jit
def _sa_successor(state, keys):
    keys = jnp.asarray(keys, jnp.int32)
    i = jnp.searchsorted(state.vals, keys, side="right").astype(jnp.int32)
    found = i < state.n
    safe = jnp.clip(i, 0, state.vals.shape[0] - 1)
    return found, jnp.where(found, state.vals[safe], 0)


def _sa_live_items(cfg, state):
    n = int(state.n)
    return [(int(v), 0) for v in np.asarray(state.vals)[:n]]


@functools.partial(jax.jit, static_argnums=3)
def _sa_scan(state, starts, his, max_items):
    """Dense-scan honesty baseline: the page is one searchsorted window
    per lane over the flat sorted array — no tree walk at all, which is
    exactly why it should win dense ranges in the scan sweep."""
    starts = jnp.asarray(starts, jnp.int32)
    his = jnp.asarray(his, jnp.int32)
    i0 = jnp.searchsorted(state.vals, starts, side="right").astype(jnp.int32)
    ic = jnp.searchsorted(state.vals, his, side="right").astype(jnp.int32)
    total = jnp.maximum(
        jnp.minimum(ic, state.n) - jnp.minimum(i0, state.n), 0)
    span = jnp.arange(max_items, dtype=jnp.int32)
    idx = jnp.clip(i0[:, None] + span[None, :], 0, state.vals.shape[0] - 1)
    valid = span[None, :] < total[:, None]
    keys = jnp.where(valid, state.vals[idx], 0)
    return (keys, jnp.zeros_like(keys),
            jnp.minimum(total, jnp.int32(max_items)),
            jnp.zeros_like(starts), total > max_items)


def _sa_successor_k(cfg, state, keys, k):
    keys = jnp.asarray(keys, jnp.int32)
    his = jnp.full(keys.shape, layout.KEY_MAX, jnp.int32)
    return _sa_scan(state, keys, his, k)


register_backend(BackendSpec(
    name="sorted_array",
    make=_sa_make,
    capability=lambda cfg: Capability(successor=True, range_scan=True,
                                      successor_k=True),
    search=lambda cfg, state, keys: _sa_search(state, keys),
    update=_sa_update,
    successor=lambda cfg, state, keys: _sa_successor(state, keys),
    scan=lambda cfg, state, starts, his, mi: _sa_scan(state, starts, his, mi),
    successor_k=_sa_successor_k,
    live_items=_sa_live_items,
    size=lambda cfg, state: int(state.n),
    touch=lambda cfg, state: BL.SortedArray.touch_fn(state),
))


# --------------------------------------------------------------------------
# pointer_bst — heap-allocated BST analog (no locality; core.baselines)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PointerBSTConfig:
    cap: int | None = None   # None: build auto-sizes to 2x the initial keys
    seed: int = 0


def _bst_make(initial, payloads, cfg=None, **kw):
    cfg = _as_cfg(PointerBSTConfig, cfg, kw)
    vals = np.asarray(initial) if initial is not None else np.zeros(0, np.int32)
    return cfg, BL.PointerBST.build(vals, cap=cfg.cap, seed=cfg.seed)


@jax.jit
def _bst_search(state, keys):
    return BL.PointerBST.search(state, keys), jnp.zeros_like(keys)


def _bst_update(cfg, state, batch: OpBatch):
    kinds, keys, is_update = batch.mask_searches()
    state, res = BL.PointerBST.update(state, kinds, keys)
    return state, res & is_update, None  # no maintenance scheduler


def _bst_live_items(cfg, state):
    n = int(state.n)
    vals = np.asarray(state.val)[:n]
    mark = np.asarray(state.mark)[:n]
    return [(int(v), 0) for v in np.sort(vals[~mark])]


register_backend(BackendSpec(
    name="pointer_bst",
    make=_bst_make,
    capability=lambda cfg: Capability(),
    search=lambda cfg, state, keys: _bst_search(state, keys),
    update=_bst_update,
    live_items=_bst_live_items,
    size=lambda cfg, state: int(state.n) - int(np.asarray(
        state.mark)[: int(state.n)].sum()),
    touch=lambda cfg, state: BL.PointerBST.touch_fn(state),
))


# --------------------------------------------------------------------------
# static_veb — VTMtree analog: search-optimal, whole-layout rebuild updates
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StaticVEBConfig:
    height: int | None = None   # None: minimal height for the build


def _sv_make(initial, payloads, cfg=None, **kw):
    cfg = _as_cfg(StaticVEBConfig, cfg, kw)
    vals = np.asarray(initial) if initial is not None else np.zeros(0, np.int32)
    return cfg, BL.StaticVEB.build(vals, height=cfg.height)


def _sv_search(cfg, state, keys):
    keys = jnp.asarray(keys, jnp.int32)
    return BL.StaticVEB.search(state, keys), jnp.zeros_like(keys)


def _sv_update(cfg, state, batch: OpBatch):
    kinds = np.asarray(batch.kinds)
    keys = np.asarray(batch.keys)
    mask = kinds != DT.OP_SEARCH
    res = np.zeros(len(keys), bool)
    if mask.any():
        state, sub = BL.StaticVEB.update(state, kinds[mask], keys[mask])
        if cfg.height is not None and state.height != cfg.height:
            # BL.StaticVEB.update rebuilds at minimal height; re-pin the
            # configured layout (build still grows h if the set outgrew it)
            state = BL.StaticVEB.build(BL.StaticVEB.to_sorted(state),
                                       height=cfg.height)
        res[mask] = np.asarray(sub)
    return state, jnp.asarray(res), None  # no maintenance scheduler


def _sv_live_items(cfg, state):
    return [(int(v), 0) for v in BL.StaticVEB.to_sorted(state)]


def _sv_scan(cfg, state, starts, his, max_items):
    """Host-side scan over the recovered sorted key set (the VTMtree
    analog rebuilds wholesale anyway, so its ordered reads are honest as
    a host replay of the layout's in-order traversal)."""
    vals = np.asarray(BL.StaticVEB.to_sorted(state), np.int32)
    starts = np.asarray(starts, np.int32)
    his = np.asarray(his, np.int32)
    i0 = np.searchsorted(vals, starts, side="right")
    ic = np.searchsorted(vals, his, side="right")
    total = np.maximum(ic - i0, 0)
    keys = np.zeros((starts.shape[0], max_items), np.int32)
    for j in range(starts.shape[0]):
        got = vals[i0[j]: ic[j]][:max_items]
        keys[j, : got.size] = got
    return (jnp.asarray(keys), jnp.zeros_like(jnp.asarray(keys)),
            jnp.asarray(np.minimum(total, max_items), jnp.int32),
            jnp.zeros((starts.shape[0],), jnp.int32),
            jnp.asarray(total > max_items))


def _sv_successor_k(cfg, state, keys, k):
    his = np.full(np.asarray(keys).shape, layout.KEY_MAX, np.int32)
    return _sv_scan(cfg, state, keys, his, k)


register_backend(BackendSpec(
    name="static_veb",
    make=_sv_make,
    capability=lambda cfg: Capability(range_scan=True, successor_k=True),
    search=_sv_search,
    update=_sv_update,
    scan=_sv_scan,
    successor_k=_sv_successor_k,
    live_items=_sv_live_items,
    size=lambda cfg, state: int(BL.StaticVEB.to_sorted(state).size),
    touch=lambda cfg, state: BL.StaticVEB.touch_fn(state),
))
