"""repro.api — one handle-based Index API over tree, forest, and baselines.

The uniform dictionary surface (DESIGN.md §5):

    ix = make_index("deltatree", initial=keys, height=7, max_dnodes=4096)
    found, hops = ix.search(queries)               # wait-free snapshot read
    ix, results = ix.insert_delete(OpBatch.inserts(new_keys))
    found, succ = ix.successor(queries)            # capability-gated
    ix, results, stats = ix.update(batch)          # + MaintenanceStats
    ix, stats = ix.flush()                         # drain deferred repairs

Backends register by name (``deltatree``, ``forest``, ``sorted_array``,
``pointer_bst``, ``static_veb``); ``Capability`` declares what each
supports.  ``Index`` is a pytree (state dynamic, spec static), ``OpBatch``
a NamedTuple of arrays — both flow through jit / shard_map.
"""

from repro.api.index import (
    BackendSpec,
    Capability,
    CapabilityError,
    Index,
    IndexSpec,
)
from repro.api.opbatch import OP_DELETE, OP_INSERT, OP_SEARCH, OpBatch
from repro.core.scan import ScanCursor, ScanResult
from repro.api.registry import (
    available_backends,
    get_backend,
    make_index,
    register_backend,
    supported_engines,
    supported_maintenance,
)
from repro.api import backends as _backends  # noqa: F401  (registers built-ins)

__all__ = [
    "BackendSpec",
    "Capability",
    "CapabilityError",
    "Index",
    "IndexSpec",
    "OpBatch",
    "OP_SEARCH",
    "OP_INSERT",
    "OP_DELETE",
    "ScanCursor",
    "ScanResult",
    "available_backends",
    "get_backend",
    "make_index",
    "register_backend",
    "supported_engines",
    "supported_maintenance",
]
