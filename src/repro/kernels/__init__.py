"""Pallas TPU kernels for the perf-critical hot spots.

- veb_search.py            — in-ΔNode vEB walk (the paper's search loop)
- delta_paged_attention.py — ΔTree-paged decode attention (serving path)
- ops.py                   — jit'd drivers/wrappers (public API)
- ref.py                   — pure-jnp oracles (test ground truth)

All kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with interpret=True against ref.py.
"""

from repro.kernels.ops import (
    default_interpret,
    delta_contains,
    delta_search,
    delta_walk,
    paged_decode_attention,
)

__all__ = ["delta_search", "delta_contains", "delta_walk",
           "default_interpret", "paged_decode_attention"]
