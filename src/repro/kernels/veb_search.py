"""Pallas TPU kernel: batched search inside ΔNodes (the paper's hot loop).

TPU mapping of the paper's locality argument (DESIGN.md §2): each query's
current ΔNode row (UB keys in vEB order, padded to a 128-lane multiple) is
gathered HBM→VMEM — one contiguous DMA per ΔNode, the dynamic-vEB pointer
hop realized as a data-dependent row gather.  Inside the kernel the whole
walk is VREG arithmetic: implicit complete-BST position math plus the
compile-time vEB permutation table, vectorized across the query tile.

The multi-ΔNode walk runs in lockstep rounds at the JAX level
(`ops.delta_walk`, the driver behind the ``"lockstep"`` SearchEngine):
gather rows for the query frontier, run this kernel (one full in-ΔNode
descent per query), hop to the child ΔNode, repeat.  Round count =
ΔNode-depth of the tree = the paper's O(log_B N) transfer bound — each
round is exactly one "memory transfer" per query.

Rows may be int32 (paper set mode) or int64 (map mode: ``key << bits |
payload`` packed values — ordering by packed value equals ordering by key,
so the walk is unchanged).  Besides the leaf triple the kernel reports the
per-ΔNode *successor candidate*: the minimum router passed on a left turn
(router = min of its right subtree, so it lower-bounds every key to the
query's right) — the lockstep successor folds these across rounds.

The serving-path sibling kernel (`delta_paged_attention`) shows the same
indirection done with scalar-prefetched `BlockSpec index_map` DMA instead
of a pre-gather; both are TPU-idiomatic realizations of a pointer hop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import layout
from repro.core.layout import EMPTY


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def walk_big(dtype) -> int:
    """Successor-candidate identity for a row dtype — must equal the tree's
    ROUTE_LEFT sentinel (int32: INT32_MAX; packed int64 map mode: 1 << 62)
    so candidate folding matches the scalar engine bit for bit."""
    if jnp.dtype(dtype) == jnp.int64:
        return 1 << 62
    return int(layout.ROUTE_LEFT)


def _kernel(height: int, big: int,
            pos_ref, q_ref, rows_ref, childrows_ref,
            leaf_val_ref, leaf_b_ref, next_dn_ref, cand_ref):
    h = height
    bottom0 = 2 ** (h - 1)
    pos = pos_ref[...]                                   # vEB permutation
    v = q_ref[...]                                       # (QT,)
    rows = rows_ref[...]                                 # (QT, UBp) VMEM

    def take(b):
        # per-lane gather rows[i, pos[b[i]]]
        return jnp.take_along_axis(rows, pos[b][:, None], axis=1)[:, 0]

    b = jnp.ones(v.shape, jnp.int32)
    cand = jnp.full(v.shape, big, rows.dtype)
    # fully unrolled H-1 level walk — pure VREG work on VMEM-resident rows
    for _ in range(h - 1):
        router = take(b)
        left = take(jnp.minimum(2 * b, 2 * bottom0 - 1))
        internal = (b < bottom0) & (left != EMPTY)
        go_right = v >= router
        # left turn: router lower-bounds the right subtree's minimum
        go_left = internal & ~go_right
        cand = jnp.where(go_left & (router < cand), router, cand)
        b = jnp.where(internal, 2 * b + go_right.astype(b.dtype), b)

    leaf_val = take(b)
    at_bottom = b >= bottom0
    slot = jnp.where(at_bottom, b - bottom0, 0)
    child = jnp.take_along_axis(childrows_ref[...], slot[:, None], axis=1)[:, 0]
    nxt = jnp.where(at_bottom, child, jnp.int32(-1))

    leaf_val_ref[...] = leaf_val
    leaf_b_ref[...] = b
    next_dn_ref[...] = nxt
    cand_ref[...] = cand


@functools.partial(jax.jit, static_argnames=("height", "q_tile", "interpret"))
def veb_walk_rows(rows: jax.Array, childrows: jax.Array, queries: jax.Array,
                  *, height: int, q_tile: int = 256, interpret: bool = True):
    """One full in-ΔNode descent per query.

    rows:      (K, UBp) int32/int64 — each query's current ΔNode row
               (vEB order; int64 = packed map-mode values)
    childrows: (K, CP)  int32 — matching bottom-slot child ids (-1 none)
    queries:   (K,)     packed, same dtype as rows; K % q_tile == 0

    Returns (leaf_val, leaf_b, next_dn, cand): leaf_val/cand in the row
    dtype, leaf_b/next_dn int32, each (K,).  next_dn = -1 when the walk
    ends inside this ΔNode; cand = min left-turn router (``walk_big`` when
    no left turn happened).
    """
    k = queries.shape[0]
    assert k % q_tile == 0, (k, q_tile)
    assert queries.dtype == rows.dtype, (queries.dtype, rows.dtype)
    n_tiles = k // q_tile
    ubp = rows.shape[1]
    cp = childrows.shape[1]
    big = walk_big(rows.dtype)

    pos = jnp.asarray(layout.veb_pos_table(height))
    posp = _round_up(pos.shape[0], 128)
    pos = jnp.pad(pos, (0, posp - pos.shape[0]))

    out_shape = [
        jax.ShapeDtypeStruct((k,), rows.dtype),   # leaf_val
        jax.ShapeDtypeStruct((k,), jnp.int32),    # leaf_b
        jax.ShapeDtypeStruct((k,), jnp.int32),    # next_dn
        jax.ShapeDtypeStruct((k,), rows.dtype),   # cand
    ]
    return pl.pallas_call(
        functools.partial(_kernel, height, big),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((posp,), lambda i: (0,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((q_tile, ubp), lambda i: (i, 0)),
            pl.BlockSpec((q_tile, cp), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((q_tile,), lambda i: (i,))] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(pos, queries, rows, childrows)


def _fused_kernel(height: int, big: int, max_rounds: int, m: int,
                  pos_ref, q_ref, root_ref, value_ref, child_ref,
                  leaf_val_ref, leaf_b_ref, final_dn_ref, hops_ref, cand_ref):
    """Persistent multi-round walk: the whole frontier loop of
    ``ops.delta_walk`` inside one kernel launch (per q_tile grid cell).

    The padded arena is resident (VMEM on TPU — the caller budgets it);
    each round is a *blind* in-ΔNode descent — one router gather per
    level, always routing right through EMPTY territory (sound by the
    connected-top-tree occupancy invariants; see
    ``ref.ref_delta_walk_fused``, the bit-exact oracle) — followed by the
    bottom-slot child hop.  Rounds stop when every lane is resolved, so
    shallow trees never pay dead iterations.
    """
    h = height
    bottom0 = 2 ** (h - 1)
    pos = pos_ref[...]
    v = q_ref[...]                                        # (QT,)
    vflat = value_ref[...].reshape(-1)                    # (M * UBp,)
    cflat = child_ref[...].reshape(-1)                    # (M * CP,)
    ub = value_ref.shape[1]
    cp = child_ref.shape[1]
    dn0 = root_ref[...]

    def cond(s):
        return jnp.any(~s[1]) & (s[7] < max_rounds)

    def body(s):
        dn, resolved, leaf_val, leaf_b, final_dn, hops, cand, rounds = s
        dnc = jnp.clip(dn, 0, m - 1)
        base = dnc * ub
        b = jnp.ones(v.shape, jnp.int32)
        lb = jnp.ones(v.shape, jnp.int32)          # last occupied position
        lv = jnp.zeros(v.shape, vflat.dtype)
        rcand = jnp.full(v.shape, big, vflat.dtype)
        routers, bs = [], []
        for _ in range(h):                          # blind descent
            router = jnp.take(vflat, base + pos[b])
            routers.append(router)
            bs.append(b)
            occ = router != EMPTY
            lb = jnp.where(occ, b, lb)
            lv = jnp.where(occ, router, lv)
            go_right = v >= router
            b = jnp.where(b < bottom0, 2 * b + go_right.astype(b.dtype), b)
        for router, bi in zip(routers, bs):         # post-hoc cand fold
            fold = ((router != EMPTY) & (bi != lb) & (v < router)
                    & (router < rcand))
            rcand = jnp.where(fold, router, rcand)
        at_bottom = lb >= bottom0
        slot = jnp.where(at_bottom, lb - bottom0, 0)
        ch = jnp.take(cflat, dnc * cp + slot)
        nxt = jnp.where(at_bottom, ch, jnp.int32(-1))
        act = ~resolved
        done_now = act & (nxt < 0)
        return (
            jnp.where(act & (nxt >= 0), nxt, dn),
            resolved | done_now,
            jnp.where(done_now, lv, leaf_val),
            jnp.where(done_now, lb, leaf_b),
            jnp.where(done_now, dn, final_dn),
            hops + act.astype(jnp.int32),
            jnp.where(act & (rcand < cand), rcand, cand),
            rounds + 1,
        )

    bigv = jnp.asarray(big, vflat.dtype)
    init = (
        dn0,
        v == bigv,                                  # sentinel lanes resolved
        jnp.zeros(v.shape, vflat.dtype),
        jnp.ones(v.shape, jnp.int32),
        dn0,
        jnp.zeros(v.shape, jnp.int32),
        jnp.full(v.shape, big, vflat.dtype),
        jnp.int32(0),
    )
    s = jax.lax.while_loop(cond, body, init)
    leaf_val_ref[...] = s[2]
    leaf_b_ref[...] = s[3]
    final_dn_ref[...] = s[4]
    hops_ref[...] = s[5]
    cand_ref[...] = s[6]


@functools.partial(jax.jit,
                   static_argnames=("height", "q_tile", "max_rounds",
                                    "interpret"))
def veb_walk_fused(value_p: jax.Array, child_p: jax.Array, roots: jax.Array,
                   queries: jax.Array, *, height: int, q_tile: int = 256,
                   max_rounds: int = 16, interpret: bool = True):
    """All walk rounds in one launch (grid over query tiles).

    value_p:  (M, UBp) padded arena rows (`pad_arena`), int32/int64
    child_p:  (M, CP)  padded bottom-slot child ids (-1 none)
    roots:    (K,)     int32 per-query frontier seeds
    queries:  (K,)     packed, same dtype as value_p; K % q_tile == 0

    Returns the full `ops.delta_walk` 5-tuple (leaf_val, leaf_b, final_dn,
    hops, cand), each (K,).  Sentinel queries (``walk_big``) are born
    resolved.  The whole arena is mapped into every grid cell — callers
    gate this path on the VMEM budget (`ops` falls back to the per-round
    driver / the compiled jnp mirror when it doesn't fit).
    """
    k = queries.shape[0]
    assert k % q_tile == 0, (k, q_tile)
    assert queries.dtype == value_p.dtype, (queries.dtype, value_p.dtype)
    n_tiles = k // q_tile
    m, ubp = value_p.shape
    cp = child_p.shape[1]
    big = walk_big(value_p.dtype)

    pos = jnp.asarray(layout.veb_pos_table(height))
    posp = _round_up(pos.shape[0], 128)
    pos = jnp.pad(pos, (0, posp - pos.shape[0]))

    out_shape = [
        jax.ShapeDtypeStruct((k,), value_p.dtype),   # leaf_val
        jax.ShapeDtypeStruct((k,), jnp.int32),       # leaf_b
        jax.ShapeDtypeStruct((k,), jnp.int32),       # final_dn
        jax.ShapeDtypeStruct((k,), jnp.int32),       # hops
        jax.ShapeDtypeStruct((k,), value_p.dtype),   # cand
    ]
    return pl.pallas_call(
        functools.partial(_fused_kernel, height, big, max_rounds, m),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((posp,), lambda i: (0,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((m, ubp), lambda i: (0, 0)),
            pl.BlockSpec((m, cp), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((q_tile,), lambda i: (i,))] * 5,
        out_shape=out_shape,
        interpret=interpret,
    )(pos, queries, roots, value_p, child_p)


def _scan_kernel(height: int, big: int, pmask: int, max_rounds: int,
                 max_out: int, mo_p: int, m: int,
                 pos_ref, start_ref, hi_ref, root_ref, value_ref, mark_ref,
                 child_ref, out_ref, n_ref, hops_ref, more_ref):
    """Persistent emit-cursor scan: the whole find/verify/emit loop of
    ``ops.delta_scan`` inside one kernel launch (per q_tile grid cell).

    Same blind-descent round structure as ``_fused_kernel``; each lane
    additionally carries a scan cursor, a FIND/VERIFY mode bit and an
    emit index into a VMEM-resident (QT, mo_p) output tile.  The exact
    pass logic is documented on the bit-exact oracle,
    ``ref.ref_delta_scan_fused``; ``mo_p`` is the lane-padded buffer
    width (emission is still capped at ``max_out``).
    """
    h = height
    bottom0 = 2 ** (h - 1)
    pos = pos_ref[...]
    starts = start_ref[...]                              # (QT,) packed
    his = hi_ref[...]
    dn0 = root_ref[...]
    vflat = value_ref[...].reshape(-1)                   # (M * UBp,)
    mflat = mark_ref[...].reshape(-1)
    cflat = child_ref[...].reshape(-1)
    ub = value_ref.shape[1]
    cp = child_ref.shape[1]
    bigv = jnp.asarray(big, vflat.dtype)
    pm = jnp.asarray(pmask, vflat.dtype)
    col = jnp.arange(mo_p, dtype=jnp.int32)[None, :]

    def cond(s):
        return jnp.any(~s[9]) & (s[10] < max_rounds)

    def body(s):
        (dn, verify, q, cursor, cand, out, n, hops, more, done, rounds) = s
        dnc = jnp.clip(dn, 0, m - 1)
        base = dnc * ub
        b = jnp.ones(q.shape, jnp.int32)
        lb = jnp.ones(q.shape, jnp.int32)          # last occupied position
        lv = jnp.zeros(q.shape, vflat.dtype)
        routers, bs = [], []
        for _ in range(h):                          # blind descent
            router = jnp.take(vflat, base + pos[b])
            routers.append(router)
            bs.append(b)
            occ = router != EMPTY
            lb = jnp.where(occ, b, lb)
            lv = jnp.where(occ, router, lv)
            go_right = q >= router
            b = jnp.where(b < bottom0, 2 * b + go_right.astype(b.dtype), b)
        rcand = jnp.full(q.shape, big, vflat.dtype)
        for router, bi in zip(routers, bs):         # post-hoc cand fold
            fold = ((router != EMPTY) & (bi != lb) & (q < router)
                    & (router < rcand))
            rcand = jnp.where(fold, router, rcand)
        at_bottom = lb >= bottom0
        slot = jnp.where(at_bottom, lb - bottom0, 0)
        ch = jnp.take(cflat, dnc * cp + slot)
        nxt = jnp.where(at_bottom, ch, jnp.int32(-1))
        act = ~done
        hopping = act & (nxt >= 0)
        res = act & (nxt < 0)
        cand = jnp.where(act & ~verify & (rcand < cand), rcand, cand)
        leaf_mark = jnp.take(mflat, base + pos[lb])
        leaf_live = (lv != EMPTY) & ~leaf_mark
        f_res = res & ~verify
        leaf_fold = f_res & leaf_live & (lv > cursor) & (lv < cand)
        cand = jnp.where(leaf_fold, lv, cand)
        f_none = f_res & ((cand == bigv) | (cand > his))
        pending = cand | pm
        to_verify = f_res & ~f_none
        v_res = res & verify
        hit = v_res & leaf_live & ((lv | pm) == q)
        can_emit = n < max_out
        emit = hit & can_emit
        full = hit & ~can_emit
        chase = v_res & ~hit
        out = jnp.where(emit[:, None] & (col == n[:, None]),
                        lv[:, None], out)
        back_to_find = emit | chase
        restart = to_verify | back_to_find
        return (
            jnp.where(hopping, nxt, jnp.where(restart, dn0, dn)),
            jnp.where(to_verify, True,
                      jnp.where(back_to_find, False, verify)),
            jnp.where(to_verify, pending, q),
            jnp.where(back_to_find, q, cursor),
            jnp.where(restart, bigv, cand),
            out,
            n + emit.astype(jnp.int32),
            hops + act.astype(jnp.int32),
            more | full,
            done | f_none | full,
            rounds + 1,
        )

    init = (
        dn0,
        jnp.zeros(starts.shape, jnp.bool_),
        starts,
        starts,
        jnp.full(starts.shape, big, vflat.dtype),
        jnp.full((starts.shape[0], mo_p), big, vflat.dtype),
        jnp.zeros(starts.shape, jnp.int32),
        jnp.zeros(starts.shape, jnp.int32),
        jnp.zeros(starts.shape, jnp.bool_),
        starts == bigv,                             # sentinel lanes done
        jnp.int32(0),
    )
    s = jax.lax.while_loop(cond, body, init)
    out_ref[...] = s[5]
    n_ref[...] = s[6]
    hops_ref[...] = s[7]
    more_ref[...] = s[8].astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("height", "q_tile", "max_rounds",
                                    "max_out", "pmask", "interpret"))
def veb_scan_fused(value_p: jax.Array, mark_p: jax.Array, child_p: jax.Array,
                   roots: jax.Array, starts: jax.Array, his: jax.Array, *,
                   height: int, max_out: int, pmask: int = 0,
                   q_tile: int = 256, max_rounds: int = 256,
                   interpret: bool = True):
    """All scan passes in one launch (grid over query tiles).

    value_p/mark_p: (M, UBp) padded arena rows + mark bits (`pad_arena` /
                    same padding), int32/int64 rows
    child_p:        (M, CP)  padded bottom-slot child ids (-1 none)
    roots:          (K,)     int32 per-lane frontier seeds
    starts/his:     (K,)     packed qpack bounds (start exclusive, hi
                    inclusive in key space); K % q_tile == 0; a start of
                    ``walk_big`` marks a pad lane (born done)

    Returns the `ops.delta_scan` 4-tuple (out (K, mo_p) packed with the
    lane-padded width ``mo_p = roundup(max_out, 128)`` — callers slice to
    ``max_out`` — n, hops, more(int32)), contract and bit-for-bit results
    documented on ``ref.ref_delta_scan_fused``.  The whole arena is
    mapped into every grid cell — same VMEM budget gate as
    ``veb_walk_fused``.
    """
    k = starts.shape[0]
    assert k % q_tile == 0, (k, q_tile)
    assert starts.dtype == value_p.dtype, (starts.dtype, value_p.dtype)
    n_tiles = k // q_tile
    m, ubp = value_p.shape
    cp = child_p.shape[1]
    big = walk_big(value_p.dtype)
    mo_p = _round_up(max_out, 128)

    pos = jnp.asarray(layout.veb_pos_table(height))
    posp = _round_up(pos.shape[0], 128)
    pos = jnp.pad(pos, (0, posp - pos.shape[0]))

    out_shape = [
        jax.ShapeDtypeStruct((k, mo_p), value_p.dtype),   # out
        jax.ShapeDtypeStruct((k,), jnp.int32),            # n
        jax.ShapeDtypeStruct((k,), jnp.int32),            # hops
        jax.ShapeDtypeStruct((k,), jnp.int32),            # more
    ]
    return pl.pallas_call(
        functools.partial(_scan_kernel, height, big, pmask, max_rounds,
                          max_out, mo_p, m),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((posp,), lambda i: (0,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((m, ubp), lambda i: (0, 0)),
            pl.BlockSpec((m, ubp), lambda i: (0, 0)),
            pl.BlockSpec((m, cp), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((q_tile, mo_p), lambda i: (i, 0)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(pos, starts, his, roots, value_p, mark_p, child_p)


def pad_arena(value: jax.Array, child: jax.Array):
    """Pad arena rows to 128-lane multiples for the kernel."""
    ubp = _round_up(value.shape[1], 128)
    cp = _round_up(child.shape[1], 128)
    value_p = jnp.pad(value, ((0, 0), (0, ubp - value.shape[1])))
    child_p = jnp.pad(child, ((0, 0), (0, cp - child.shape[1])),
                      constant_values=-1)
    return value_p, child_p


def fuse_arenas(value: jax.Array, child: jax.Array, root: jax.Array):
    """Concatenate stacked shard arenas into one base-offset arena view.

    value (S, M, UB) / child (S, M, CP) / root (S,) are S independent
    arenas whose ΔNode ids are arena-local.  The fused view is a single
    (S*M, ...) arena in which shard ``s``'s ids shift by ``s*M`` — the
    base offset is applied to child links and roots ONCE, here, never per
    walk round — so a multi-root `ops.delta_walk` (per-query ``root``
    seeds) can drive one shared frontier across every shard.  Child links
    of ``-1`` (none) are preserved; walks seeded at shard ``s``'s fused
    root can only ever reach shard ``s``'s rows (child links never cross
    arenas), so per-query results are bit-identical to S separate walks.

    Returns (fused_value (S*M, UB), fused_child (S*M, CP),
    fused_roots (S,) int32).
    """
    s, m = value.shape[0], value.shape[1]
    base = jnp.arange(s, dtype=jnp.int32) * jnp.int32(m)
    child = jnp.where(child >= 0, child + base[:, None, None], child)
    return (value.reshape((s * m,) + value.shape[2:]),
            child.reshape((s * m,) + child.shape[2:]),
            root.astype(jnp.int32) + base)
