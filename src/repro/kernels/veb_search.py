"""Pallas TPU kernel: batched search inside ΔNodes (the paper's hot loop).

TPU mapping of the paper's locality argument (DESIGN.md §2): each query's
current ΔNode row (UB keys in vEB order, padded to a 128-lane multiple) is
gathered HBM→VMEM — one contiguous DMA per ΔNode, the dynamic-vEB pointer
hop realized as a data-dependent row gather.  Inside the kernel the whole
walk is VREG arithmetic: implicit complete-BST position math plus the
compile-time vEB permutation table, vectorized across the query tile.

The multi-ΔNode walk runs in lockstep rounds at the JAX level
(`ops.delta_search`): gather rows for the query frontier, run this kernel
(one full in-ΔNode descent per query), hop to the child ΔNode, repeat.
Round count = ΔNode-depth of the tree = the paper's O(log_B N) transfer
bound — each round is exactly one "memory transfer" per query.

The serving-path sibling kernel (`delta_paged_attention`) shows the same
indirection done with scalar-prefetched `BlockSpec index_map` DMA instead
of a pre-gather; both are TPU-idiomatic realizations of a pointer hop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import layout
from repro.core.layout import EMPTY


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _kernel(height: int,
            pos_ref, q_ref, rows_ref, childrows_ref,
            leaf_val_ref, leaf_b_ref, next_dn_ref):
    h = height
    bottom0 = 2 ** (h - 1)
    pos = pos_ref[...]                                   # vEB permutation
    v = q_ref[...]                                       # (QT,)
    rows = rows_ref[...]                                 # (QT, UBp) VMEM

    def take(b):
        # per-lane gather rows[i, pos[b[i]]]
        return jnp.take_along_axis(rows, pos[b][:, None], axis=1)[:, 0]

    b = jnp.ones_like(v)
    # fully unrolled H-1 level walk — pure VREG work on VMEM-resident rows
    for _ in range(h - 1):
        router = take(b)
        left = take(jnp.minimum(2 * b, 2 * bottom0 - 1))
        internal = (b < bottom0) & (left != EMPTY)
        step = (v >= router).astype(b.dtype)
        b = jnp.where(internal, 2 * b + step, b)

    leaf_val = take(b)
    at_bottom = b >= bottom0
    slot = jnp.where(at_bottom, b - bottom0, 0)
    child = jnp.take_along_axis(childrows_ref[...], slot[:, None], axis=1)[:, 0]
    nxt = jnp.where(at_bottom, child, jnp.int32(-1))

    leaf_val_ref[...] = leaf_val
    leaf_b_ref[...] = b
    next_dn_ref[...] = nxt


@functools.partial(jax.jit, static_argnames=("height", "q_tile", "interpret"))
def veb_walk_rows(rows: jax.Array, childrows: jax.Array, queries: jax.Array,
                  *, height: int, q_tile: int = 256, interpret: bool = True):
    """One full in-ΔNode descent per query.

    rows:      (K, UBp) int32 — each query's current ΔNode row (vEB order)
    childrows: (K, CP)  int32 — matching bottom-slot child ids (-1 none)
    queries:   (K,)     int32, K % q_tile == 0

    Returns (leaf_val, leaf_b, next_dn), each (K,) int32; next_dn = -1 when
    the walk ends inside this ΔNode.
    """
    k = queries.shape[0]
    assert k % q_tile == 0, (k, q_tile)
    n_tiles = k // q_tile
    ubp = rows.shape[1]
    cp = childrows.shape[1]

    pos = jnp.asarray(layout.veb_pos_table(height))
    posp = _round_up(pos.shape[0], 128)
    pos = jnp.pad(pos, (0, posp - pos.shape[0]))

    out_shape = [jax.ShapeDtypeStruct((k,), jnp.int32)] * 3
    return pl.pallas_call(
        functools.partial(_kernel, height),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((posp,), lambda i: (0,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((q_tile, ubp), lambda i: (i, 0)),
            pl.BlockSpec((q_tile, cp), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((q_tile,), lambda i: (i,))] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(pos, queries, rows, childrows)


def pad_arena(value: jax.Array, child: jax.Array):
    """Pad arena rows to 128-lane multiples for the kernel."""
    ubp = _round_up(value.shape[1], 128)
    cp = _round_up(child.shape[1], 128)
    value_p = jnp.pad(value, ((0, 0), (0, ubp - value.shape[1])))
    child_p = jnp.pad(child, ((0, 0), (0, cp - child.shape[1])),
                      constant_values=-1)
    return value_p, child_p
