"""Pallas TPU kernel: ΔTree-paged decode attention (serving hot path).

The ΔTree serving index (serving/pager.py) resolves (seq, logical_block) →
physical page; this kernel consumes the resolved block table and DMAs *only
the pages a sequence owns* — the paper's locality thesis applied to the KV
cache: the transfer unit (one KV page) is sized to the VMEM block, and the
indirection is a scalar-prefetched pointer, exactly like a ΔNode hop.

Grid (B, KVH, MAXP): one (batch row, kv head, page) per step, accumulating
online softmax in VMEM scratch (flash-decoding style).  The block table and
sequence lengths ride in scalar-prefetch memory so the K/V `BlockSpec
index_map` can pick the physical page per grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(maxp: int, page_size: int, scale: float,
            # scalar prefetch
            bt_ref, len_ref,
            # inputs
            q_ref, k_ref, v_ref,
            # outputs
            o_ref,
            # scratch
            m_ref, l_ref, acc_ref):
    b = pl.program_id(0)
    p = pl.program_id(2)
    seq_len = len_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(p * page_size < seq_len)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)         # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)   # (PS, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)   # (PS, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                    # (G, PS)
        tok = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tok < seq_len, s, NEG_INF)
        m_old = m_ref[:, 0]                          # (G,)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        alpha = jnp.exp(m_old - m_new)               # (G,)
        pr = jnp.exp(s - m_new[:, None])             # (G, PS)
        l_new = alpha * l_ref[:, 0] + jnp.sum(pr, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(p == maxp - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


def paged_decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           block_tables: jax.Array, seq_lens: jax.Array,
                           *, interpret: bool | None = None) -> jax.Array:
    """ΔTree-paged GQA decode attention.

    q:            (B, QH, D)
    k/v_pages:    (NP, PS, KVH, D)
    block_tables: (B, MAXP) int32 (-1 = unused; clamped for DMA, masked in
                  compute via seq_lens)
    seq_lens:     (B,) int32
    Returns (B, QH, D) in q.dtype.

    ``interpret=None`` auto-resolves at call time like the search kernels
    (`ops.default_interpret`): compiled on TPU, interpret elsewhere —
    serving decode steps stop silently paying the interpreter tax on TPU.
    """
    from repro.kernels.ops import _resolve_interpret

    return _paged_decode_attention(q, k_pages, v_pages, block_tables,
                                   seq_lens,
                                   interpret=_resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("interpret",)
)
def _paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens,
                            *, interpret: bool):
    b, qh, d = q.shape
    np_, ps, kvh, _ = k_pages.shape
    maxp = block_tables.shape[1]
    g = qh // kvh
    assert g * kvh == qh
    scale = 1.0 / (d**0.5)

    bt_flat = jnp.maximum(block_tables, 0).reshape(-1)
    q4 = q.reshape(b, kvh, g, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, pi, bt, sl: (bi, hi, 0, 0)),
            pl.BlockSpec(
                (1, ps, 1, d),
                lambda bi, hi, pi, bt, sl: (bt[bi * maxp + pi], 0, hi, 0),
            ),
            pl.BlockSpec(
                (1, ps, 1, d),
                lambda bi, hi, pi, bt, sl: (bt[bi * maxp + pi], 0, hi, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda bi, hi, pi, bt, sl: (bi, hi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, maxp, ps, scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(bt_flat, seq_lens, q4, k_pages, v_pages)
    return out.reshape(b, qh, d)
