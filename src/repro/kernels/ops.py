"""jit'd public wrappers around the Pallas kernels.

- `delta_walk`         — multi-round lockstep walk: every active query
  descends its current ΔNode fully (one contiguous row DMA — the paper's
  "memory transfer"), hops to the child ΔNode, repeats until it lands on
  its leaf.  Reports per-query hop counts (= rounds active = ΔNodes
  visited) and the folded successor candidate.  ``root`` may be per-query
  (multi-root seeding over a `veb_search.fuse_arenas` view — the fused
  forest frontier, DESIGN.md §8).  This is the engine room of the
  ``"lockstep"`` SearchEngine (repro.core.engine).  Two drivers share the
  contract bit for bit:
    * fused (default): ALL rounds inside one launch —
      `veb_search.veb_walk_fused` (persistent Pallas kernel, arena
      resident per q_tile grid cell) where Pallas can lower it, else the
      XLA-compiled `kernels.ref.ref_delta_walk_fused`;
    * per-round (``fused=False``): the original
      pallas_call-inside-``lax.while_loop`` — one `veb_walk_rows` launch
      per frontier round; retained as the parity oracle and the TPU
      fallback when the arena outgrows the fused kernel's VMEM budget.
- `delta_search`       — legacy 3-tuple contract on top of `delta_walk`.
- `delta_contains`     — paper SEARCHNODE set semantics on top (mark bit +
  overflow buffer check).
- `paged_decode_attention` — re-exported from delta_paged_attention.

Execution-mode resolution (``interpret=None`` everywhere): Pallas compiled
on TPU, interpret mode elsewhere, overridable per call (``interpret=``) or
process-wide via ``REPRO_PALLAS_INTERPRET=0/1``.  Outside interpret mode
Pallas only lowers on TPU (and never for packed int64 rows), so every
compiled non-TPU walk routes through the XLA-compiled jnp mirrors
(`ref_delta_walk_fused` / `ref_veb_walk_rows`) — same round structure,
same bits, no interpreter tax.  ``max_rounds=None`` derives the round cap
from the arena geometry at trace time (`walk_round_cap`), so shallow
trees never carry the historical 64-round bound.
"""

from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp

from repro.core import layout
from repro.kernels.delta_paged_attention import paged_decode_attention  # noqa: F401
from repro.kernels.veb_search import (
    pad_arena, veb_scan_fused, veb_walk_fused, veb_walk_rows, walk_big,
)
from repro.obs import trace as TR


def default_interpret() -> bool:
    """Auto-detected Pallas mode: compiled on TPU, interpret elsewhere.

    ``REPRO_PALLAS_INTERPRET=1`` forces interpret mode (kernel debugging on
    TPU), ``=0`` forces compiled lowering; unset (or set empty) defers to
    the backend so TPU runs stop silently paying the interpreter tax."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip()
    if env:
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def default_fused() -> bool:
    """Walk-driver default: the fused single-launch walk everywhere
    (bit-identical to the per-round driver; the parity suite pins it).
    ``REPRO_PALLAS_FUSED=0`` flips the process to the per-round driver —
    the A/B knob `benchmarks/engine_compare.py` and kernel debugging
    use."""
    env = os.environ.get("REPRO_PALLAS_FUSED", "").strip()
    if env:
        return env.lower() not in ("0", "false", "no")
    return True


def _resolve_fused(fused: bool | None) -> bool:
    return default_fused() if fused is None else bool(fused)


def walk_round_cap(height: int, max_dnodes: int) -> int:
    """Trace-time walk round bound derived from the arena geometry,
    replacing the historical fixed ``max_rounds=64``.

    An arena of M ΔNodes holds at most ``M * 2**(height-1)`` leaves, so a
    *balanced* ΔNode tree is ``ceil(log2(M * leaf_cap) / (height-1))``
    ΔNodes deep; maintenance (Rebalance/Expand/Merge) keeps the tree
    within a constant factor of that, and the cap doubles the balanced
    depth and adds slack for overflow-chase hops mid-maintenance.  The
    structural depth assertion in ``check_invariants`` and the
    never-hit-the-cap test pin the bound; compiled fused kernels size
    their in-kernel loop with it, so shallow trees stop paying 64 dead
    iterations of lowered loop body.
    """
    leaf_cap = 2 ** (height - 1)
    balanced = math.ceil(
        math.log2(max(max_dnodes, 2) * leaf_cap) / max(height - 1, 1))
    return 2 * balanced + 8


def _resolve_max_rounds(max_rounds: int | None, height: int,
                        max_dnodes: int) -> int:
    if max_rounds is None:
        return walk_round_cap(height, max_dnodes)
    return int(max_rounds)


def _check_q_tile(tile: int, origin: str, lane_aligned: bool) -> int:
    """Shared q_tile validation: positive everywhere; the process-wide
    production knob (``REPRO_PALLAS_QTILE``) additionally requires a
    multiple of 128 so the compiled Pallas block shape stays lane-aligned.
    Explicit per-call tiles stay lenient — tests and interpret-mode runs
    legitimately use small tiles (16/64)."""
    tile = int(tile)
    bad = tile <= 0 or (lane_aligned and tile % 128)
    if bad:
        want = "positive multiple of 128" if lane_aligned else "positive"
        raise ValueError(f"q_tile must be {want}, got {tile} ({origin})")
    return tile


def default_q_tile(height: int | None = None,
                   payload_bits: int = 0) -> int:
    """Lockstep kernel query tile: ``REPRO_PALLAS_QTILE`` env override,
    else the autotuned height→tile table (`kernels.autotune` — the
    ``REPRO_PALLAS_AUTOTUNE`` cache file over the committed baked
    winners), else 256 (two VREG lanes' worth)."""
    env = os.environ.get("REPRO_PALLAS_QTILE", "").strip()
    if env:
        try:
            tile = int(env)
        except ValueError:
            raise ValueError(
                f"REPRO_PALLAS_QTILE must be an integer, got {env!r}"
            ) from None
        return _check_q_tile(tile, f"REPRO_PALLAS_QTILE={env!r}",
                             lane_aligned=True)
    if height is not None:
        from repro.kernels.autotune import best_q_tile

        tile = best_q_tile(height, compiled=not default_interpret(),
                           bits=64 if payload_bits else 32)
        if tile is not None:
            return _check_q_tile(tile, "autotune table", lane_aligned=False)
    return 256


def _resolve_q_tile(q_tile: int | None, height: int | None = None,
                    payload_bits: int = 0) -> int:
    if q_tile is None:
        return default_q_tile(height, payload_bits)
    return _check_q_tile(q_tile, "explicit q_tile", lane_aligned=False)


def _pallas_lowers(dtype, interpret: bool) -> bool:
    """Whether the Pallas walk kernels can actually run: always in
    interpret mode; compiled only on TPU and never for packed int64 rows
    (checked at trace time — compiled non-TPU walks MUST route to the
    XLA jnp mirrors or pallas_call raises at lowering)."""
    if interpret:
        return True
    return jax.default_backend() == "tpu" and jnp.dtype(dtype) != jnp.int64


# Compiled fused kernel budget: the padded arena is resident per grid
# cell, so it must fit VMEM (~16 MB/core) next to the query tile and the
# round state.  Conservative by design — past it the per-round driver
# (streaming row gathers) takes over on TPU.
FUSED_VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _fused_pallas_ok(value_p, child_p, interpret: bool) -> bool:
    if not _pallas_lowers(value_p.dtype, interpret):
        return False
    if interpret:
        return True
    arena_bytes = (value_p.size * value_p.dtype.itemsize
                   + child_p.size * child_p.dtype.itemsize)
    return arena_bytes <= FUSED_VMEM_BUDGET_BYTES


def _row_walk(rows, childrows, queries, *, height, q_tile, interpret):
    """One lockstep round: the Pallas kernel, or its compiled jnp mirror
    wherever the kernel cannot lower (any compiled non-TPU backend, and
    int64 packed rows outside interpret mode)."""
    if not _pallas_lowers(rows.dtype, interpret):
        from repro.kernels.ref import ref_veb_walk_rows

        return ref_veb_walk_rows(rows, childrows, queries, height=height)
    return veb_walk_rows(rows, childrows, queries, height=height,
                         q_tile=q_tile, interpret=interpret)


def delta_walk(value: jax.Array, child: jax.Array, root: jax.Array,
               queries: jax.Array, *, height: int, q_tile: int | None = None,
               max_rounds: int | None = None, interpret: bool | None = None,
               fused: bool | None = None):
    """Multi-hop ΔTree walk in lockstep rounds over the query frontier.

    value/child are unpadded arena arrays (value int32, or int64 packed map
    mode); ``queries`` are *packed* values in the same dtype (`cfg.qpack`).
    ``root`` is either a scalar (single-arena walk) or a per-query (K,)
    int32 array of frontier seeds — the multi-root form drives one fused
    frontier across several concatenated arenas (`veb_search.fuse_arenas`
    base-offset view, each query seeded at its owner shard's root).
    Rows are 128-padded here; the query batch is padded to a ``q_tile``
    multiple with a ROUTE_LEFT sentinel that provably matches no stored
    leaf, and padded lanes start *resolved* so they never contribute a
    round to the termination test.  The same sentinel contract extends to
    *real* lanes: a query equal to ``walk_big(dtype)`` (the reserved
    ROUTE_LEFT key, packed) is born resolved — hops 0, miss leaf, no
    successor candidate — which is what lets the forest router pad its
    dense per-shard lanes without buying them a full walk.

    ``interpret=None`` resolves via `default_interpret` *at call time*
    (env/backend changes are honored between calls); callers that trace
    this under an outer jit bake the mode at their own trace time.
    ``q_tile=None`` resolves via `default_q_tile` the same way
    (``REPRO_PALLAS_QTILE`` env override, else the autotuned
    height→tile table, else 256).  ``fused=None`` resolves via
    `default_fused` (``REPRO_PALLAS_FUSED`` override, else the fused
    single-launch driver); ``max_rounds=None`` derives the round cap
    from the arena geometry (`walk_round_cap`).

    Returns per query (batch-padding sliced off):
      leaf_val: packed value at the final position (EMPTY on miss)
      leaf_b:   final BFS position in the final ΔNode
      final_dn: final ΔNode id
      hops:     rounds the query stayed active = ΔNodes visited — exactly
                the scalar engine's `_descend` transfer statistic
      cand:     min left-turn router over the whole walk (successor lower
                bound; ``walk_big(dtype)`` = the dtype's ROUTE_LEFT when no
                left turn happened)
    """
    TR.bump("delta_walk.dispatch")
    q_tile = _resolve_q_tile(
        q_tile, height, 0 if value.dtype == jnp.int32 else 1)
    max_rounds = _resolve_max_rounds(max_rounds, height, value.shape[0])
    interpret = _resolve_interpret(interpret)
    with TR.annotate("delta_walk"):
        if _resolve_fused(fused):
            return _delta_walk_fused(value, child, root, queries,
                                     height=height, q_tile=q_tile,
                                     max_rounds=max_rounds,
                                     interpret=interpret)
        return _delta_walk(value, child, root, queries, height=height,
                           q_tile=q_tile, max_rounds=max_rounds,
                           interpret=interpret)


def delta_walk_fused(value: jax.Array, child: jax.Array, root: jax.Array,
                     queries: jax.Array, *, height: int,
                     q_tile: int | None = None,
                     max_rounds: int | None = None,
                     interpret: bool | None = None):
    """`delta_walk` pinned to the fused single-launch driver (ignores the
    ``REPRO_PALLAS_FUSED`` process default) — the explicit entry point for
    parity tests and the autotuner."""
    return delta_walk(value, child, root, queries, height=height,
                      q_tile=q_tile, max_rounds=max_rounds,
                      interpret=interpret, fused=True)


@functools.partial(
    jax.jit, static_argnames=("height", "q_tile", "max_rounds", "interpret")
)
def _delta_walk_fused(value, child, root, queries, *, height, q_tile,
                      max_rounds, interpret: bool):
    """Fused driver: every walk round inside ONE launch.

    Pallas persistent kernel where it lowers (interpret mode anywhere;
    compiled on TPU for int32 arenas within the VMEM budget), else the
    XLA-compiled blind-descent mirror `ref_delta_walk_fused` — the
    compiled non-TPU (and int64 / oversized-arena) fused path.  Both are
    bit-identical to the per-round driver, per-query ``hops`` included.
    """
    queries = queries.astype(value.dtype)
    k = queries.shape[0]
    dn0 = jnp.broadcast_to(jnp.asarray(root, jnp.int32), (k,))
    value_p, child_p = pad_arena(value, child)
    if not _fused_pallas_ok(value_p, child_p, interpret):
        from repro.kernels.ref import ref_delta_walk_fused

        # big-sentinel lanes are born resolved inside the mirror; no
        # q_tile padding — XLA has no tile-shape constraint to satisfy
        return ref_delta_walk_fused(value, child, dn0, queries,
                                    height=height, max_rounds=max_rounds)
    kp = (k + q_tile - 1) // q_tile * q_tile
    qpad = jnp.pad(queries, (0, kp - k),
                   constant_values=walk_big(value.dtype))
    dnpad = jnp.pad(dn0, (0, kp - k))
    out = veb_walk_fused(value_p, child_p, dnpad, qpad, height=height,
                         q_tile=q_tile, max_rounds=max_rounds,
                         interpret=interpret)
    return tuple(o[:k] for o in out)


@functools.partial(
    jax.jit, static_argnames=("height", "q_tile", "max_rounds", "interpret")
)
def _delta_walk(value, child, root, queries, *, height, q_tile, max_rounds,
                interpret: bool):
    value_p, child_p = pad_arena(value, child)
    queries = queries.astype(value.dtype)
    k = queries.shape[0]
    kp = (k + q_tile - 1) // q_tile * q_tile
    big = jnp.asarray(walk_big(value.dtype), value.dtype)
    qpad = jnp.pad(queries, (0, kp - k), constant_values=walk_big(value.dtype))
    # scalar root broadcasts (single arena); a (K,) array seeds each query
    # at its own root (fused multi-arena frontier)
    dn0 = jnp.pad(jnp.broadcast_to(jnp.asarray(root, jnp.int32), (k,)),
                  (0, kp - k))

    state = dict(
        dn=dn0,
        # padding lanes AND sentinel-keyed real lanes (router pads) are
        # born resolved: they never gate termination nor count a hop
        resolved=(jnp.arange(kp) >= k) | (qpad == big),
        leaf_val=jnp.zeros((kp,), value.dtype),
        leaf_b=jnp.ones((kp,), jnp.int32),
        final_dn=dn0,
        hops=jnp.zeros((kp,), jnp.int32),
        cand=jnp.full((kp,), big, value.dtype),
        rounds=jnp.int32(0),
    )

    def cond(s):
        return jnp.any(~s["resolved"]) & (s["rounds"] < max_rounds)

    def body(s):
        # REPRO_TRACE: names one frontier round in xprof (the paper's
        # "one memory transfer") — gated at trace time, so flipping the
        # env var between calls does not retrace cached programs
        with TR.annotate("delta_walk.round"):
            dnc = jnp.clip(s["dn"], 0, value.shape[0] - 1)
            rows = value_p[dnc]      # (K, UBp) — the per-query ΔNode DMA
            childrows = child_p[dnc]
            lv, lb, nxt, rcand = _row_walk(
                rows, childrows, qpad, height=height, q_tile=q_tile,
                interpret=interpret,
            )
        act = ~s["resolved"]
        done_now = act & (nxt < 0)
        return dict(
            dn=jnp.where(act & (nxt >= 0), nxt, s["dn"]),
            resolved=s["resolved"] | done_now,
            leaf_val=jnp.where(done_now, lv, s["leaf_val"]),
            leaf_b=jnp.where(done_now, lb, s["leaf_b"]),
            final_dn=jnp.where(done_now, s["dn"], s["final_dn"]),
            hops=s["hops"] + act.astype(jnp.int32),
            cand=jnp.where(act & (rcand < s["cand"]), rcand, s["cand"]),
            rounds=s["rounds"] + 1,
        )

    state = jax.lax.while_loop(cond, body, state)
    return (state["leaf_val"][:k], state["leaf_b"][:k],
            state["final_dn"][:k], state["hops"][:k], state["cand"][:k])


def scan_round_cap(height: int, max_dnodes: int, max_out: int,
                   chase_slack: int = 16) -> int:
    """Trace-time round bound for the emit-cursor scan frontier: each
    emitted item costs at most two full walk passes (FIND + VERIFY), each
    bounded by `walk_round_cap`, plus slack passes for tombstone chases.
    Generous by design — the in-kernel loop exits as soon as every lane
    is done, so the cap only bounds the lowered loop."""
    return walk_round_cap(height, max_dnodes) * 2 * (max_out + chase_slack)


def delta_scan(value: jax.Array, mark: jax.Array, child: jax.Array,
               root: jax.Array, starts: jax.Array, his: jax.Array, *,
               height: int, max_out: int, pmask: int = 0,
               q_tile: int | None = None, max_rounds: int | None = None,
               interpret: bool | None = None):
    """Ordered range/successor-k scan in lockstep passes over the lane
    frontier — the emit-cursor variant of `delta_walk` (ONE dispatch for
    the whole scan, every pass inside a single launch).

    value/mark/child are unpadded arena arrays; ``starts``/``his`` are
    *packed* ``qpack`` bounds per lane (start exclusive, hi inclusive in
    key space).  ``root`` is scalar or per-lane (K,) seeds — the
    multi-root form drives one fused scan across concatenated shard
    arenas (`veb_search.fuse_arenas`), each lane emitting its owner
    shard's band.  A lane whose start equals ``walk_big(dtype)`` is born
    done (the router's pad-lane contract).

    Single-launch discipline matches `delta_walk`: the persistent Pallas
    kernel `veb_search.veb_scan_fused` where it lowers (interpret mode
    anywhere; compiled on TPU for int32 arenas within the VMEM budget),
    else the XLA-compiled mirror `ref.ref_delta_scan_fused` — both
    bit-identical, pass logic documented on the mirror.

    Returns per lane (pad width sliced off):
      out:  (K, max_out) packed live *leaf* values in (start, hi], key
            ascending, ``walk_big`` padding (overflow buffers are merged
            by the engine dispatch — I5' correctness lives there)
      n:    emitted count
      hops: ΔNode visits across every pass (`delta_walk` accounting)
      more: bool — buffer filled with live items remaining; resume from
            ``key_of(out[lane, n-1])``
    """
    TR.bump("delta_scan.dispatch")
    q_tile = _resolve_q_tile(
        q_tile, height, 0 if value.dtype == jnp.int32 else 1)
    if max_rounds is None:
        max_rounds = scan_round_cap(height, value.shape[0], max_out)
    interpret = _resolve_interpret(interpret)
    with TR.annotate("delta_scan"):
        return _delta_scan(value, mark, child, root, starts, his,
                           height=height, max_out=max_out, pmask=pmask,
                           q_tile=q_tile, max_rounds=int(max_rounds),
                           interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("height", "max_out", "pmask", "q_tile",
                              "max_rounds", "interpret")
)
def _delta_scan(value, mark, child, root, starts, his, *, height, max_out,
                pmask, q_tile, max_rounds, interpret: bool):
    starts = starts.astype(value.dtype)
    his = his.astype(value.dtype)
    k = starts.shape[0]
    dn0 = jnp.broadcast_to(jnp.asarray(root, jnp.int32), (k,))
    value_p, child_p = pad_arena(value, child)
    if not _fused_pallas_ok(value_p, child_p, interpret):
        from repro.kernels.ref import ref_delta_scan_fused

        return ref_delta_scan_fused(value, mark, child, dn0, starts, his,
                                    height=height, max_rounds=max_rounds,
                                    max_out=max_out, pmask=pmask)
    mark_p = jnp.pad(mark, ((0, 0), (0, value_p.shape[1] - mark.shape[1])))
    kp = (k + q_tile - 1) // q_tile * q_tile
    big = walk_big(value.dtype)
    spad = jnp.pad(starts, (0, kp - k), constant_values=big)
    hpad = jnp.pad(his, (0, kp - k), constant_values=big)
    dnpad = jnp.pad(dn0, (0, kp - k))
    out, n, hops, more = veb_scan_fused(
        value_p, mark_p, child_p, dnpad, spad, hpad, height=height,
        max_out=max_out, pmask=pmask, q_tile=q_tile, max_rounds=max_rounds,
        interpret=interpret)
    return (out[:k, :max_out], n[:k], hops[:k],
            more[:k].astype(jnp.bool_))


def delta_search(value: jax.Array, child: jax.Array, root: jax.Array,
                 queries: jax.Array, *, height: int, q_tile: int | None = None,
                 max_rounds: int | None = None,
                 interpret: bool | None = None, fused: bool | None = None):
    """Legacy 3-tuple walk: (leaf_val, leaf_b, final_dn) per query (same
    contract as `kernels.ref.ref_delta_search`); ``interpret=None`` /
    ``q_tile=None`` / ``max_rounds=None`` / ``fused=None`` = auto-resolved
    at call time like `delta_walk`."""
    lv, lb, dn, _, _ = delta_walk(
        value, child, root, queries,
        height=height, q_tile=q_tile, max_rounds=max_rounds,
        interpret=interpret, fused=fused,
    )
    return lv, lb, dn


def delta_contains(value: jax.Array, mark: jax.Array, child: jax.Array,
                   buf: jax.Array, root: jax.Array, queries: jax.Array, *,
                   height: int, q_tile: int | None = None,
                   max_rounds: int | None = None,
                   interpret: bool | None = None, fused: bool | None = None):
    """Paper SEARCHNODE on top of the kernel walk: leaf match & ~mark, else
    the ΔNode's overflow buffer (paper Fig. 8 lines 9..17)."""
    return _delta_contains(
        value, mark, child, buf, root, queries, height=height,
        q_tile=_resolve_q_tile(
            q_tile, height, 0 if value.dtype == jnp.int32 else 1),
        max_rounds=_resolve_max_rounds(max_rounds, height, value.shape[0]),
        interpret=_resolve_interpret(interpret),
        fused=_resolve_fused(fused))


@functools.partial(
    jax.jit,
    static_argnames=("height", "q_tile", "max_rounds", "interpret", "fused")
)
def _delta_contains(value, mark, child, buf, root, queries, *, height,
                    q_tile, max_rounds, interpret: bool, fused: bool):
    pos = jnp.asarray(layout.veb_pos_table(height))
    lv, lb, dn = delta_search(
        value, child, root, queries,
        height=height, q_tile=q_tile, max_rounds=max_rounds,
        interpret=interpret, fused=fused,
    )
    leaf_hit = lv == queries
    leaf_live = leaf_hit & ~mark[dn, pos[lb]]
    in_buf = jnp.any(buf[dn] == queries[:, None], axis=1)
    return jnp.where(leaf_hit, leaf_live, in_buf)
