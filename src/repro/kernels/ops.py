"""jit'd public wrappers around the Pallas kernels.

- `delta_search`       — multi-round driver for veb_search: sort queries by
  their current ΔNode, run the level kernel (one scalar-prefetched ΔNode row
  DMA per query tile), hop, repeat until every query lands on its leaf.
- `delta_contains`     — full paper SEARCHNODE semantics on top (mark bit +
  overflow buffer check).
- `paged_decode_attention` — re-exported from delta_paged_attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import layout
from repro.kernels.delta_paged_attention import paged_decode_attention  # noqa: F401
from repro.kernels.veb_search import pad_arena, veb_walk_rows


@functools.partial(
    jax.jit, static_argnames=("height", "q_tile", "max_rounds", "interpret")
)
def delta_search(value: jax.Array, child: jax.Array, root: jax.Array,
                 queries: jax.Array, *, height: int, q_tile: int = 256,
                 max_rounds: int = 64, interpret: bool = True):
    """Multi-hop ΔTree search via the Pallas walk kernel, in lockstep rounds:
    each round gathers the frontier's ΔNode rows (one contiguous DMA per
    query — the paper's "memory transfer") and descends them fully in VMEM.

    value/child may be unpadded arena arrays; rows are 128-padded here.
    Returns (leaf_val, leaf_b, final_dn) per query (same contract as
    `kernels.ref.ref_delta_search`).
    """
    value_p, child_p = pad_arena(value, child)
    k = queries.shape[0]
    kp = (k + q_tile - 1) // q_tile * q_tile
    qpad = jnp.pad(queries, (0, kp - k))

    state = dict(
        dn=jnp.full((kp,), root, jnp.int32),
        resolved=jnp.zeros((kp,), jnp.bool_),
        leaf_val=jnp.zeros((kp,), jnp.int32),
        leaf_b=jnp.ones((kp,), jnp.int32),
        final_dn=jnp.full((kp,), root, jnp.int32),
        rounds=jnp.int32(0),
    )

    def cond(s):
        return jnp.any(~s["resolved"]) & (s["rounds"] < max_rounds)

    def body(s):
        dnc = jnp.clip(s["dn"], 0, value.shape[0] - 1)
        rows = value_p[dnc]          # (K, UBp) — the per-query ΔNode DMA
        childrows = child_p[dnc]
        lv, lb, nxt = veb_walk_rows(
            rows, childrows, qpad, height=height, q_tile=q_tile,
            interpret=interpret,
        )
        act = ~s["resolved"]
        done_now = act & (nxt < 0)
        return dict(
            dn=jnp.where(act & (nxt >= 0), nxt, s["dn"]),
            resolved=s["resolved"] | done_now,
            leaf_val=jnp.where(done_now, lv, s["leaf_val"]),
            leaf_b=jnp.where(done_now, lb, s["leaf_b"]),
            final_dn=jnp.where(done_now, s["dn"], s["final_dn"]),
            rounds=s["rounds"] + 1,
        )

    state = jax.lax.while_loop(cond, body, state)
    return state["leaf_val"][:k], state["leaf_b"][:k], state["final_dn"][:k]


@functools.partial(
    jax.jit, static_argnames=("height", "q_tile", "max_rounds", "interpret")
)
def delta_contains(value: jax.Array, mark: jax.Array, child: jax.Array,
                   buf: jax.Array, root: jax.Array, queries: jax.Array, *,
                   height: int, q_tile: int = 256, max_rounds: int = 64,
                   interpret: bool = True):
    """Paper SEARCHNODE on top of the kernel walk: leaf match & ~mark, else
    the ΔNode's overflow buffer (paper Fig. 8 lines 9..17)."""
    pos = jnp.asarray(layout.veb_pos_table(height))
    lv, lb, dn = delta_search(
        value, child, root, queries,
        height=height, q_tile=q_tile, max_rounds=max_rounds, interpret=interpret,
    )
    leaf_hit = lv == queries
    leaf_live = leaf_hit & ~mark[dn, pos[lb]]
    in_buf = jnp.any(buf[dn] == queries[:, None], axis=1)
    return jnp.where(leaf_hit, leaf_live, in_buf)
