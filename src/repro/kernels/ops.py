"""jit'd public wrappers around the Pallas kernels.

- `delta_walk`         — multi-round lockstep driver for veb_search: gather
  each active query's current ΔNode row (one contiguous DMA per query —
  the paper's "memory transfer"), run the level kernel (one full in-ΔNode
  descent), hop to the child ΔNode, repeat until every query lands on its
  leaf.  Reports per-query hop counts (= rounds active = ΔNodes visited)
  and the folded successor candidate.  ``root`` may be per-query (multi-
  root seeding over a `veb_search.fuse_arenas` view — the fused forest
  frontier, DESIGN.md §8).  This is the engine room of the ``"lockstep"``
  SearchEngine (repro.core.engine).
- `delta_search`       — legacy 3-tuple contract on top of `delta_walk`.
- `delta_contains`     — paper SEARCHNODE set semantics on top (mark bit +
  overflow buffer check).
- `paged_decode_attention` — re-exported from delta_paged_attention.

Execution-mode resolution (``interpret=None`` everywhere): Pallas compiled
on TPU, interpret mode elsewhere, overridable per call (``interpret=``) or
process-wide via ``REPRO_PALLAS_INTERPRET=0/1``.  Packed int64 rows cannot
lower through the TPU Pallas pipeline, so the compiled path for them is
``kernels.ref.ref_veb_walk_rows`` — same lockstep rounds, XLA-compiled.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import layout
from repro.kernels.delta_paged_attention import paged_decode_attention  # noqa: F401
from repro.kernels.veb_search import pad_arena, veb_walk_rows, walk_big
from repro.obs import trace as TR


def default_interpret() -> bool:
    """Auto-detected Pallas mode: compiled on TPU, interpret elsewhere.

    ``REPRO_PALLAS_INTERPRET=1`` forces interpret mode (kernel debugging on
    TPU), ``=0`` forces compiled lowering; unset (or set empty) defers to
    the backend so TPU runs stop silently paying the interpreter tax."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip()
    if env:
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def _check_q_tile(tile: int, origin: str, lane_aligned: bool) -> int:
    """Shared q_tile validation: positive everywhere; the process-wide
    production knob (``REPRO_PALLAS_QTILE``) additionally requires a
    multiple of 128 so the compiled Pallas block shape stays lane-aligned.
    Explicit per-call tiles stay lenient — tests and interpret-mode runs
    legitimately use small tiles (16/64)."""
    tile = int(tile)
    bad = tile <= 0 or (lane_aligned and tile % 128)
    if bad:
        want = "positive multiple of 128" if lane_aligned else "positive"
        raise ValueError(f"q_tile must be {want}, got {tile} ({origin})")
    return tile


def default_q_tile() -> int:
    """Lockstep kernel query tile: ``REPRO_PALLAS_QTILE`` env override,
    else 256 (two VREG lanes' worth; the ROADMAP autotuning item sweeps
    this once TPU timings exist)."""
    env = os.environ.get("REPRO_PALLAS_QTILE", "").strip()
    if not env:
        return 256
    try:
        tile = int(env)
    except ValueError:
        raise ValueError(
            f"REPRO_PALLAS_QTILE must be an integer, got {env!r}") from None
    return _check_q_tile(tile, f"REPRO_PALLAS_QTILE={env!r}",
                         lane_aligned=True)


def _resolve_q_tile(q_tile: int | None) -> int:
    if q_tile is None:
        return default_q_tile()
    return _check_q_tile(q_tile, "explicit q_tile", lane_aligned=False)


def _row_walk(rows, childrows, queries, *, height, q_tile, interpret):
    """One lockstep round: the Pallas kernel, or its compiled jnp mirror
    when the kernel cannot lower (int64 packed rows outside interpret)."""
    if not interpret and rows.dtype == jnp.int64:
        from repro.kernels.ref import ref_veb_walk_rows

        return ref_veb_walk_rows(rows, childrows, queries, height=height)
    return veb_walk_rows(rows, childrows, queries, height=height,
                         q_tile=q_tile, interpret=interpret)


def delta_walk(value: jax.Array, child: jax.Array, root: jax.Array,
               queries: jax.Array, *, height: int, q_tile: int | None = None,
               max_rounds: int = 64, interpret: bool | None = None):
    """Multi-hop ΔTree walk in lockstep rounds over the query frontier.

    value/child are unpadded arena arrays (value int32, or int64 packed map
    mode); ``queries`` are *packed* values in the same dtype (`cfg.qpack`).
    ``root`` is either a scalar (single-arena walk) or a per-query (K,)
    int32 array of frontier seeds — the multi-root form drives one fused
    frontier across several concatenated arenas (`veb_search.fuse_arenas`
    base-offset view, each query seeded at its owner shard's root).
    Rows are 128-padded here; the query batch is padded to a ``q_tile``
    multiple with a ROUTE_LEFT sentinel that provably matches no stored
    leaf, and padded lanes start *resolved* so they never contribute a
    round to the termination test.  The same sentinel contract extends to
    *real* lanes: a query equal to ``walk_big(dtype)`` (the reserved
    ROUTE_LEFT key, packed) is born resolved — hops 0, miss leaf, no
    successor candidate — which is what lets the forest router pad its
    dense per-shard lanes without buying them a full walk.

    ``interpret=None`` resolves via `default_interpret` *at call time*
    (env/backend changes are honored between calls); callers that trace
    this under an outer jit bake the mode at their own trace time.
    ``q_tile=None`` resolves via `default_q_tile` the same way
    (``REPRO_PALLAS_QTILE`` env override, else 256).

    Returns per query (batch-padding sliced off):
      leaf_val: packed value at the final position (EMPTY on miss)
      leaf_b:   final BFS position in the final ΔNode
      final_dn: final ΔNode id
      hops:     rounds the query stayed active = ΔNodes visited — exactly
                the scalar engine's `_descend` transfer statistic
      cand:     min left-turn router over the whole walk (successor lower
                bound; ``walk_big(dtype)`` = the dtype's ROUTE_LEFT when no
                left turn happened)
    """
    with TR.annotate("delta_walk"):
        return _delta_walk(value, child, root, queries, height=height,
                           q_tile=_resolve_q_tile(q_tile),
                           max_rounds=max_rounds,
                           interpret=_resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("height", "q_tile", "max_rounds", "interpret")
)
def _delta_walk(value, child, root, queries, *, height, q_tile, max_rounds,
                interpret: bool):
    value_p, child_p = pad_arena(value, child)
    queries = queries.astype(value.dtype)
    k = queries.shape[0]
    kp = (k + q_tile - 1) // q_tile * q_tile
    big = jnp.asarray(walk_big(value.dtype), value.dtype)
    qpad = jnp.pad(queries, (0, kp - k), constant_values=walk_big(value.dtype))
    # scalar root broadcasts (single arena); a (K,) array seeds each query
    # at its own root (fused multi-arena frontier)
    dn0 = jnp.pad(jnp.broadcast_to(jnp.asarray(root, jnp.int32), (k,)),
                  (0, kp - k))

    state = dict(
        dn=dn0,
        # padding lanes AND sentinel-keyed real lanes (router pads) are
        # born resolved: they never gate termination nor count a hop
        resolved=(jnp.arange(kp) >= k) | (qpad == big),
        leaf_val=jnp.zeros((kp,), value.dtype),
        leaf_b=jnp.ones((kp,), jnp.int32),
        final_dn=dn0,
        hops=jnp.zeros((kp,), jnp.int32),
        cand=jnp.full((kp,), big, value.dtype),
        rounds=jnp.int32(0),
    )

    def cond(s):
        return jnp.any(~s["resolved"]) & (s["rounds"] < max_rounds)

    def body(s):
        # REPRO_TRACE: names one frontier round in xprof (the paper's
        # "one memory transfer") — gated at trace time, so flipping the
        # env var between calls does not retrace cached programs
        with TR.annotate("delta_walk.round"):
            dnc = jnp.clip(s["dn"], 0, value.shape[0] - 1)
            rows = value_p[dnc]      # (K, UBp) — the per-query ΔNode DMA
            childrows = child_p[dnc]
            lv, lb, nxt, rcand = _row_walk(
                rows, childrows, qpad, height=height, q_tile=q_tile,
                interpret=interpret,
            )
        act = ~s["resolved"]
        done_now = act & (nxt < 0)
        return dict(
            dn=jnp.where(act & (nxt >= 0), nxt, s["dn"]),
            resolved=s["resolved"] | done_now,
            leaf_val=jnp.where(done_now, lv, s["leaf_val"]),
            leaf_b=jnp.where(done_now, lb, s["leaf_b"]),
            final_dn=jnp.where(done_now, s["dn"], s["final_dn"]),
            hops=s["hops"] + act.astype(jnp.int32),
            cand=jnp.where(act & (rcand < s["cand"]), rcand, s["cand"]),
            rounds=s["rounds"] + 1,
        )

    state = jax.lax.while_loop(cond, body, state)
    return (state["leaf_val"][:k], state["leaf_b"][:k],
            state["final_dn"][:k], state["hops"][:k], state["cand"][:k])


def delta_search(value: jax.Array, child: jax.Array, root: jax.Array,
                 queries: jax.Array, *, height: int, q_tile: int | None = None,
                 max_rounds: int = 64, interpret: bool | None = None):
    """Legacy 3-tuple walk: (leaf_val, leaf_b, final_dn) per query (same
    contract as `kernels.ref.ref_delta_search`); ``interpret=None`` /
    ``q_tile=None`` = auto-resolved at call time like `delta_walk`."""
    lv, lb, dn, _, _ = delta_walk(
        value, child, root, queries,
        height=height, q_tile=q_tile, max_rounds=max_rounds,
        interpret=interpret,
    )
    return lv, lb, dn


def delta_contains(value: jax.Array, mark: jax.Array, child: jax.Array,
                   buf: jax.Array, root: jax.Array, queries: jax.Array, *,
                   height: int, q_tile: int | None = None,
                   max_rounds: int = 64, interpret: bool | None = None):
    """Paper SEARCHNODE on top of the kernel walk: leaf match & ~mark, else
    the ΔNode's overflow buffer (paper Fig. 8 lines 9..17)."""
    return _delta_contains(value, mark, child, buf, root, queries,
                           height=height, q_tile=_resolve_q_tile(q_tile),
                           max_rounds=max_rounds,
                           interpret=_resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("height", "q_tile", "max_rounds", "interpret")
)
def _delta_contains(value, mark, child, buf, root, queries, *, height,
                    q_tile, max_rounds, interpret: bool):
    pos = jnp.asarray(layout.veb_pos_table(height))
    lv, lb, dn = delta_search(
        value, child, root, queries,
        height=height, q_tile=q_tile, max_rounds=max_rounds, interpret=interpret,
    )
    leaf_hit = lv == queries
    leaf_live = leaf_hit & ~mark[dn, pos[lb]]
    in_buf = jnp.any(buf[dn] == queries[:, None], axis=1)
    return jnp.where(leaf_hit, leaf_live, in_buf)
