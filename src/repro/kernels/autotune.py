"""q_tile autotuner: sweep the lockstep walk tile per tree height, bake
winners into a height→tile table consulted by ``ops.default_q_tile``.

Resolution order for ``q_tile=None`` walks (see ``ops.default_q_tile``):

1. ``REPRO_PALLAS_QTILE`` env override (process-wide pin, lane-aligned);
2. the ``REPRO_PALLAS_AUTOTUNE`` cache file — a JSON table written by
   `save_cache` / ``benchmarks/autotune_qtile.py`` on the machine at hand
   (keys ``"<height>/<compiled|interpret>/<bits>"``, values tile ints);
3. the committed ``BAKED`` table below — winners from the repo's recorded
   compiled sweeps (``benchmarks/autotune_qtile.py`` under
   ``REPRO_PALLAS_INTERPRET=0``; see the BENCH files at the repo root);
4. the historical default, 256.

The tile gates two costs: query-batch padding (batches pad up to a
``q_tile`` multiple, so oversized tiles tax small frontiers) and, on the
compiled TPU path, the Pallas grid/VMEM shape per cell.  The sweep times
the *real* driver (`ops.delta_walk_fused` end to end, jit-warm, best of
``repeats``) so whatever path the current backend resolves to — fused
Pallas or the XLA mirror — is what gets tuned.
"""

from __future__ import annotations

import json
import os
import time

ENV_CACHE = "REPRO_PALLAS_AUTOTUNE"

CANDIDATES = (128, 256, 512, 1024)

# Committed winners: (height, compiled, bits) -> q_tile.  Baked from
# benchmarks/autotune_qtile.py on the CPU compiled harness
# (run_compiled.sh — REPRO_PALLAS_INTERPRET=0, jax 0.4.37, batch 1024);
# re-bake after running the sweep on new hardware — on a TPU the tile
# also shapes the Pallas grid/VMEM per cell, so TPU winners will differ.
# Heights absent here fall through to 256.  NB on compiled CPU the tile
# only gates batch padding (the XLA mirror is tile-free), so these
# winners sit within run-to-run noise of each other there by design.
BAKED: dict[tuple[int, bool, int], int] = {
    (5, True, 32): 1024,
    (7, True, 32): 256,
    (9, True, 32): 512,
    (7, True, 64): 512,
}


def cache_path() -> str | None:
    """The ``REPRO_PALLAS_AUTOTUNE`` cache file path (None = no cache)."""
    p = os.environ.get(ENV_CACHE, "").strip()
    return p or None


def _key(height: int, compiled: bool, bits: int) -> str:
    return f"{height}/{'compiled' if compiled else 'interpret'}/{bits}"


def load_cache(path: str | None = None) -> dict[str, int]:
    """Read the autotune cache (missing/corrupt file = empty table: the
    autotuner must never make a walk fail)."""
    path = path or cache_path()
    if not path or not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            raw = json.load(f)
        return {str(k): int(v) for k, v in raw.items()}
    except (json.JSONDecodeError, OSError, TypeError, ValueError):
        return {}


def save_cache(table: dict[str, int], path: str | None = None) -> str | None:
    """Merge ``table`` into the cache file (existing keys updated).
    Returns the path written, or None when no cache is configured."""
    path = path or cache_path()
    if not path:
        return None
    merged = load_cache(path)
    merged.update({str(k): int(v) for k, v in table.items()})
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True)
    return path


def best_q_tile(height: int, *, compiled: bool, bits: int = 32
                ) -> int | None:
    """Autotuned tile for ``height`` under the given execution mode, or
    None when neither the cache nor the baked table knows it."""
    hit = load_cache().get(_key(height, compiled, bits))
    if hit is not None:
        return hit
    return BAKED.get((height, compiled, bits))


def sweep_height(height: int, *, batch: int = 1024, n_keys: int = 50_000,
                 repeats: int = 3, iters: int = 10,
                 candidates: tuple[int, ...] = CANDIDATES,
                 payload_bits: int = 0, seed: int = 0):
    """Time `ops.delta_walk_fused` per candidate tile on a bulk-built tree.

    Returns ``(best_tile, {tile: seconds-per-iter})`` — per tile: jit
    warmup off the clock, then ``repeats`` timed runs of ``iters``
    back-to-back walks (one final block), best repeat kept.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import bulk_build
    from repro.core.deltatree import TreeConfig
    from repro.kernels import ops as OPS

    rng = np.random.default_rng(seed)
    cfg = TreeConfig(height=height, payload_bits=payload_bits,
                     max_dnodes=max(256, 6 * n_keys // 2 ** (height - 1)))
    vals = np.unique(rng.integers(1, 4 * n_keys, n_keys).astype(np.int32))
    t = bulk_build(cfg, vals)
    q = cfg.qpack(jnp.asarray(
        rng.integers(1, 4 * n_keys, batch).astype(np.int32)))

    timings: dict[int, float] = {}
    for tile in candidates:
        def walk():
            return OPS.delta_walk_fused(t.value, t.child, t.root, q,
                                        height=height, q_tile=tile)

        jax.block_until_ready(walk())  # compile off the clock
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(iters):
                out = walk()
            jax.block_until_ready(out)
            best = min(best, (time.perf_counter() - t0) / iters)
        timings[tile] = best
    best_tile = min(timings, key=timings.get)
    return best_tile, timings
