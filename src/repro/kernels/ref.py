"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import layout
from repro.core.layout import EMPTY


@functools.partial(jax.jit, static_argnames=("height",))
def ref_veb_walk_rows(rows: jax.Array, childrows: jax.Array,
                      queries: jax.Array, *, height: int):
    """Pure-jnp mirror of ``veb_search.veb_walk_rows`` (identical contract:
    one full in-ΔNode descent per query over pre-gathered rows, returning
    (leaf_val, leaf_b, next_dn, cand)).

    Besides being the kernel's allclose oracle this is the *compiled*
    non-Pallas walk: `ops` routes here when the Pallas kernel cannot lower
    (int64 packed rows on TPU) — same lockstep round structure, same one
    row gather per query per round, just XLA-compiled gathers instead of a
    hand-written VMEM tile.
    """
    from repro.kernels.veb_search import walk_big

    pos = jnp.asarray(layout.veb_pos_table(height))
    bottom0 = 2 ** (height - 1)
    big = walk_big(rows.dtype)

    def take(b):
        return jnp.take_along_axis(rows, pos[b][:, None], axis=1)[:, 0]

    v = queries
    b = jnp.ones(v.shape, jnp.int32)
    cand = jnp.full(v.shape, big, rows.dtype)
    for _ in range(height - 1):
        router = take(b)
        left = take(jnp.minimum(2 * b, 2 * bottom0 - 1))
        internal = (b < bottom0) & (left != EMPTY)
        go_right = v >= router
        go_left = internal & ~go_right
        cand = jnp.where(go_left & (router < cand), router, cand)
        b = jnp.where(internal, 2 * b + go_right.astype(b.dtype), b)

    leaf_val = take(b)
    at_bottom = b >= bottom0
    slot = jnp.where(at_bottom, b - bottom0, 0)
    child = jnp.take_along_axis(childrows, slot[:, None], axis=1)[:, 0]
    nxt = jnp.where(at_bottom, child, jnp.int32(-1))
    return leaf_val, b, nxt, cand


@functools.partial(jax.jit, static_argnames=("height",))
def ref_delta_search(value: jax.Array, child: jax.Array, root: jax.Array,
                     queries: jax.Array, *, height: int):
    """Oracle for the multi-hop ΔTree search over (value, child) arena rows.

    Returns (leaf_val, leaf_b, final_dn) per query — identical contract to
    `kernels.ops.delta_search`.
    """
    pos = jnp.asarray(layout.veb_pos_table(height))
    bottom0 = 2 ** (height - 1)

    def one(v):
        def cond(s):
            return ~s[2]

        def body(s):
            dn, b, _ = s
            at_bottom = b >= bottom0
            left = jnp.where(
                at_bottom, EMPTY, value[dn, pos[jnp.minimum(2 * b, 2 * bottom0 - 1)]]
            )
            internal = (~at_bottom) & (left != EMPTY)
            router = value[dn, pos[b]]
            slot = jnp.where(at_bottom, b - bottom0, 0)
            ch = jnp.where(at_bottom, child[dn, slot], jnp.int32(-1))
            hop = at_bottom & (ch >= 0)
            nb = jnp.where(internal, 2 * b + (v >= router).astype(jnp.int32), b)
            nb = jnp.where(hop, jnp.int32(1), nb)
            ndn = jnp.where(hop, ch, dn)
            done = (~internal) & (~hop)
            return ndn, nb, done

        dn, b, _ = jax.lax.while_loop(
            cond, body, (jnp.int32(root), jnp.int32(1), jnp.bool_(False))
        )
        return value[dn, pos[b]], b, dn

    return jax.vmap(one)(queries)


@jax.jit
def ref_paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               seq_lens: jax.Array):
    """Oracle for ΔTree-paged decode attention.

    q:            (B, QH, D)
    k/v_pages:    (NP, PS, KVH, D)
    block_tables: (B, MAXP) int32 physical page ids (-1 = unused)
    seq_lens:     (B,) int32

    Gathers each sequence's pages into a contiguous (S, KVH, D) cache, then
    runs masked GQA decode attention in f32. Returns (B, QH, D) in q.dtype.
    """
    b, qh, d = q.shape
    np_, ps, kvh, _ = k_pages.shape
    maxp = block_tables.shape[1]
    g = qh // kvh
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    bt = jnp.maximum(block_tables, 0)
    k = k_pages[bt]  # (B, MAXP, PS, KVH, D)
    v = v_pages[bt]
    k = k.reshape(b, maxp * ps, kvh, d).astype(jnp.float32)
    v = v.reshape(b, maxp * ps, kvh, d).astype(jnp.float32)

    qf = q.reshape(b, kvh, g, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k) * scale
    mask = jnp.arange(maxp * ps)[None, :] < seq_lens[:, None]  # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return out.reshape(b, qh, d).astype(q.dtype)
