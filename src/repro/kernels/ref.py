"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import layout
from repro.core.layout import EMPTY


@functools.partial(jax.jit, static_argnames=("height",))
def ref_veb_walk_rows(rows: jax.Array, childrows: jax.Array,
                      queries: jax.Array, *, height: int):
    """Pure-jnp mirror of ``veb_search.veb_walk_rows`` (identical contract:
    one full in-ΔNode descent per query over pre-gathered rows, returning
    (leaf_val, leaf_b, next_dn, cand)).

    Besides being the kernel's allclose oracle this is the *compiled*
    non-Pallas walk: `ops` routes here when the Pallas kernel cannot lower
    (int64 packed rows on TPU) — same lockstep round structure, same one
    row gather per query per round, just XLA-compiled gathers instead of a
    hand-written VMEM tile.
    """
    from repro.kernels.veb_search import walk_big

    pos = jnp.asarray(layout.veb_pos_table(height))
    bottom0 = 2 ** (height - 1)
    big = walk_big(rows.dtype)

    def take(b):
        return jnp.take_along_axis(rows, pos[b][:, None], axis=1)[:, 0]

    v = queries
    b = jnp.ones(v.shape, jnp.int32)
    cand = jnp.full(v.shape, big, rows.dtype)
    for _ in range(height - 1):
        router = take(b)
        left = take(jnp.minimum(2 * b, 2 * bottom0 - 1))
        internal = (b < bottom0) & (left != EMPTY)
        go_right = v >= router
        go_left = internal & ~go_right
        cand = jnp.where(go_left & (router < cand), router, cand)
        b = jnp.where(internal, 2 * b + go_right.astype(b.dtype), b)

    leaf_val = take(b)
    at_bottom = b >= bottom0
    slot = jnp.where(at_bottom, b - bottom0, 0)
    child = jnp.take_along_axis(childrows, slot[:, None], axis=1)[:, 0]
    nxt = jnp.where(at_bottom, child, jnp.int32(-1))
    return leaf_val, b, nxt, cand


@functools.partial(jax.jit, static_argnames=("height", "max_rounds"))
def ref_delta_walk_fused(value: jax.Array, child: jax.Array, root: jax.Array,
                         queries: jax.Array, *, height: int,
                         max_rounds: int):
    """Fused multi-round walk, XLA-compiled: the whole frontier loop in one
    program (contract of ``ops.delta_walk`` — (leaf_val, leaf_b, final_dn,
    hops, cand) per query, ``root`` scalar or per-query (K,) seeds, and a
    query equal to ``walk_big(dtype)`` born resolved).

    This is both the allclose oracle for ``veb_search.veb_walk_fused`` and
    the *compiled* fused walk wherever Pallas cannot lower (non-TPU
    backends, int64 packed rows, arenas past the VMEM budget) — the CPU
    compiled-performance path runs here.

    The in-ΔNode descent is *blind*: one router gather per level (instead
    of router + left-child), always routing right through EMPTY territory.
    Sound because occupied slots form a connected top tree (I1/I2: an
    EMPTY slot has no occupied descendants) and packed queries are >= 1 >
    EMPTY, so once the walk leaves the occupied region it only ever sees
    EMPTY routers and the last-occupied position it tracks *is* the leaf
    the eager walk stops at.  The successor candidate is reconstructed
    post-descent: the occupied positions visited above the leaf are
    exactly the internal ancestors, so folding their routers under
    ``v < router`` reproduces the per-level left-turn fold bit for bit.
    """
    from repro.kernels.veb_search import walk_big

    h = height
    bottom0 = 2 ** (h - 1)
    m, ub = value.shape
    pos = jnp.asarray(layout.veb_pos_table(h))
    big = jnp.asarray(walk_big(value.dtype), value.dtype)
    queries = queries.astype(value.dtype)
    k = queries.shape[0]
    vflat = value.reshape(-1)
    dn0 = jnp.broadcast_to(jnp.asarray(root, jnp.int32), (k,))

    state = dict(
        dn=dn0,
        resolved=queries == big,
        leaf_val=jnp.zeros((k,), value.dtype),
        leaf_b=jnp.ones((k,), jnp.int32),
        final_dn=dn0,
        hops=jnp.zeros((k,), jnp.int32),
        cand=jnp.full((k,), big, value.dtype),
        rounds=jnp.int32(0),
    )

    def cond(s):
        return jnp.any(~s["resolved"]) & (s["rounds"] < max_rounds)

    def body(s):
        dnc = jnp.clip(s["dn"], 0, m - 1)
        base = dnc * ub
        v = queries
        b = jnp.ones((k,), jnp.int32)
        lb = jnp.ones((k,), jnp.int32)          # last occupied position
        lv = jnp.zeros((k,), value.dtype)
        routers, bs = [], []
        for _ in range(h):                       # blind descent: h gathers
            router = vflat.at[base + pos[b]].get(mode="promise_in_bounds")
            routers.append(router)
            bs.append(b)
            occ = router != EMPTY
            lb = jnp.where(occ, b, lb)
            lv = jnp.where(occ, router, lv)
            go_right = v >= router               # EMPTY always routes right
            b = jnp.where(b < bottom0, 2 * b + go_right.astype(b.dtype), b)
        # post-hoc candidate fold: occupied non-leaf positions on the path
        # are the internal ancestors; v < router there means a left turn
        cand = jnp.full((k,), big, value.dtype)
        for router, bi in zip(routers, bs):
            fold = (router != EMPTY) & (bi != lb) & (v < router) & (router < cand)
            cand = jnp.where(fold, router, cand)
        at_bottom = lb >= bottom0
        slot = jnp.where(at_bottom, lb - bottom0, 0)
        ch = child.at[dnc, slot].get(mode="promise_in_bounds")
        nxt = jnp.where(at_bottom, ch, jnp.int32(-1))
        act = ~s["resolved"]
        done_now = act & (nxt < 0)
        return dict(
            dn=jnp.where(act & (nxt >= 0), nxt, s["dn"]),
            resolved=s["resolved"] | done_now,
            leaf_val=jnp.where(done_now, lv, s["leaf_val"]),
            leaf_b=jnp.where(done_now, lb, s["leaf_b"]),
            final_dn=jnp.where(done_now, s["dn"], s["final_dn"]),
            hops=s["hops"] + act.astype(jnp.int32),
            cand=jnp.where(act & (cand < s["cand"]), cand, s["cand"]),
            rounds=s["rounds"] + 1,
        )

    s = jax.lax.while_loop(cond, body, state)
    return (s["leaf_val"], s["leaf_b"], s["final_dn"], s["hops"], s["cand"])


@functools.partial(
    jax.jit, static_argnames=("height", "max_rounds", "max_out", "pmask"))
def ref_delta_scan_fused(value: jax.Array, mark: jax.Array, child: jax.Array,
                         root: jax.Array, starts: jax.Array, his: jax.Array,
                         *, height: int, max_rounds: int, max_out: int,
                         pmask: int = 0):
    """Fused emit-cursor scan frontier, XLA-compiled: the whole
    find/verify/emit loop in one program (contract of ``ops.delta_scan``).

    Each lane carries an emit cursor over the packed key space and fills
    ``out[lane, :]`` with the live *leaf* values in ``(start, hi]`` in key
    order (packed, ascending; ``walk_big`` pads unused slots).  ``starts``
    and ``his`` are packed ``qpack`` bounds: start exclusive, hi inclusive
    in key space (``v > start_q`` iff ``key(v) > start_key`` since qpack
    packs an all-ones payload).  A lane alternates two pass kinds over the
    same blind-descent round structure as ``ref_delta_walk_fused``:

    * FIND — a successor walk from the root for the cursor, folding
      left-turn routers plus the final live leaf into a candidate;
    * VERIFY — an exact walk for the candidate key (candidate routers may
      be tombstones); a live hit is emitted and becomes the new cursor, a
      dead one is chased (cursor advances past it without emitting).

    Overflow buffers are NOT consulted — the engine dispatch merges
    I5' buffered items into the emitted run (``repro.core.engine``), so
    both engines share one merge and stay bit-identical.

    Returns (out (K, max_out) packed, n (K,) int32, hops (K,) int32,
    more (K,) bool).  ``hops`` counts ΔNode visits across every pass —
    exactly the rounds the lane stayed active, matching ``delta_walk``'s
    accounting.  ``more`` marks lanes whose buffer filled with live items
    remaining; the continuation cursor is the last emitted key
    (``key_of(out[lane, n-1])``).  A lane whose start equals ``walk_big``
    is born done (the q_tile pad contract).
    """
    from repro.kernels.veb_search import walk_big

    h = height
    bottom0 = 2 ** (h - 1)
    m, ub = value.shape
    pos = jnp.asarray(layout.veb_pos_table(h))
    big = jnp.asarray(walk_big(value.dtype), value.dtype)
    starts = starts.astype(value.dtype)
    his = his.astype(value.dtype)
    k = starts.shape[0]
    vflat = value.reshape(-1)
    mflat = mark.reshape(-1)
    dn0 = jnp.broadcast_to(jnp.asarray(root, jnp.int32), (k,))
    pm = jnp.asarray(pmask, value.dtype)

    state = dict(
        dn=dn0,
        verify=jnp.zeros((k,), jnp.bool_),
        q=starts,                       # FIND: cursor_q; VERIFY: pending_q
        cursor=starts,                  # start / last emitted (packed qpack)
        cand=jnp.full((k,), big, value.dtype),
        out=jnp.full((k, max_out), big, value.dtype),
        n=jnp.zeros((k,), jnp.int32),
        hops=jnp.zeros((k,), jnp.int32),
        more=jnp.zeros((k,), jnp.bool_),
        done=starts == big,             # sentinel lanes born done
        rounds=jnp.int32(0),
    )

    def cond(s):
        return jnp.any(~s["done"]) & (s["rounds"] < max_rounds)

    def body(s):
        dnc = jnp.clip(s["dn"], 0, m - 1)
        base = dnc * ub
        v = s["q"]
        b = jnp.ones((k,), jnp.int32)
        lb = jnp.ones((k,), jnp.int32)          # last occupied position
        lv = jnp.zeros((k,), value.dtype)
        routers, bs = [], []
        for _ in range(h):                       # blind descent: h gathers
            router = vflat.at[base + pos[b]].get(mode="promise_in_bounds")
            routers.append(router)
            bs.append(b)
            occ = router != EMPTY
            lb = jnp.where(occ, b, lb)
            lv = jnp.where(occ, router, lv)
            go_right = v >= router               # EMPTY always routes right
            b = jnp.where(b < bottom0, 2 * b + go_right.astype(b.dtype), b)
        rcand = jnp.full((k,), big, value.dtype)
        for router, bi in zip(routers, bs):      # post-hoc candidate fold
            fold = ((router != EMPTY) & (bi != lb) & (v < router)
                    & (router < rcand))
            rcand = jnp.where(fold, router, rcand)
        at_bottom = lb >= bottom0
        slot = jnp.where(at_bottom, lb - bottom0, 0)
        ch = child.at[dnc, slot].get(mode="promise_in_bounds")
        nxt = jnp.where(at_bottom, ch, jnp.int32(-1))
        act = ~s["done"]
        hopping = act & (nxt >= 0)
        res = act & (nxt < 0)                    # pass resolved this round
        # pass-level candidate fold (FIND passes only)
        cand = jnp.where(act & ~s["verify"] & (rcand < s["cand"]),
                         rcand, s["cand"])
        leaf_mark = mflat.at[base + pos[lb]].get(mode="promise_in_bounds")
        leaf_live = (lv != EMPTY) & ~leaf_mark
        # FIND resolution: fold the final leaf, then accept / stop
        f_res = res & ~s["verify"]
        leaf_fold = f_res & leaf_live & (lv > s["cursor"]) & (lv < cand)
        cand = jnp.where(leaf_fold, lv, cand)
        f_none = f_res & ((cand == big) | (cand > his))
        pending = cand | pm                      # qpack of candidate key
        to_verify = f_res & ~f_none
        # VERIFY resolution: emit a live hit, chase a tombstone
        v_res = res & s["verify"]
        hit = v_res & leaf_live & ((lv | pm) == s["q"])
        can_emit = s["n"] < max_out
        emit = hit & can_emit
        full = hit & ~can_emit
        chase = v_res & ~hit
        col = jnp.arange(max_out, dtype=jnp.int32)[None, :]
        out = jnp.where(emit[:, None] & (col == s["n"][:, None]),
                        lv[:, None], s["out"])
        back_to_find = emit | chase
        restart = to_verify | back_to_find
        return dict(
            dn=jnp.where(hopping, nxt, jnp.where(restart, dn0, s["dn"])),
            verify=jnp.where(to_verify, True,
                             jnp.where(back_to_find, False, s["verify"])),
            q=jnp.where(to_verify, pending, s["q"]),
            cursor=jnp.where(back_to_find, s["q"], s["cursor"]),
            cand=jnp.where(restart, big, cand),
            out=out,
            n=s["n"] + emit.astype(jnp.int32),
            hops=s["hops"] + act.astype(jnp.int32),
            more=s["more"] | full,
            done=s["done"] | f_none | full,
            rounds=s["rounds"] + 1,
        )

    s = jax.lax.while_loop(cond, body, state)
    return s["out"], s["n"], s["hops"], s["more"]


@functools.partial(jax.jit, static_argnames=("height",))
def ref_delta_search(value: jax.Array, child: jax.Array, root: jax.Array,
                     queries: jax.Array, *, height: int):
    """Oracle for the multi-hop ΔTree search over (value, child) arena rows.

    Returns (leaf_val, leaf_b, final_dn) per query — identical contract to
    `kernels.ops.delta_search`.
    """
    pos = jnp.asarray(layout.veb_pos_table(height))
    bottom0 = 2 ** (height - 1)

    def one(v):
        def cond(s):
            return ~s[2]

        def body(s):
            dn, b, _ = s
            at_bottom = b >= bottom0
            left = jnp.where(
                at_bottom, EMPTY, value[dn, pos[jnp.minimum(2 * b, 2 * bottom0 - 1)]]
            )
            internal = (~at_bottom) & (left != EMPTY)
            router = value[dn, pos[b]]
            slot = jnp.where(at_bottom, b - bottom0, 0)
            ch = jnp.where(at_bottom, child[dn, slot], jnp.int32(-1))
            hop = at_bottom & (ch >= 0)
            nb = jnp.where(internal, 2 * b + (v >= router).astype(jnp.int32), b)
            nb = jnp.where(hop, jnp.int32(1), nb)
            ndn = jnp.where(hop, ch, dn)
            done = (~internal) & (~hop)
            return ndn, nb, done

        dn, b, _ = jax.lax.while_loop(
            cond, body, (jnp.int32(root), jnp.int32(1), jnp.bool_(False))
        )
        return value[dn, pos[b]], b, dn

    return jax.vmap(one)(queries)


@jax.jit
def ref_paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               seq_lens: jax.Array):
    """Oracle for ΔTree-paged decode attention.

    q:            (B, QH, D)
    k/v_pages:    (NP, PS, KVH, D)
    block_tables: (B, MAXP) int32 physical page ids (-1 = unused)
    seq_lens:     (B,) int32

    Gathers each sequence's pages into a contiguous (S, KVH, D) cache, then
    runs masked GQA decode attention in f32. Returns (B, QH, D) in q.dtype.
    """
    b, qh, d = q.shape
    np_, ps, kvh, _ = k_pages.shape
    maxp = block_tables.shape[1]
    g = qh // kvh
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    bt = jnp.maximum(block_tables, 0)
    k = k_pages[bt]  # (B, MAXP, PS, KVH, D)
    v = v_pages[bt]
    k = k.reshape(b, maxp * ps, kvh, d).astype(jnp.float32)
    v = v.reshape(b, maxp * ps, kvh, d).astype(jnp.float32)

    qf = q.reshape(b, kvh, g, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k) * scale
    mask = jnp.arange(maxp * ps)[None, :] < seq_lens[:, None]  # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return out.reshape(b, qh, d).astype(q.dtype)
