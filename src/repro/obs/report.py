"""Render and diff consolidated ``BENCH_*.json`` files.

``benchmarks/run.py`` consolidates every row of a run into one JSON file
(``{"timestamp", "args", "meta", "rows"}``); this CLI turns those files
into something a human can read across PRs::

    python -m repro.obs.report BENCH_NEW.json                 # tables
    python -m repro.obs.report BENCH_NEW.json --diff OLD.json # + deltas
    python -m repro.obs.report NEW.json --diff OLD.json --out report.md

The diff matches rows by their identity fields (suite/backend/engine/...)
— tolerantly, so files written by different schema generations still
pair up (a key missing on one side is a wildcard) — and reports a
speedup factor per pair on the row's primary metric (``ops_per_s``
higher-better; ``*_us``/``seconds``/``loads`` lower-better).  Pairs
below ``--threshold`` are flagged as regressions;
``--fail-on-regression`` turns flags into a non-zero exit (off by
default: CI smoke numbers are noisy by design and only the rendered
artifact is meant for eyes).

stdlib-only on purpose: the CLI must render a report without importing
jax (fast, and usable on machines that only have the JSON files).
"""

from __future__ import annotations

import argparse
import json
import sys

# Row fields that identify *what* was measured (matched in the diff) —
# everything else is either a measurement or an execution-mode stamp.
ID_KEYS = [
    "suite", "bench", "backend", "engine", "dispatch", "walk",
    "maintenance", "update_pct", "batch", "ub", "height", "shards",
    "devices", "q_tile", "flush_every", "initial_keys", "seed", "skipped",
    "density", "max_items",
]

# Execution-mode stamps (obs PR): describe the machine, not the workload.
META_KEYS = ["device_kind", "interpret", "x64", "jax_version"]

# Lower-is-better metrics; anything else numeric is higher-is-better.
LOWER_BETTER = {
    "seconds", "compile_seconds", "paged_step_us", "dense_step_us",
    "p50_us", "p99_us", "loads", "blocks_b16", "blocks_b128",
    "hops", "hops_mean", "hops_max", "hops_per_search", "rounds",
    "inline_maint", "admit_wait", "queue_hwm", "walk_launches",
}

# Primary metric per row, first present wins (name, higher_is_better).
PRIMARY = [("ops_per_s", True), ("scans_per_s", True),
           ("paged_step_us", False), ("loads", False), ("seconds", False)]


def load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, list):  # bare row list (hand-rolled files)
        data = {"timestamp": "?", "args": {}, "rows": data}
    return data


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    if isinstance(v, list):
        s = ",".join(_fmt(x) for x in v)
        return "[" + (s if len(s) <= 18 else s[:15] + "...") + "]"
    if v is None:
        return "-"
    return str(v)


def _table(rows: list[dict], cols: list[str]) -> list[str]:
    cells = [[_fmt(r.get(c)) for c in cols] for r in rows]
    widths = [max([len(c)] + [len(row[i]) for row in cells])
              for i, c in enumerate(cols)]
    out = ["  ".join(c.ljust(w) for c, w in zip(cols, widths)).rstrip()]
    out.append("  ".join("-" * w for w in widths))
    out.extend("  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
               for row in cells)
    return out


def _suite_cols(rows: list[dict]) -> list[str]:
    present: list[str] = []
    for r in rows:
        for k in r:
            if k not in present:
                present.append(k)
    ids = [k for k in ID_KEYS if k in present and k != "suite"]
    metrics = [k for k in present
               if k not in ID_KEYS and k not in META_KEYS]
    return ids + metrics


def by_suite(rows: list[dict]) -> dict[str, list[dict]]:
    out: dict[str, list[dict]] = {}
    for r in rows:
        out.setdefault(str(r.get("suite", "unknown")), []).append(r)
    return out


def render(bench: dict, title: str = "") -> list[str]:
    lines = []
    if title:
        lines.append(f"# {title}")
    meta = bench.get("meta") or {}
    stamp = ", ".join(f"{k}={_fmt(meta[k])}" for k in META_KEYS if k in meta)
    args = bench.get("args") or {}
    lines.append(f"timestamp: {bench.get('timestamp', '?')}"
                 + (f"  ({stamp})" if stamp else ""))
    if args:
        lines.append("args: " + json.dumps(args, sort_keys=True))
    for suite, rows in sorted(by_suite(bench["rows"]).items()):
        lines.append("")
        lines.append(f"## {suite} ({len(rows)} rows)")
        lines.extend(_table(rows, _suite_cols(rows)))
    return lines


# ---------------------------------------------------------------- diff ---


def _row_label(r: dict) -> str:
    return " ".join(
        _fmt(r[k]) for k in ("bench", "backend", "engine", "dispatch",
                             "maintenance", "update_pct", "batch", "ub",
                             "height", "shards", "density", "max_items")
        if r.get(k) is not None) or "(row)"


def _primary_one(row: dict):
    for name, _higher in PRIMARY:
        v = row.get(name)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            return name, float(v)
    return None


def history(benches: list[dict]) -> list[str]:
    """Per-suite trajectory tables across many BENCH files: one row per
    measured identity, one column per timestamp, cells the row's primary
    metric — the at-a-glance perf record across committed artifacts."""
    benches = sorted(benches, key=lambda b: str(b.get("timestamp", "?")))
    stamps: list[str] = []
    for b in benches:
        ts = str(b.get("timestamp", "?"))
        while ts in stamps:  # duplicate stamps still get a column each
            ts += "'"
        stamps.append(ts)
    suites: dict[str, dict[str, dict]] = {}
    for b, ts in zip(benches, stamps):
        for suite, rows in by_suite(b.get("rows", [])).items():
            per = suites.setdefault(suite, {})
            for r in rows:
                p = _primary_one(r)
                if p is None:
                    continue
                name, v = p
                cell = per.setdefault(_row_label(r), {"metric": name})
                cell[ts] = v
    lines = [f"# history across {len(benches)} files"]
    for suite in sorted(suites):
        table = [{"row": label, **cells}
                 for label, cells in sorted(suites[suite].items())]
        if not table:  # no row in the suite carried a primary metric
            continue
        lines.append("")
        lines.append(f"## {suite} ({len(table)} rows)")
        lines.extend(_table(table, ["row", "metric"] + stamps))
    return lines


def _match(new_row: dict, base_rows: list[dict]) -> dict | None:
    """Base row whose identity agrees with ``new_row`` on every ID key
    present in *both* rows (schema-generation tolerant); None when the
    match is absent or ambiguous."""
    hits = []
    for b in base_rows:
        shared = [k for k in ID_KEYS if k in new_row and k in b]
        if shared and all(new_row[k] == b[k] for k in shared):
            hits.append(b)
    return hits[0] if len(hits) == 1 else None


def _primary(new_row: dict, base_row: dict):
    for name, higher in PRIMARY:
        a, b = new_row.get(name), base_row.get(name)
        if isinstance(a, (int, float)) and isinstance(b, (int, float)):
            return name, higher, float(a), float(b)
    return None


def diff(new: dict, base: dict, threshold: float = 0.9):
    """Pairwise speedups new-vs-base.  Returns (lines, regressions)."""
    lines, regressions = [], []
    base_by = by_suite(base["rows"])
    for suite, rows in sorted(by_suite(new["rows"]).items()):
        pool = list(base_by.get(suite, []))
        pairs, unmatched = [], 0
        for r in rows:
            b = _match(r, pool)
            if b is None:
                unmatched += 1
                continue
            pool.remove(b)  # a base row pairs at most once
            p = _primary(r, b)
            if p is None:
                continue
            name, higher, av, bv = p
            if min(av, bv) <= 0:
                continue
            speedup = (av / bv) if higher else (bv / av)
            label = " ".join(
                _fmt(r[k]) for k in ("backend", "engine", "dispatch",
                                     "maintenance", "update_pct", "batch",
                                     "ub")
                if r.get(k) is not None)
            flag = ""
            if speedup < threshold:
                flag = "  << REGRESSION"
                regressions.append((suite, label, name, speedup))
            pairs.append({"row": label, "metric": name,
                          "base": _fmt(bv), "new": _fmt(av),
                          "speedup": f"{speedup:.3f}x{flag}"})
        lines.append("")
        lines.append(f"## {suite}: {len(pairs)} matched"
                     + (f", {unmatched} unmatched" if unmatched else ""))
        if pairs:
            lines.extend(_table(pairs,
                                ["row", "metric", "base", "new", "speedup"]))
    return lines, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="render / diff consolidated BENCH_*.json files")
    ap.add_argument("bench", nargs="+",
                    help="BENCH_*.json to render (several with --history)")
    ap.add_argument("--diff", default=None, metavar="BASE",
                    help="baseline BENCH_*.json to diff against")
    ap.add_argument("--history", action="store_true",
                    help="render a per-suite trajectory table across all "
                         "given files (primary metric per timestamp)")
    ap.add_argument("--threshold", type=float, default=0.9,
                    help="speedup below this flags a regression (0.9)")
    ap.add_argument("--out", default=None,
                    help="also write the rendered report to this path")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 when any pair regresses past --threshold")
    args = ap.parse_args(argv)

    if args.history:
        text = "\n".join(history([load(p) for p in args.bench])) + "\n"
        sys.stdout.write(text)
        if args.out:
            with open(args.out, "w") as f:
                f.write(text)
        return 0
    if len(args.bench) > 1:
        ap.error("multiple BENCH files need --history")
    new = load(args.bench[0])
    lines = render(new, title=f"bench report: {args.bench[0]}")
    regressions = []
    if args.diff:
        base = load(args.diff)
        lines.append("")
        lines.append(f"# diff vs {args.diff} "
                     f"(timestamp {base.get('timestamp', '?')})")
        dl, regressions = diff(new, base, threshold=args.threshold)
        lines.extend(dl)
        lines.append("")
        lines.append(f"regressions (<{args.threshold}x): {len(regressions)}")
    text = "\n".join(lines) + "\n"
    sys.stdout.write(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    return 1 if (regressions and args.fail_on_regression) else 0


if __name__ == "__main__":
    raise SystemExit(main())
