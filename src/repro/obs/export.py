"""Metrics export: point-in-time snapshots of the stats pytrees.

Every counter pytree in ``repro.obs.stats`` already knows how to render
itself host-side (``asdict``).  This module composes those dicts into
one named snapshot and serializes it two ways:

- ``to_prometheus(snap)``: Prometheus text exposition (version 0.0.4) —
  scalars become gauges, list-valued counters (histogram bins, per-shard
  lanes, round occupancy) become labeled series with an ``index`` label.
- ``to_json(snap)``: the same snapshot as a JSON document (for BENCH
  rows, dashboards that ingest JSON, or plain logging).

``ServeScheduler.metrics()`` is the live producer: it snapshots the
decode loop's ``ServeStats``, the maintenance worker's drain counters
and the pager's host-side op counters each call.  stdlib+numpy only —
rendering a snapshot must never trace or sync anything beyond the
``asdict`` host reads the stats classes already do.
"""

from __future__ import annotations

import json
import numbers

import numpy as np

__all__ = ["snapshot", "to_prometheus", "to_json"]


def _plain(v):
    """Coerce one metric value to a JSON/Prometheus-safe plain type."""
    if isinstance(v, (bool, np.bool_)):
        return int(v)
    if isinstance(v, numbers.Number):
        return v.item() if hasattr(v, "item") else v
    if isinstance(v, (list, tuple, np.ndarray)):
        return [_plain(x) for x in np.asarray(v).tolist()]
    if hasattr(v, "item"):  # 0-d jax array
        return v.item()
    return v


def snapshot(**groups) -> dict:
    """Compose named stats into one plain-python snapshot.

    Each keyword is a group name mapping to a stats pytree (anything
    with ``asdict``), a plain dict of numbers, or ``None`` (dropped) —
    e.g. ``snapshot(search=rs.search, transfers=rs.transfers,
    serve=sched.obs, maintenance=worker.stats())``.
    """
    out = {}
    for name, obj in groups.items():
        if obj is None:
            continue
        d = obj.asdict() if hasattr(obj, "asdict") else dict(obj)
        out[name] = {k: _plain(v) for k, v in d.items()}
    return out


def to_prometheus(snap: dict, prefix: str = "repro") -> str:
    """Render a ``snapshot`` as Prometheus text exposition."""
    lines = []
    for group in sorted(snap):
        for key in snap[group]:
            v = snap[group][key]
            name = f"{prefix}_{group}_{key}"
            lines.append(f"# TYPE {name} gauge")
            if isinstance(v, list):
                lines.extend(
                    f'{name}{{index="{i}"}} {_num(x)}'
                    for i, x in enumerate(v))
            else:
                lines.append(f"{name} {_num(v)}")
    return "\n".join(lines) + "\n" if lines else ""


def _num(v) -> str:
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, float):
        return repr(v)
    if isinstance(v, numbers.Number):
        return str(v)
    raise TypeError(f"non-numeric metric value {v!r}")


def to_json(snap: dict, **dump_kw) -> str:
    dump_kw.setdefault("sort_keys", True)
    return json.dumps(snap, **dump_kw)
