"""repro.obs — cross-cutting observability (DESIGN.md §9).

Three kinds of instrument, all zero-cost when off:

- **Counter pytrees** (`obs.stats`): jit/shard_map-safe NamedTuples of
  int32 counters riding the return path, generalizing the
  ``MaintenanceStats`` pattern (which now lives here) — ``SearchStats``
  for the read path, ``RouterStats`` for the forest router,
  ``ServeStats`` for the decode loop.  Collection is gated by the
  *static* ``TreeConfig.collect_stats`` flag: the disabled path lowers
  to HLO byte-identical to a build without the stats code at all
  (asserted by ``tests/test_obs.py``).
- **Trace spans** (`obs.trace`): ``jax.profiler.TraceAnnotation`` /
  ``jax.named_scope`` wrappers around engine dispatch, ``delta_walk``
  rounds, router dispatch and maintenance phases, gated by the
  ``REPRO_TRACE`` env var, plus an xprof trace-dump helper
  (``obs.trace.capture``) for the compiled-performance campaign.
- **Benchmark reports** (`obs.report`): a stdlib-only CLI that renders
  consolidated ``BENCH_*.json`` files as per-suite tables and *diffs*
  them against a baseline file (speedup deltas, regression flags)::

      python -m repro.obs.report BENCH_NEW.json --diff BENCH_OLD.json
"""

from repro.obs import report, stats, trace
from repro.obs.stats import (
    MaintenanceStats,
    ReadStats,
    RouterStats,
    SearchStats,
    ServeStats,
)

__all__ = [
    "MaintenanceStats",
    "ReadStats",
    "RouterStats",
    "SearchStats",
    "ServeStats",
    "report",
    "stats",
    "trace",
]
