"""repro.obs — cross-cutting observability (DESIGN.md §9).

Three kinds of instrument, all zero-cost when off:

- **Counter pytrees** (`obs.stats`): jit/shard_map-safe NamedTuples of
  int32 counters riding the return path, generalizing the
  ``MaintenanceStats`` pattern (which now lives here) — ``SearchStats``
  for the read path, ``RouterStats`` for the forest router,
  ``ServeStats`` for the decode loop.  Collection is gated by the
  *static* ``TreeConfig.collect_stats`` flag: the disabled path lowers
  to HLO byte-identical to a build without the stats code at all
  (asserted by ``tests/test_obs.py``).
- **Trace spans** (`obs.trace`): ``jax.profiler.TraceAnnotation`` /
  ``jax.named_scope`` wrappers around engine dispatch, ``delta_walk``
  rounds, router dispatch and maintenance phases, gated by the
  ``REPRO_TRACE`` env var, plus an xprof trace-dump helper
  (``obs.trace.capture``) for the compiled-performance campaign.
- **Benchmark reports** (`obs.report`): a stdlib-only CLI that renders
  consolidated ``BENCH_*.json`` files as per-suite tables, *diffs* them
  against a baseline file (speedup deltas, regression flags), and
  renders per-suite ``--history`` trajectories across many files::

      python -m repro.obs.report BENCH_NEW.json --diff BENCH_OLD.json
      python -m repro.obs.report BENCH_*.json --history

Two more instruments complete the transfer-accounting loop
(DESIGN.md §14):

- **Measured transfers** (`obs.transfers`): a device-side replay of the
  descent deriving ``TransferStats`` — distinct ΔNode visits and
  distinct B-block touches per read batch — equal on a quiescent tree
  to the analytical `core.baselines.count_block_transfers` *exactly*,
  gated by ``TreeConfig.collect_transfers`` under ``collect_stats``.
- **Metrics export** (`obs.export`): stats pytrees → one named snapshot
  → Prometheus text exposition / JSON (``ServeScheduler.metrics()`` is
  the live producer), plus `obs.trace.write_chrome_trace` for a
  perfetto-compatible span timeline.
"""

from repro.obs import export, report, stats, trace, transfers
from repro.obs.stats import (
    MaintenanceStats,
    ReadStats,
    RouterStats,
    ScanStats,
    SearchStats,
    ServeStats,
    TransferStats,
)

__all__ = [
    "MaintenanceStats",
    "ReadStats",
    "RouterStats",
    "ScanStats",
    "SearchStats",
    "ServeStats",
    "TransferStats",
    "export",
    "report",
    "stats",
    "trace",
    "transfers",
]
