"""Counter pytrees — jit/shard_map-safe telemetry riding the return path.

Every class here follows the contract ``MaintenanceStats`` (PR 4)
established: a ``NamedTuple`` of small jax arrays (so it flows through
``jit`` / ``donate_argnums`` / ``shard_map`` unchanged), a ``zero()``
constructor, a ``reduce()`` that aggregates a stacked (S,) leading axis
(per-shard legs: *rounds-like* fields take the max — shards run
concurrently, so the critical path is what you'd measure — while
*work-like* fields sum), a ``merge()`` that folds two instances (for
accumulating across benchmark steps without a host sync), and a host-side
``asdict()`` for JSON rows and logging.

Collection is gated by the static ``TreeConfig.collect_stats`` flag and
happens in the *dispatch* layers (``repro.core.engine``,
``repro.distributed.forest``), never inside an engine hook — both
SearchEngines produce bit-identical ``found``/``hops`` columns
(conformance-tested), so computing ``SearchStats`` from those columns
makes the cross-engine histogram parity structural rather than something
each engine must re-earn.

This module imports only jax — no ``repro`` modules — so any layer of
the stack (kernels included) can depend on it without cycles.
"""

from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

HOP_BINS = 16          # SearchStats histogram bins (hops clip to the last)
OCC_ROUNDS = 16        # SearchStats per-round occupancy window
LATENCY_RESERVOIR = 512  # ServeStats ring-buffer capacity (decode steps)
TRANSFER_BLOCK_SIZES = (8, 16, 32, 64)  # TransferStats block-size sweep (B)


class MaintenanceStats(NamedTuple):
    """Why and how much maintenance ran during one update step.

    Returned (alongside the tree and per-op results) by every
    ``update_batch`` / forest ``update_batch`` / ``Index.update`` call,
    and by ``flush``.  Re-homed from ``repro.maintenance.stats`` (which
    still re-exports it) when ``repro.obs`` became the home of every
    counter pytree.
    """

    rounds: jax.Array    # () int32 — scheduler rounds taken
    rebuilds: jax.Array  # () int32 — Rebalance mirror-swaps
    expands: jax.Array   # () int32 — child ΔNodes allocated by Expand
    merges: jax.Array    # () int32 — successful Merge splices
    pending: jax.Array   # () int32 — buffered items carried forward (I5')
    reclaimed: jax.Array = jnp.int32(0)  # () int32 — arena slots freed
    #                      by Merge splicing away a child ΔNode (the
    #                      freelist-pressure signal the budgeted Merge
    #                      ranking feeds on; trailing default keeps older
    #                      5-field construction sites valid)

    @classmethod
    def zero(cls) -> "MaintenanceStats":
        z = jnp.int32(0)
        return cls(rounds=z, rebuilds=z, expands=z, merges=z, pending=z,
                   reclaimed=z)

    @classmethod
    def reduce(cls, stacked: "MaintenanceStats") -> "MaintenanceStats":
        """Aggregate per-shard (S,) stats: rounds is the critical path
        (max over shards — shards run concurrently), work counters sum."""
        return cls(
            rounds=jnp.max(stacked.rounds),
            rebuilds=jnp.sum(stacked.rebuilds),
            expands=jnp.sum(stacked.expands),
            merges=jnp.sum(stacked.merges),
            pending=jnp.sum(stacked.pending),
            reclaimed=jnp.sum(stacked.reclaimed),
        )

    def merge(self, other: "MaintenanceStats") -> "MaintenanceStats":
        """Fold two steps' stats (rounds max, work sums; pending is the
        latest step's carry — the earlier one was superseded)."""
        return MaintenanceStats(
            rounds=jnp.maximum(self.rounds, other.rounds),
            rebuilds=self.rebuilds + other.rebuilds,
            expands=self.expands + other.expands,
            merges=self.merges + other.merges,
            pending=other.pending,
            reclaimed=self.reclaimed + other.reclaimed,
        )

    def asdict(self) -> dict:
        """Host-side plain-int view (for JSON benchmark rows / logging)."""
        return {k: int(v) for k, v in self._asdict().items()}

    # ---- deprecation shim: the old third tuple element was ``rounds`` ----

    def __int__(self) -> int:
        warnings.warn(
            "update_batch now returns MaintenanceStats as its third "
            "element; use stats.rounds instead of treating it as the "
            "round count",
            DeprecationWarning,
            stacklevel=2,
        )
        return int(self.rounds)

    __index__ = __int__


class SearchStats(NamedTuple):
    """One read batch, as the paper would measure it (§5, Table 1).

    ``hops`` is the per-query transfer statistic both engines report
    bit-identically (ΔNode boundary crossings == lockstep rounds active),
    so every field derives from the same columns on either engine:
    ``rounds`` is the frontier's round count (max hops over the batch —
    the lockstep walk runs exactly that many kernel launches), and
    ``occupancy[r]`` counts the lanes still active entering round r (a
    query with h hops is active in rounds 0..h-1) — the frontier decay
    profile the compiled campaign needs to size ``q_tile``.
    """

    queries: jax.Array      # () int32 — lanes in the batch (pads included)
    pad_lanes: jax.Array    # () int32 — born-resolved ROUTE_LEFT lanes
    hops_sum: jax.Array     # () int32 — total ΔNode transfers
    hops_max: jax.Array     # () int32 — deepest walk in the batch
    rounds: jax.Array       # () int32 — lockstep frontier rounds (= hops_max)
    buffer_hits: jax.Array  # () int32 — queries resolved from overflow buffers
    hops_hist: jax.Array    # (HOP_BINS,) int32 — hops histogram (clipped)
    occupancy: jax.Array    # (OCC_ROUNDS,) int32 — active lanes per round

    @classmethod
    def zero(cls) -> "SearchStats":
        z = jnp.int32(0)
        return cls(queries=z, pad_lanes=z, hops_sum=z, hops_max=z, rounds=z,
                   buffer_hits=z,
                   hops_hist=jnp.zeros((HOP_BINS,), jnp.int32),
                   occupancy=jnp.zeros((OCC_ROUNDS,), jnp.int32))

    @classmethod
    def of(cls, hops: jax.Array, pad: jax.Array,
           buffer_hit: jax.Array) -> "SearchStats":
        """Derive the batch's stats from its per-query columns:
        ``hops[K]`` int32, ``pad[K]`` bool (sentinel lanes), and
        ``buffer_hit[K]`` bool (found via an overflow buffer)."""
        hops = jnp.asarray(hops, jnp.int32)
        hmax = jnp.max(hops)
        hist = jnp.zeros((HOP_BINS,), jnp.int32).at[
            jnp.clip(hops, 0, HOP_BINS - 1)].add(1)
        occ = jnp.sum(
            hops[None, :] > jnp.arange(OCC_ROUNDS, dtype=jnp.int32)[:, None],
            axis=1, dtype=jnp.int32)
        return cls(
            queries=jnp.int32(hops.shape[0]),
            pad_lanes=jnp.sum(pad, dtype=jnp.int32),
            hops_sum=jnp.sum(hops),
            hops_max=hmax,
            rounds=hmax,
            buffer_hits=jnp.sum(buffer_hit, dtype=jnp.int32),
            hops_hist=hist,
            occupancy=occ,
        )

    @classmethod
    def reduce(cls, stacked: "SearchStats") -> "SearchStats":
        """Aggregate stacked (S,) legs: rounds-like fields max (concurrent
        frontiers — the critical path), work-like fields sum."""
        return cls(
            queries=jnp.sum(stacked.queries),
            pad_lanes=jnp.sum(stacked.pad_lanes),
            hops_sum=jnp.sum(stacked.hops_sum),
            hops_max=jnp.max(stacked.hops_max),
            rounds=jnp.max(stacked.rounds),
            buffer_hits=jnp.sum(stacked.buffer_hits),
            hops_hist=jnp.sum(stacked.hops_hist, axis=0),
            occupancy=jnp.sum(stacked.occupancy, axis=0),
        )

    def merge(self, other: "SearchStats") -> "SearchStats":
        """Fold another batch's stats in (benchmark-loop accumulation;
        stays device-side — no host sync mid-loop)."""
        return SearchStats(
            queries=self.queries + other.queries,
            pad_lanes=self.pad_lanes + other.pad_lanes,
            hops_sum=self.hops_sum + other.hops_sum,
            hops_max=jnp.maximum(self.hops_max, other.hops_max),
            rounds=jnp.maximum(self.rounds, other.rounds),
            buffer_hits=self.buffer_hits + other.buffer_hits,
            hops_hist=self.hops_hist + other.hops_hist,
            occupancy=self.occupancy + other.occupancy,
        )

    def asdict(self) -> dict:
        real = max(int(self.queries) - int(self.pad_lanes), 1)
        return {
            "queries": int(self.queries),
            "pad_lanes": int(self.pad_lanes),
            "hops_sum": int(self.hops_sum),
            "hops_max": int(self.hops_max),
            "hops_mean": round(int(self.hops_sum) / real, 3),
            "rounds": int(self.rounds),
            "buffer_hits": int(self.buffer_hits),
            "hops_hist": np.asarray(self.hops_hist).tolist(),
            "occupancy": np.asarray(self.occupancy).tolist(),
        }


class RouterStats(NamedTuple):
    """One routed batch through the forest router (skew telemetry — the
    load-adaptive ROADMAP item's input signal)."""

    lanes: jax.Array    # (S,) int32 — ops routed to each shard
    clamped: jax.Array  # () int32 — out-of-domain keys clamped by the router
    batches: jax.Array  # () int32 — batches folded in (1 for a fresh batch)

    @classmethod
    def zero(cls, num_shards: int) -> "RouterStats":
        return cls(lanes=jnp.zeros((num_shards,), jnp.int32),
                   clamped=jnp.int32(0), batches=jnp.int32(0))

    @classmethod
    def of(cls, lanes: jax.Array, clamped) -> "RouterStats":
        return cls(lanes=jnp.asarray(lanes, jnp.int32),
                   clamped=jnp.asarray(clamped, jnp.int32),
                   batches=jnp.int32(1))

    @classmethod
    def reduce(cls, stacked: "RouterStats") -> "RouterStats":
        """Aggregate stacked (N, S) legs (lane counts and clamps are all
        work-like: everything sums)."""
        return cls(lanes=jnp.sum(stacked.lanes, axis=0),
                   clamped=jnp.sum(stacked.clamped),
                   batches=jnp.sum(stacked.batches))

    def merge(self, other: "RouterStats") -> "RouterStats":
        return RouterStats(lanes=self.lanes + other.lanes,
                           clamped=self.clamped + other.clamped,
                           batches=self.batches + other.batches)

    def skew(self) -> float:
        """max/mean shard load — 1.0 is a perfectly balanced router."""
        lanes = np.asarray(self.lanes, np.float64)
        mean = lanes.mean()
        return float(lanes.max() / mean) if mean > 0 else 1.0

    def asdict(self) -> dict:
        return {
            "lanes": np.asarray(self.lanes).tolist(),
            "clamped": int(self.clamped),
            "batches": int(self.batches),
            "skew": round(self.skew(), 3),
        }


class TransferStats(NamedTuple):
    """Measured memory transfers of one read batch in the ideal-cache
    model (the paper's O(log_B N) claim, Table 1 / Lemma 2.1).

    Derived in the dispatch layers by replaying the walk's per-level
    gather indices device-side (`repro.obs.transfers`) — the replay
    depends only on (arena, roots, keys), never on which engine or
    dispatch produced the result, so cross-engine bit-parity is
    structural like ``SearchStats``.  ``blocks[i]`` is the batch total of
    *distinct* ``TRANSFER_BLOCK_SIZES[i]``-element blocks touched per
    query (what `core.baselines.count_block_transfers` counts, exactly);
    ``buffer_probes`` (SEARCHNODE's branchless overflow-buffer row read,
    one per resolved real query) is kept out of the block counts — the
    analytical model excludes it too.
    """

    queries: jax.Array         # () int32 — lanes in the batch (pads included)
    pad_lanes: jax.Array       # () int32 — born-resolved ROUTE_LEFT lanes
    dnode_visits: jax.Array    # () int32 — distinct ΔNodes entered (batch sum)
    router_touches: jax.Array  # () int32 — element reads steering the walk
    leaf_touches: jax.Array    # () int32 — terminal leaf-test reads
    buffer_probes: jax.Array   # () int32 — SEARCHNODE buffer-row probes
    blocks: jax.Array          # (len(TRANSFER_BLOCK_SIZES),) int32 totals
    batches: jax.Array         # () int32 — batches folded in

    @classmethod
    def zero(cls) -> "TransferStats":
        z = jnp.int32(0)
        return cls(queries=z, pad_lanes=z, dnode_visits=z, router_touches=z,
                   leaf_touches=z, buffer_probes=z,
                   blocks=jnp.zeros((len(TRANSFER_BLOCK_SIZES),), jnp.int32),
                   batches=z)

    @classmethod
    def of(cls, pad: jax.Array, visits: jax.Array, router: jax.Array,
           leaf: jax.Array, blocks: jax.Array) -> "TransferStats":
        """Derive the batch's stats from per-query columns: ``pad[K]``
        bool, ``visits[K]`` / ``router[K]`` / ``leaf[K]`` int32 counts,
        and ``blocks[K, len(TRANSFER_BLOCK_SIZES)]`` distinct-block
        counts (all already zero on pad lanes — `obs.transfers`)."""
        real = jnp.sum(~pad, dtype=jnp.int32)
        return cls(
            queries=jnp.int32(pad.shape[0]),
            pad_lanes=jnp.sum(pad, dtype=jnp.int32),
            dnode_visits=jnp.sum(visits, dtype=jnp.int32),
            router_touches=jnp.sum(router, dtype=jnp.int32),
            leaf_touches=jnp.sum(leaf, dtype=jnp.int32),
            buffer_probes=real,
            blocks=jnp.sum(blocks, axis=0, dtype=jnp.int32),
            batches=jnp.int32(1),
        )

    @classmethod
    def reduce(cls, stacked: "TransferStats") -> "TransferStats":
        """Aggregate stacked (S,) legs: transfers are all work-like —
        everything sums (concurrent shards still move every block)."""
        return cls(*(jnp.sum(x, axis=0) for x in stacked))

    def merge(self, other: "TransferStats") -> "TransferStats":
        return TransferStats(*(a + b for a, b in zip(self, other)))

    def asdict(self) -> dict:
        real = max(int(self.queries) - int(self.pad_lanes), 1)
        out = {
            "queries": int(self.queries),
            "pad_lanes": int(self.pad_lanes),
            "dnode_visits": int(self.dnode_visits),
            "router_touches": int(self.router_touches),
            "leaf_touches": int(self.leaf_touches),
            "buffer_probes": int(self.buffer_probes),
            "batches": int(self.batches),
            "visits_mean": round(int(self.dnode_visits) / real, 3),
        }
        for i, b in enumerate(TRANSFER_BLOCK_SIZES):
            out[f"blocks_b{b}"] = int(self.blocks[i])
            out[f"blocks_b{b}_mean"] = round(int(self.blocks[i]) / real, 3)
        return out


class ReadStats(NamedTuple):
    """What a stats-collecting read returns as its trailing element:
    the batch's ``SearchStats`` plus, on the forest dispatch, the
    router's ``RouterStats``, plus — under the ``collect_transfers``
    sub-gate — the measured ``TransferStats`` (``None`` legs flatten to
    nothing, so the jitted entry points stay shape-static either way)."""

    search: SearchStats
    router: RouterStats | None = None
    transfers: TransferStats | None = None


class ScanStats(NamedTuple):
    """Range-scan / bulk-ordered-read telemetry.  One ``of`` per scan
    dispatch (a whole lane batch); counters fold with ``merge`` and
    stacked legs aggregate with ``reduce`` like the other stats classes.
    ``truncated`` counts lanes whose output buffer filled before the
    range was exhausted (``more=True`` — the caller holds a continuation
    cursor), which is the honest signal that a sweep under-sized
    ``max_items``."""

    scans: jax.Array      # () int32 — scan dispatches folded in
    lanes: jax.Array      # () int32 — scan lanes served
    emitted: jax.Array    # () int32 — (key, payload) rows emitted
    truncated: jax.Array  # () int32 — lanes that filled max_items (more)
    hops_sum: jax.Array   # () int32 — total ΔNode visits across lanes
    hops_max: jax.Array   # () int32 — worst single-lane ΔNode visits

    @classmethod
    def zero(cls) -> "ScanStats":
        z = jnp.int32(0)
        return cls(scans=z, lanes=z, emitted=z, truncated=z,
                   hops_sum=z, hops_max=z)

    @classmethod
    def of(cls, n: jax.Array, hops: jax.Array,
           more: jax.Array) -> "ScanStats":
        """Build from one scan dispatch's per-lane columns (the engine's
        ``(out, n, hops, more)`` tail)."""
        return cls(scans=jnp.int32(1),
                   lanes=jnp.int32(n.shape[0]),
                   emitted=jnp.sum(n).astype(jnp.int32),
                   truncated=jnp.sum(more.astype(jnp.int32)),
                   hops_sum=jnp.sum(hops).astype(jnp.int32),
                   hops_max=jnp.max(hops).astype(jnp.int32))

    def merge(self, other: "ScanStats") -> "ScanStats":
        return ScanStats(scans=self.scans + other.scans,
                         lanes=self.lanes + other.lanes,
                         emitted=self.emitted + other.emitted,
                         truncated=self.truncated + other.truncated,
                         hops_sum=self.hops_sum + other.hops_sum,
                         hops_max=jnp.maximum(self.hops_max, other.hops_max))

    @classmethod
    def reduce(cls, stacked: "ScanStats") -> "ScanStats":
        """Aggregate stacked (S,) legs: counters sum, hops_max maxes."""
        return cls(scans=jnp.sum(stacked.scans),
                   lanes=jnp.sum(stacked.lanes),
                   emitted=jnp.sum(stacked.emitted),
                   truncated=jnp.sum(stacked.truncated),
                   hops_sum=jnp.sum(stacked.hops_sum),
                   hops_max=jnp.max(stacked.hops_max))

    def asdict(self) -> dict:
        return {k: int(v) for k, v in self._asdict().items()}


class ServeStats(NamedTuple):
    """Decode-loop telemetry: a fixed-size latency reservoir (ring buffer
    over the last ``LATENCY_RESERVOIR`` decode steps — p50/p99 come from
    it host-side) plus flush/pending counters.  Host-driven like the
    ServeEngine itself, but a pytree so it can ride jitted state.

    The serve-scheduler fields (queue depth high-water, admission waits,
    combined ops, fused-view cache hits/builds) default to zero on every
    ``record`` call, so the legacy lockstep decode loop keeps recording
    through the same class unchanged."""

    steps: jax.Array        # () int32 — decode steps recorded
    flushes: jax.Array      # () int32 — background flushes triggered
    pending_hwm: jax.Array  # () int32 — max pending maintenance seen
    queue_hwm: jax.Array    # () int32 — max waiting-queue depth seen
    admitted: jax.Array     # () int32 — requests admitted into live slots
    admit_wait: jax.Array   # () int32 — total steps admitted reqs waited
    combined: jax.Array     # () int32 — ops eliminated by op-combining
    view_hits: jax.Array    # () int32 — fused-view cache hits observed
    view_builds: jax.Array  # () int32 — fused-view cache builds observed
    probe_queries: jax.Array  # () int32 — read-service probe lookups issued
    probe_hits: jax.Array     # () int32 — probes that resolved a mapping
    lat_us: jax.Array       # (LATENCY_RESERVOIR,) float32 — step latencies

    @classmethod
    def zero(cls) -> "ServeStats":
        z = jnp.int32(0)
        return cls(steps=z, flushes=z, pending_hwm=z, queue_hwm=z,
                   admitted=z, admit_wait=z, combined=z, view_hits=z,
                   view_builds=z, probe_queries=z, probe_hits=z,
                   lat_us=jnp.zeros((LATENCY_RESERVOIR,), jnp.float32))

    def record(self, seconds, *, pending: int = 0, flushed: bool = False,
               queue_depth: int = 0, admitted: int = 0, admit_wait: int = 0,
               combined: int = 0, view_hits: int = 0,
               view_builds: int = 0) -> "ServeStats":
        """Fold one decode step in (ring-buffer write at ``steps`` mod
        capacity).  Host-side floats/bools or traced values both work."""
        idx = self.steps % self.lat_us.shape[0]
        return ServeStats(
            steps=self.steps + 1,
            flushes=self.flushes + jnp.int32(flushed),
            pending_hwm=jnp.maximum(self.pending_hwm, jnp.int32(pending)),
            queue_hwm=jnp.maximum(self.queue_hwm, jnp.int32(queue_depth)),
            admitted=self.admitted + jnp.int32(admitted),
            admit_wait=self.admit_wait + jnp.int32(admit_wait),
            combined=self.combined + jnp.int32(combined),
            view_hits=self.view_hits + jnp.int32(view_hits),
            view_builds=self.view_builds + jnp.int32(view_builds),
            probe_queries=self.probe_queries,
            probe_hits=self.probe_hits,
            lat_us=self.lat_us.at[idx].set(jnp.float32(seconds) * 1e6),
        )

    def record_probe(self, queries: int, hits: int) -> "ServeStats":
        """Fold one read-service ``probe`` call in (between decode steps
        — bumps no step counter and writes no latency sample)."""
        return self._replace(
            probe_queries=self.probe_queries + jnp.int32(queries),
            probe_hits=self.probe_hits + jnp.int32(hits))

    @classmethod
    def reduce(cls, stacked: "ServeStats") -> "ServeStats":
        """Aggregate stacked (N,) legs: counters sum, the high-water marks
        max, and the reservoirs concatenate (percentiles over the union)."""
        return cls(steps=jnp.sum(stacked.steps),
                   flushes=jnp.sum(stacked.flushes),
                   pending_hwm=jnp.max(stacked.pending_hwm),
                   queue_hwm=jnp.max(stacked.queue_hwm),
                   admitted=jnp.sum(stacked.admitted),
                   admit_wait=jnp.sum(stacked.admit_wait),
                   combined=jnp.sum(stacked.combined),
                   view_hits=jnp.sum(stacked.view_hits),
                   view_builds=jnp.sum(stacked.view_builds),
                   probe_queries=jnp.sum(stacked.probe_queries),
                   probe_hits=jnp.sum(stacked.probe_hits),
                   lat_us=stacked.lat_us.reshape(-1))

    def valid_latencies(self) -> np.ndarray:
        """Host-side view of the recorded step latencies (µs)."""
        n = min(int(self.steps), int(self.lat_us.shape[0]))
        return np.asarray(self.lat_us)[:n] if n else np.zeros((0,), np.float32)

    def percentiles(self, qs=(50, 99)) -> dict:
        lat = self.valid_latencies()
        if lat.size == 0:
            return {f"p{q}_us": 0.0 for q in qs}
        return {f"p{q}_us": round(float(np.percentile(lat, q)), 1)
                for q in qs}

    def asdict(self) -> dict:
        out = {"steps": int(self.steps), "flushes": int(self.flushes),
               "pending_hwm": int(self.pending_hwm),
               "queue_hwm": int(self.queue_hwm),
               "admitted": int(self.admitted),
               "admit_wait": int(self.admit_wait),
               "combined": int(self.combined),
               "view_hits": int(self.view_hits),
               "view_builds": int(self.view_builds),
               "probe_queries": int(self.probe_queries),
               "probe_hits": int(self.probe_hits)}
        out.update(self.percentiles())
        return out
