"""Measured memory-transfer accounting (the O(log_B N) loop, closed).

`core.transfers.delta_touch_fn` is the *analytical* side of the paper's
Table 1: a host-side replay of the descent that yields the flat element
indices an ideal cache would fetch.  This module is the *measured* side:
the same replay, written as a fixed-length ``lax.scan`` over the arena
pytree, so the dispatch layers (``core.engine``, ``distributed.forest``)
can derive a ``TransferStats`` counter pytree device-side from exactly
the inputs the walk consumed — (arena, roots, sid, keys) — under jit,
inside someone else's trace, for every engine and dispatch.

Because the replay never looks at which engine produced the read result,
cross-engine × cross-dispatch bit-parity is structural, the same argument
``SearchStats`` makes.  And because it appends exactly the indices the
host model appends (node read each micro-step; the leaf-test read only
when the left child is non-EMPTY; the terminating leaf-test read *not*
counted; SEARCHNODE's buffer probe kept out of block counting), the
measured distinct-block counts on a quiescent tree equal
`core.baselines.count_block_transfers` **exactly** — tier-1 tested.

Address space: per-shard flat indices ``dn * UB + vEB-position`` (the
model's ``stride = cfg.ub`` unpadded layout).  ROUTE_LEFT pad lanes are
born resolved and contribute zero touches, zero visits, zero blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.stats import TRANSFER_BLOCK_SIZES, TransferStats

# sorts after every real flat index; np (not jnp) so the lazy first
# import inside someone's jit trace can't mint a leaked tracer constant
_SENTINEL = np.int32(2**31 - 1)


def _replay(cfg, value, child, roots, sid, keys):
    """Replay each query's descent over stacked arenas.

    value (S, M, UB) packed, child (S, M, leaf_cap), roots[K] shard-local
    start ΔNodes, sid[K] owner-shard ids, keys[K] int32.  Returns
    (idx (K, 2T) int32 touched flat indices, SENTINEL-padded;
     visits[K], router[K], leaf[K] int32 per-query counts).
    """
    from repro.core import layout

    pos = jnp.asarray(layout.veb_pos_table(cfg.height))
    bottom0, stride = cfg.bottom0, cfg.ub
    steps = int(getattr(cfg, "walk_round_cap", None) or cfg.max_rounds)
    steps *= cfg.height  # ≤ height micro-steps per ΔNode visit
    keys = jnp.asarray(keys, jnp.int32)
    q = cfg.qpack(keys)
    sid = jnp.asarray(sid, jnp.int32)
    active0 = keys != layout.ROUTE_LEFT
    zero = jnp.zeros(keys.shape, jnp.int32)

    def body(s, _):
        dn, b, active, visits, router_t, leaf_t = s
        pos_b = pos[b]
        node = value[sid, dn, pos_b]
        at_bottom = b >= bottom0
        slot = jnp.where(at_bottom, b - bottom0, 0)
        ch = jnp.where(at_bottom, child[sid, dn, slot], jnp.int32(-1))
        hop = at_bottom & (ch >= 0)
        lpos = pos[jnp.minimum(2 * b, 2 * bottom0 - 1)]
        left_val = jnp.where(at_bottom, jnp.zeros((), value.dtype),
                             value[sid, dn, lpos])
        internal = (~at_bottom) & (left_val != layout.EMPTY)
        terminal = active & ~internal & ~hop
        idx1 = jnp.where(active, dn * stride + pos_b, _SENTINEL)
        idx2 = jnp.where(active & internal, dn * stride + lpos, _SENTINEL)
        b_next = jnp.where(internal,
                           2 * b + (q >= node).astype(jnp.int32), b)
        b_next = jnp.where(hop, jnp.int32(1), b_next)
        dn_next = jnp.where(hop, ch, dn)
        s = (dn_next, b_next, active & ~terminal,
             visits + (active & hop).astype(jnp.int32),
             router_t + active.astype(jnp.int32)
             + (active & internal).astype(jnp.int32),
             leaf_t + terminal.astype(jnp.int32))
        return s, (idx1, idx2)

    init = (jnp.asarray(roots, jnp.int32),
            jnp.ones(keys.shape, jnp.int32),  # b=1; pos[0] is the -1 hole
            active0, active0.astype(jnp.int32), zero, zero)
    (_, _, _, visits, router_t, leaf_t), (i1, i2) = jax.lax.scan(
        body, init, None, length=steps)
    idx = jnp.concatenate([i1, i2], axis=0).T  # (K, 2T)
    # every touch is counted once in router_t; the terminal read is the
    # leaf test that resolves the query — split it out of the router count
    return idx, visits, router_t - leaf_t, leaf_t


def _distinct_blocks(sorted_idx, block: int):
    """Per-query distinct ``block``-element blocks among the valid
    (non-SENTINEL) entries of an ascending-sorted (K, T) index array —
    exactly what `count_block_transfers` totals per key."""
    valid = sorted_idx < _SENTINEL
    bid = sorted_idx // jnp.int32(block)
    first = jnp.concatenate(
        [jnp.ones_like(valid[:, :1]), bid[:, 1:] != bid[:, :-1]], axis=1)
    return jnp.sum(valid & first, axis=1, dtype=jnp.int32)


def measure_stacked(cfg, value, child, roots, sid, keys) -> TransferStats:
    """``TransferStats`` for one read batch over stacked (S, M, ...)
    arenas (the forest's owner-shard view; S=1 for a single arena)."""
    idx, visits, router_t, leaf_t = _replay(cfg, value, child, roots, sid,
                                            keys)
    sidx = jnp.sort(idx, axis=1)
    blocks = jnp.stack([_distinct_blocks(sidx, b)
                        for b in TRANSFER_BLOCK_SIZES], axis=1)
    pad = jnp.asarray(keys, jnp.int32) == _SENTINEL  # ROUTE_LEFT == int32max
    return TransferStats.of(pad, visits, router_t, leaf_t, blocks)


def measure(cfg, t, keys) -> TransferStats:
    """``TransferStats`` for one read batch on a single arena ``t``
    (jit-safe; this is what `engine._read_stats` threads through)."""
    keys = jnp.asarray(keys, jnp.int32)
    roots = jnp.broadcast_to(jnp.asarray(t.root, jnp.int32), keys.shape)
    return measure_stacked(cfg, t.value[None], t.child[None], roots,
                           jnp.zeros(keys.shape, jnp.int32), keys)


# ------------------------------------------------------------ validation ---


def compare_model(cfg, t, keys, block_sizes=TRANSFER_BLOCK_SIZES) -> dict:
    """Measured-vs-analytical distinct-block transfers on one tree.

    Returns ``{B: {"measured", "model", "ratio"}}``.  On a quiescent
    (flushed) tree the two sides count the identical index multiset, so
    ``ratio == 1.0`` exactly for every B — the tier-1 / compiled-smoke
    acceptance gate.  Host-side helper: don't call it inside a trace.
    """
    from repro.core import transfers as CT
    from repro.core.baselines import count_block_transfers

    keys = np.asarray(keys)
    ts = measure(cfg, t, keys)
    touch = CT.delta_touch_fn(cfg, t)
    out = {}
    for b in block_sizes:
        i = TRANSFER_BLOCK_SIZES.index(b)
        measured = int(ts.blocks[i]) / max(len(keys), 1)
        model = count_block_transfers(touch, keys, b)
        out[int(b)] = {"measured": measured, "model": model,
                       "ratio": measured / model if model else 0.0}
    return out


def fit_log_b(n_points: int = 11, *, block: int = 16, height: int = 4,
              start: int = 128, factor: int = 2, queries: int = 512,
              seed: int = 0) -> dict:
    """Fit measured mean block transfers against c·log_B N + d across a
    geometric sweep of quiescent tree sizes.

    Builds ``n_points`` bulk trees of N = start·factor^i unique keys,
    measures the mean distinct ``block``-element blocks per search over
    ``queries`` random probes, and least-squares fits the means against
    log_B N.  Returns {"block", "points": [(n, measured)], "c", "d",
    "r2"} — r2 ≥ 0.98 is the tier-1 O(log_B N) acceptance gate.  The
    default sweep doubles N (factor=2): mean ΔNode depth grows in
    plateaus, so coarse geometric steps alias the staircase and tank the
    fit; doubling samples it densely enough that the linear trend
    dominates (r2 ≈ 0.992-0.994 across seeds).
    """
    from repro.core import deltatree as DT
    from repro.core import layout

    i = TRANSFER_BLOCK_SIZES.index(block)
    rng = np.random.default_rng(seed)
    points = []
    for p in range(n_points):
        n = start * factor**p
        keys = np.unique(rng.integers(
            layout.KEY_MIN, layout.KEY_MAX, size=n).astype(np.int32))
        cfg = DT.TreeConfig(
            height=height,
            max_dnodes=max(256, 6 * len(keys) // 2 ** (height - 1)))
        t = DT.bulk_build(cfg, keys)
        probes = rng.integers(layout.KEY_MIN, layout.KEY_MAX,
                              size=queries).astype(np.int32)
        ts = measure(cfg, t, jnp.asarray(probes))
        points.append((len(keys), int(ts.blocks[i]) / queries))
    x = np.log(np.asarray([n for n, _ in points], np.float64)) / np.log(block)
    y = np.asarray([m for _, m in points], np.float64)
    c, d = np.polyfit(x, y, 1)
    pred = c * x + d
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot else 1.0
    return {"block": int(block), "points": points, "c": float(c),
            "d": float(d), "r2": float(r2)}
