"""Trace spans: profiler annotations for every layer, off by default.

Gated by the ``REPRO_TRACE`` env var (unset/0 = every helper is a
zero-cost ``nullcontext`` and traced programs lower byte-identically to
an unannotated build).  With ``REPRO_TRACE=1``:

- ``span(name)`` opens a host-side ``jax.profiler.TraceAnnotation`` *and*
  a device-side ``jax.named_scope`` — use it around host-driven sections
  (engine dispatch, a ServeEngine decode step).  Each completed span
  additionally records a host wall-clock event for the Chrome-trace
  writer below.
- ``annotate(name)`` opens only the ``named_scope`` — use it *inside*
  traced functions (``delta_walk`` rounds, maintenance phases, the router
  dispatch), where a host annotation would stamp trace time, not run time.
  Callers under an outer jit bake the gate at their trace time: flipping
  ``REPRO_TRACE`` does not retrace already-cached programs.
- ``capture(logdir)`` wraps a region in ``jax.profiler.start_trace`` /
  ``stop_trace`` — the xprof/perfetto trace-dump hook the ROADMAP's
  compiled-performance campaign points at a device run (also reachable as
  ``benchmarks/run.py --trace-dir``).
- ``write_chrome_trace(path)`` dumps the recorded span events as a
  Chrome-trace / perfetto JSON timeline (``{"traceEvents": [...]}``) —
  host wall-clock only, so ``--trace-dir`` emits a browsable timeline
  even where ``jax.profiler`` has no device backend to sample.

Counters and the event ring are guarded by one module lock: the serve
layer's maintenance worker is headed for its own thread (ROADMAP), and
dict item updates from two threads would otherwise drop bumps.
"""

from __future__ import annotations

import contextlib
import functools
import json
import os
import threading
import time

import jax

ENV = "REPRO_TRACE"

# Host-side span counters (``REPRO_TRACE`` gated like the spans): every
# entered span/annotate/bump increments its name.  A span opened at trace
# time counts traces, one opened per call counts dispatches — which is the
# point: `bump("delta_walk.dispatch")` in `ops.delta_walk` is the
# kernel-dispatch counter behind the benchmarks' ``walk_launches`` column
# (the per-ROUND launch count is device data — the driver's round counter
# — because while_loop iterations never re-enter the host).
_COUNTS: dict[str, int] = {}
# Completed host-side span events for `write_chrome_trace`, bounded so a
# long benchmark loop can't grow without limit (drops count under the
# reserved name below instead of silently vanishing).
_EVENTS: list[dict] = []
_EVENT_CAP = 200_000
_DROPPED = "trace.events_dropped"
_LOCK = threading.Lock()
_EPOCH = time.perf_counter()


def enabled() -> bool:
    """True when ``REPRO_TRACE`` asks for spans (read at call time)."""
    env = os.environ.get(ENV, "").strip()
    return bool(env) and env.lower() not in ("0", "false", "no")


def bump(name: str, n: int = 1) -> None:
    """Count an event under ``name`` (no-op unless ``REPRO_TRACE``)."""
    if enabled():
        with _LOCK:
            _COUNTS[name] = _COUNTS.get(name, 0) + n


def counters() -> dict[str, int]:
    """Snapshot of the span/event counters accumulated so far."""
    with _LOCK:
        return dict(_COUNTS)


def reset_counters() -> None:
    """Clear the counters — callers that reuse one process for many
    measurement rows (``benchmarks/common.run_index``) reset between
    rows so counts like ``walk_launches`` can't leak across.  The
    chrome-trace event ring is deliberately untouched: a ``--trace-dir``
    run wants the whole run's timeline (``reset_events`` exists for
    callers that do want it cleared)."""
    with _LOCK:
        _COUNTS.clear()


def reset_events() -> None:
    with _LOCK:
        _EVENTS.clear()


def _record_event(name: str, t0: float, t1: float) -> None:
    ev = {"name": name, "ph": "X", "pid": os.getpid(),
          "tid": threading.get_ident(),
          "ts": round((t0 - _EPOCH) * 1e6, 3),
          "dur": round((t1 - t0) * 1e6, 3)}
    with _LOCK:
        if len(_EVENTS) < _EVENT_CAP:
            _EVENTS.append(ev)
        else:
            _COUNTS[_DROPPED] = _COUNTS.get(_DROPPED, 0) + 1


def events() -> list[dict]:
    """Snapshot of the recorded Chrome-trace span events."""
    with _LOCK:
        return [dict(e) for e in _EVENTS]


def write_chrome_trace(path: str) -> int:
    """Write the recorded span events as Chrome-trace JSON (open in
    ``chrome://tracing`` or https://ui.perfetto.dev).  Returns the event
    count written.  Unconditional like ``capture`` — asking for the file
    is the opt-in — but only spans entered under ``REPRO_TRACE=1``
    recorded anything."""
    evs = events()
    with open(path, "w") as f:
        json.dump({"traceEvents": evs, "displayTimeUnit": "ms"}, f)
    return len(evs)


def annotate(name: str):
    """Device-side scope: names the ops traced under it in HLO/xprof.
    Safe anywhere (host or trace time); nullcontext when disabled."""
    if not enabled():
        return contextlib.nullcontext()
    bump(name)
    return jax.named_scope(name)


@contextlib.contextmanager
def _timed_span(name: str):
    with contextlib.ExitStack() as stack:
        stack.enter_context(jax.profiler.TraceAnnotation(name))
        stack.enter_context(jax.named_scope(name))
        t0 = time.perf_counter()
        try:
            yield
        finally:
            _record_event(name, t0, time.perf_counter())


def span(name: str):
    """Host wall-clock span + device scope; nullcontext when disabled."""
    if not enabled():
        return contextlib.nullcontext()
    bump(name)
    return _timed_span(name)


def traced(name: str):
    """Decorator form of ``span`` (host-driven functions)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with span(name):
                return fn(*args, **kwargs)

        return wrapper

    return deco


@contextlib.contextmanager
def capture(logdir: str):
    """Dump an xprof/perfetto trace of the enclosed region to ``logdir``
    (view with xprof / tensorboard-profile / perfetto).  Unconditional —
    asking for a trace dump *is* the opt-in, no ``REPRO_TRACE`` needed."""
    jax.profiler.start_trace(logdir)
    try:
        yield logdir
    finally:
        jax.profiler.stop_trace()


def trace_run(fn, *args, logdir: str, **kwargs):
    """Run ``fn(*args, **kwargs)`` under ``capture`` and block until its
    results land, so the dump covers the real device work — the one-call
    helper for profiling a jitted read/update on hardware."""
    with capture(logdir):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    return out
