"""Trace spans: profiler annotations for every layer, off by default.

Gated by the ``REPRO_TRACE`` env var (unset/0 = every helper is a
zero-cost ``nullcontext`` and traced programs lower byte-identically to
an unannotated build).  With ``REPRO_TRACE=1``:

- ``span(name)`` opens a host-side ``jax.profiler.TraceAnnotation`` *and*
  a device-side ``jax.named_scope`` — use it around host-driven sections
  (engine dispatch, a ServeEngine decode step).
- ``annotate(name)`` opens only the ``named_scope`` — use it *inside*
  traced functions (``delta_walk`` rounds, maintenance phases, the router
  dispatch), where a host annotation would stamp trace time, not run time.
  Callers under an outer jit bake the gate at their trace time: flipping
  ``REPRO_TRACE`` does not retrace already-cached programs.
- ``capture(logdir)`` wraps a region in ``jax.profiler.start_trace`` /
  ``stop_trace`` — the xprof/perfetto trace-dump hook the ROADMAP's
  compiled-performance campaign points at a device run (also reachable as
  ``benchmarks/run.py --trace-dir``).
"""

from __future__ import annotations

import contextlib
import functools
import os

import jax

ENV = "REPRO_TRACE"

# Host-side span counters (``REPRO_TRACE`` gated like the spans): every
# entered span/annotate/bump increments its name.  A span opened at trace
# time counts traces, one opened per call counts dispatches — which is the
# point: `bump("delta_walk.dispatch")` in `ops.delta_walk` is the
# kernel-dispatch counter behind the benchmarks' ``walk_launches`` column
# (the per-ROUND launch count is device data — the driver's round counter
# — because while_loop iterations never re-enter the host).
_COUNTS: dict[str, int] = {}


def enabled() -> bool:
    """True when ``REPRO_TRACE`` asks for spans (read at call time)."""
    env = os.environ.get(ENV, "").strip()
    return bool(env) and env.lower() not in ("0", "false", "no")


def bump(name: str, n: int = 1) -> None:
    """Count an event under ``name`` (no-op unless ``REPRO_TRACE``)."""
    if enabled():
        _COUNTS[name] = _COUNTS.get(name, 0) + n


def counters() -> dict[str, int]:
    """Snapshot of the span/event counters accumulated so far."""
    return dict(_COUNTS)


def reset_counters() -> None:
    _COUNTS.clear()


def annotate(name: str):
    """Device-side scope: names the ops traced under it in HLO/xprof.
    Safe anywhere (host or trace time); nullcontext when disabled."""
    if not enabled():
        return contextlib.nullcontext()
    bump(name)
    return jax.named_scope(name)


def span(name: str):
    """Host wall-clock span + device scope; nullcontext when disabled."""
    if not enabled():
        return contextlib.nullcontext()
    bump(name)
    stack = contextlib.ExitStack()
    stack.enter_context(jax.profiler.TraceAnnotation(name))
    stack.enter_context(jax.named_scope(name))
    return stack


def traced(name: str):
    """Decorator form of ``span`` (host-driven functions)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with span(name):
                return fn(*args, **kwargs)

        return wrapper

    return deco


@contextlib.contextmanager
def capture(logdir: str):
    """Dump an xprof/perfetto trace of the enclosed region to ``logdir``
    (view with xprof / tensorboard-profile / perfetto).  Unconditional —
    asking for a trace dump *is* the opt-in, no ``REPRO_TRACE`` needed."""
    jax.profiler.start_trace(logdir)
    try:
        yield logdir
    finally:
        jax.profiler.stop_trace()


def trace_run(fn, *args, logdir: str, **kwargs):
    """Run ``fn(*args, **kwargs)`` under ``capture`` and block until its
    results land, so the dump covers the real device work — the one-call
    helper for profiling a jitted read/update on hardware."""
    with capture(logdir):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    return out
