from repro.data.pipeline import DataConfig, Pipeline, batch_at_step

__all__ = ["DataConfig", "Pipeline", "batch_at_step"]
