"""Deterministic-by-step sharded data pipeline.

Design for fault tolerance / straggler mitigation (DESIGN.md §12):
- `batch_at_step(cfg, step)` is a pure function of (seed, step) — any host
  can (re)materialize any step's global batch, so there is no shuffle state
  to checkpoint beyond the step counter, restarts are bit-exact, and a
  backup host can take over a straggler's shard by recomputing it (no
  producer handoff protocol needed).
- Each host slices its `[host_index * per_host, ...)` rows; under jit the
  global batch is assembled via `jax.make_array_from_process_local_data`
  (single-process here: a plain device_put with the batch sharding).
- `Pipeline` adds double-buffered background prefetch (thread) so step N+1's
  batch is built while step N runs — the straggler-mitigation hook is the
  `prefetch_depth`.

The synthetic stream mimics packed-document LM data: documents of
power-law length packed into fixed windows with EOS=0 boundaries; labels
are next-token with -100 on padding (masked by the loss).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    family: str = "dense"          # vlm/audio add stub modality inputs
    d_model: int = 0
    vision_tokens: int = 0
    encoder_seq: int = 0


def _pack_row(rng: np.random.Generator, cfg: DataConfig) -> np.ndarray:
    """One packed row of documents (EOS=0 separators)."""
    row = np.empty(cfg.seq_len + 1, np.int32)
    pos = 0
    while pos < cfg.seq_len + 1:
        n = int(rng.pareto(2.0) * cfg.mean_doc_len) + 8
        n = min(n, cfg.seq_len + 1 - pos)
        row[pos : pos + n] = rng.integers(1, cfg.vocab_size, size=n)
        pos += n
        if pos < cfg.seq_len + 1:
            row[pos] = 0
            pos += 1
    return row


def batch_at_step(cfg: DataConfig, step: int) -> dict:
    """Pure (seed, step) -> global batch. Recomputable by any host."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, 0xDE17A]))
    rows = np.stack([_pack_row(rng, cfg) for _ in range(cfg.global_batch)])
    batch = {"tokens": rows[:, :-1], "labels": rows[:, 1:].copy()}
    if cfg.family == "vlm":
        batch["vision_embeds"] = rng.standard_normal(
            (cfg.global_batch, cfg.vision_tokens, cfg.d_model)
        ).astype(np.float32)
    if cfg.family == "audio":
        batch["frames"] = rng.standard_normal(
            (cfg.global_batch, cfg.encoder_seq, cfg.d_model)
        ).astype(np.float32)
    return batch


class Pipeline:
    """Double-buffered prefetching iterator over `batch_at_step`."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch_depth: int = 2):
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch_depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put((s, batch_at_step(self.cfg, s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __next__(self):
        s, b = self._q.get()
        self.step = s + 1
        return s, b

    def close(self):
        self._stop.set()
