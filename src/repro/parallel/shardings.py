"""Parameter / cache / batch PartitionSpecs for the production mesh.

Scheme (DESIGN.md §7): TP on "model" (heads / FFN hidden / experts / vocab),
FSDP on "data" for every large matrix (params replicated across "pod";
cross-pod traffic is gradient-only), batch on ("pod","data").  Stacked
scan params carry a leading (reps,) axis that is never sharded.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# name -> spec over the *trailing* dims (leading stack axes padded with None)
_TRAILING_RULES: dict[str, tuple] = {
    # embedding
    "tok": ("model", "data"),        # (V, D)
    "head": ("data", "model"),       # (D, V)
    # attention
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    # MLA
    "wq_a": ("data", "model"),
    "wq_b": ("data", "model"),
    "wkv_a": ("data", None),
    "wkv_b": ("data", "model"),
    # MLP (rank 2) / MoE experts (rank 3) — dispatched on rank below
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    "w_in": ("data", "model"),
    "b_in": ("model",),
    "w_out": ("model", "data"),
    "b_out": (None,),
    "router": (None, None),
    # mamba2
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "a_log": (None,),
    "d_skip": (None,),
    "dt_bias": (None,),
    "gate_norm": (None,),
    # norms
    "scale": (None,),
    "bias": (None,),
}

_MOE_RULES = {  # rank-3 expert tensors: EP on "model", FSDP inside expert
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def _leaf_spec(name: str, leaf, in_moe: bool) -> P:
    base = None
    if in_moe and name in _MOE_RULES:
        base = _MOE_RULES[name]
    elif name in _TRAILING_RULES:
        base = _TRAILING_RULES[name]
    if base is None:
        return P()
    pad = leaf.ndim - len(base)
    assert pad >= 0, (name, leaf.ndim, base)
    return P(*((None,) * pad + tuple(base)))


def param_specs(params_shape) -> object:
    """PartitionSpec pytree matching a params (shape) pytree.

    MoE expert tensors are recognized by a sibling "router" entry (robust
    to scan-stacking changing ranks)."""

    def walk(node, in_moe=False):
        if isinstance(node, dict):
            moe_here = "router" in node
            return {
                k: (walk(v, moe_here) if isinstance(v, (dict, list, tuple))
                    else _leaf_spec(k, v, moe_here))
                for k, v in node.items()
            }
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, in_moe) for v in node)
        return P()

    return walk(params_shape)


def opt_specs(pspecs):
    """AdamW state specs: moments shard like params; step replicated."""
    return {"m": pspecs, "v": pspecs, "step": P()}


def _cache_leaf_spec(path: tuple, leaf) -> P:
    names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    last = names[-1]
    nd = leaf.ndim
    trailing = {
        # (B, S, KVH, HD): shard cache length on "model" (split-K decode)
        "k": (("pod", "data"), "model", None, None),
        "v": (("pod", "data"), "model", None, None),
        "ck": (("pod", "data"), "model", None, None),
        "cv": (("pod", "data"), "model", None, None),
        # MLA latent caches (B, S, r)
        "ckv": (("pod", "data"), "model", None),
        "krope": (("pod", "data"), "model", None),
        # SSD state (B, H, P, N) / conv cache (B, w-1, CD)
        "state": (("pod", "data"), "model", None, None),
        "conv": (("pod", "data"), None, "model"),
    }[last]
    pad = nd - len(trailing)
    assert pad >= 0, (names, nd)
    return P(*((None,) * pad + tuple(trailing)))


def cache_specs(cache_shape, mesh) -> object:
    """Decode-cache specs; drops mesh axes whose size doesn't divide dims."""
    def fix(path, leaf):
        spec = _cache_leaf_spec(path, leaf)
        parts = []
        for dim, ax in zip(leaf.shape, spec):
            axes = (ax,) if isinstance(ax, str) else (ax or ())
            size = 1
            for a in axes:
                size *= mesh.shape[a] if a in mesh.axis_names else 1
            keep = tuple(a for a in axes if a in mesh.axis_names)
            parts.append(keep if dim % max(size, 1) == 0 and keep else None)
        parts = [p[0] if isinstance(p, tuple) and len(p) == 1 else p for p in parts]
        return P(*parts)

    return jax.tree_util.tree_map_with_path(fix, cache_shape)


def batch_axes(mesh, batch_size: int):
    """Largest prefix of ("pod","data") whose product divides batch_size."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen, prod = [], 1
    for a in axes:
        if batch_size % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    return tuple(chosen)


def batch_spec(mesh, batch_size: int, ndim: int) -> P:
    ax = batch_axes(mesh, batch_size)
    first = ax if len(ax) > 1 else (ax[0] if ax else None)
    return P(*((first,) + (None,) * (ndim - 1)))


def to_named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
