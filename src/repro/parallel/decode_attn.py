"""Split-K sharded decode attention (flash-decoding style) via shard_map.

The decode KV cache is sharded along *sequence* on the "model" axis
(parallel/shardings.py).  Instead of letting the SPMD partitioner all-gather
the cache for the softmax, each shard computes a partial (max, sum, out)
over its local KV slice and the shards combine with two tiny psums — wire
traffic O(B·H·D) instead of O(B·S·KVH·D).  Used as a §Perf optimization for
the decode cells and unit-tested against `decode_attention` on host devices.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _local_partial(q, k, v, length, s0):
    """Partial attention over a local KV slice starting at position s0."""
    b, _, h, d = q.shape
    s_loc, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qf = q.reshape(b, kvh, g, d).astype(jnp.float32)
    sc = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32)) / np.sqrt(d)
    pos = s0 + jnp.arange(s_loc)
    sc = jnp.where((pos[None, :] < length[:, None])[:, None, None], sc, NEG_INF)
    m = jnp.max(sc, axis=-1)                     # (B,KVH,G)
    p = jnp.exp(sc - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return m, l, o


def split_k_decode_attention(mesh, q, k_cache, v_cache, length,
                             axis: str = "model"):
    """q: (B,1,H,D) replicated over `axis`; caches: (B,S,KVH,D) sharded on S
    over `axis`; length: (B,). Returns (B,1,H,D)."""
    n = mesh.shape[axis]
    s = k_cache.shape[1]
    s_loc = s // n

    def local(q, k, v, length):
        i = jax.lax.axis_index(axis)
        m, l, o = _local_partial(q, k, v, length, i * s_loc)
        # rescaled combine: M = global max; sum l', o' with alpha factors
        mm = jax.lax.pmax(m, axis)
        alpha = jnp.exp(m - mm)
        ll = jax.lax.psum(l * alpha, axis)
        oo = jax.lax.psum(o * alpha[..., None], axis)
        out = oo / jnp.maximum(ll, 1e-30)[..., None]
        b, kvh, g, d = out.shape
        return out.reshape(b, 1, kvh * g, d).astype(q.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None), P()),
        out_specs=P(),
        check_rep=False,
    )(q, k_cache, v_cache, length)
