"""Distribution layer: logical-axis sharding rules, param shardings,
sharded decode attention (split-K), collective helpers.

``__all__`` is the single source of truth for this package's surface
(tests/test_exports.py asserts every name imports) — it re-exports the
actual API of ``ax`` / ``shardings`` / ``decode_attn`` instead of the
mesh helpers alone.  The DeltaForest (repro/distributed) rides this layer
too: its 1-D "shards" mesh is re-exported here so mesh plumbing has one
import home.
"""

from repro.launch.mesh import make_forest_mesh, make_host_mesh
from repro.parallel.ax import DEFAULT_RULES, constrain, logical_rules, spec_for
from repro.parallel.decode_attn import split_k_decode_attention
from repro.parallel.shardings import (
    batch_axes,
    batch_spec,
    cache_specs,
    opt_specs,
    param_specs,
    to_named,
)

__all__ = [
    "DEFAULT_RULES",
    "batch_axes",
    "batch_spec",
    "cache_specs",
    "constrain",
    "logical_rules",
    "make_forest_mesh",
    "make_host_mesh",
    "opt_specs",
    "param_specs",
    "spec_for",
    "split_k_decode_attention",
    "to_named",
]
