"""Distribution layer: logical-axis sharding rules, param shardings,
sharded decode attention (split-K), collective helpers."""
