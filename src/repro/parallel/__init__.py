"""Distribution layer: logical-axis sharding rules, param shardings,
sharded decode attention (split-K), collective helpers.

The DeltaForest (repro/distributed) rides this layer too: its 1-D
"shards" mesh is re-exported here so mesh plumbing has one import home.
"""

from repro.launch.mesh import make_forest_mesh, make_host_mesh

__all__ = ["make_forest_mesh", "make_host_mesh"]
