"""Logical activation-axis sharding (MaxText-style logical rules).

Models annotate activations with *logical* axis names; a thread-local rule
set (installed by the launcher / dry-run under a mesh) maps them to mesh
axes.  Outside a rules context every annotation is a no-op, so model code
runs unchanged on a single CPU device.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()

# default logical-name -> mesh-axes mapping used by the production mesh
DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    "batch": ("pod", "data"),     # pod axis collapses onto data when absent
    "seq": None,
    "decode_seq": "model",        # sharded KV cache length (split-K decode)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_cap": ("pod", "data"),
    "ssm_inner": "model",
    "state": None,
}


@contextlib.contextmanager
def logical_rules(mesh, rules: dict | None = None):
    """Activate logical-axis constraint rules for `constrain` calls."""
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES if rules is None else rules)
    try:
        yield
    finally:
        _state.mesh = None
        _state.rules = None


def spec_for(*names: str | None) -> P:
    """Translate logical names to a PartitionSpec under the active rules."""
    rules = getattr(_state, "rules", None)
    mesh = getattr(_state, "mesh", None)
    parts = []
    for n in names:
        axes = rules.get(n) if (rules and n) else None
        if axes is None:
            parts.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        present = tuple(a for a in axes if mesh is not None and a in mesh.axis_names)
        parts.append(present if len(present) > 1 else (present[0] if present else None))
    return P(*parts)


def constrain(x, *names: str | None):
    """with_sharding_constraint using logical names; no-op without rules."""
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(*names))
    )
