"""Sharded checkpointing with atomic commit, async save, and resharding
restore (the elastic-scaling path; DESIGN.md §12).

Format: one .npy per pytree leaf (path-encoded filename) + manifest.json
(step, tree structure, shapes/dtypes, mesh shape, data cursor).  Commit is
write-to-tmp → fsync → atomic rename, so a crash mid-save never corrupts
the latest checkpoint.  `restore` rebuilds global arrays and `device_put`s
them with the *target* mesh's shardings — restoring a 4-way checkpoint onto
a 2-way (or 512-way) mesh is the same code path (lose a pod → restart on
the single-pod mesh from the same files).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]"))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(skeleton, flat):
    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}.{k}" if prefix else str(k))
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(
                walk(v, f"{prefix}[{i}]") for i, v in enumerate(node))
        return flat[prefix]
    return walk(skeleton)


def latest_step(ckpt_dir) -> int | None:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*") if p.is_dir()]
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, ckpt_dir, keep_last: int = 3, async_save: bool = True):
        self.dir = Path(ckpt_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- saving ---

    def save(self, step: int, tree, extra: dict | None = None):
        """Snapshot to host (blocking) then write (async by default)."""
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict):
        tmp = self.dir / f".tmp_step_{step}_{os.getpid()}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "leaves": {}}
        for k, v in flat.items():
            fn = k.replace("/", "_") + ".npy"
            np.save(tmp / fn, v)
            manifest["leaves"][k] = {
                "file": fn, "shape": list(v.shape), "dtype": str(v.dtype)}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._gc()

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*") if p.is_dir())
        for p in steps[: -self.keep_last]:
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------ restore ---

    def restore(self, step: int | None, skeleton, shardings=None):
        """Load into the skeleton pytree; device_put with target shardings
        (resharding restore). Returns (step, tree, extra)."""
        if step is None:
            step = latest_step(self.dir)
            assert step is not None, f"no checkpoints under {self.dir}"
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for k, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            flat[k] = arr
        tree = _unflatten_into(skeleton, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return manifest["step"], tree, manifest.get("extra", {})
