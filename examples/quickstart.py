"""Quickstart: the ΔTree public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    OP_DELETE, OP_INSERT, TreeConfig, bulk_build, empty, search_jit,
    update_batch,
)
from repro.core.transfers import delta_hops_fn


def main():
    # a ΔTree with page-sized ΔNodes (UB = 127, the paper's sweet spot)
    cfg = TreeConfig(height=7, max_dnodes=1 << 16, buf_cap=32)

    # bulk-load a million keys (half-dense ΔNodes, vEB layout inside each)
    rng = np.random.default_rng(0)
    keys = np.unique(rng.integers(1, 5_000_000, size=1_000_000).astype(np.int32))
    tree = bulk_build(cfg, keys)
    print(f"built ΔTree: {keys.size:,} keys, "
          f"{int(np.asarray(tree.alive).sum()):,} ΔNodes")

    # wait-free batched search (one SPMD step = one linearization point)
    queries = rng.integers(1, 5_000_000, size=4096).astype(np.int32)
    found, hops = search_jit(cfg, tree, jnp.asarray(queries))
    print(f"search: {int(np.asarray(found).sum())}/{queries.size} hits, "
          f"mean ΔNode hops {float(np.asarray(hops).mean()):.2f} "
          f"(= O(log_B N) memory transfers)")

    # concurrent-batch updates: inserts + deletes in one step
    kinds = np.asarray([OP_INSERT] * 4 + [OP_DELETE] * 4, np.int32)
    vals = np.asarray([7, 9, 11, 13, int(keys[0]), int(keys[1]), 7, 999_999_937],
                      np.int32)
    tree, results, rounds = update_batch(
        cfg, tree, jnp.asarray(kinds), jnp.asarray(vals))
    print("updates:", dict(zip(vals.tolist(), np.asarray(results).tolist())),
          f"(maintenance rounds: {int(rounds)})")

    # exact ideal-cache transfer accounting (the paper's Table 1 metric)
    hopf = delta_hops_fn(cfg, tree)
    sample = [hopf(int(k)) for k in queries[:100]]
    print(f"transfer model: {np.mean(sample):.2f} ΔNode transfers/search "
          f"for N={keys.size:,}, UB=127")


if __name__ == "__main__":
    main()
