"""Quickstart: the handle-based Index API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

``make_index`` is the one entry point: the backend string picks the
structure (``deltatree`` here; ``forest`` / ``sorted_array`` / ... are
drop-ins), the handle carries the state, and every op is a jitted batched
step.
"""

import numpy as np
import jax.numpy as jnp

from repro.api import OpBatch, make_index
from repro.core.transfers import delta_hops_fn


def main():
    # a ΔTree with page-sized ΔNodes (UB = 127, the paper's sweet spot)
    rng = np.random.default_rng(0)
    keys = np.unique(rng.integers(1, 5_000_000, size=1_000_000).astype(np.int32))
    ix = make_index("deltatree", initial=keys,
                    height=7, max_dnodes=1 << 16, buf_cap=32)
    print(f"built {ix!r}: {ix.size():,} keys, "
          f"{int(np.asarray(ix.state.alive).sum()):,} ΔNodes")

    # wait-free batched search (one SPMD step = one linearization point)
    queries = rng.integers(1, 5_000_000, size=4096).astype(np.int32)
    found, hops = ix.search(jnp.asarray(queries))
    print(f"search: {int(np.asarray(found).sum())}/{queries.size} hits, "
          f"mean ΔNode hops {float(np.asarray(hops).mean()):.2f} "
          f"(= O(log_B N) memory transfers)")

    # concurrent-batch updates: inserts + deletes in one OpBatch step
    batch = OpBatch.mixed(
        kinds=[1, 1, 1, 1, 2, 2, 2, 2],
        keys=[7, 9, 11, 13, int(keys[0]), int(keys[1]), 7, 999_999_937],
    )
    ix, results = ix.insert_delete(batch)
    print("updates:", dict(zip(np.asarray(batch.keys).tolist(),
                               np.asarray(results).tolist())))

    # ordered queries ride the same handle (capability-gated)
    sf, succ = ix.successor(jnp.asarray([7, 8], jnp.int32))
    print(f"successor(7) -> {int(succ[0])}, successor(8) -> {int(succ[1])}")

    # the lockstep engine: same reads through the Pallas vEB walk kernel
    # (one contiguous ΔNode-row DMA per query per round) — bit-identical
    # results and hop counts, selected per handle
    ixl = make_index("deltatree", initial=keys, height=7,
                     max_dnodes=1 << 16, buf_cap=32, engine="lockstep")
    lfound, lhops = ixl.search(jnp.asarray(queries[:256]))
    assert (np.asarray(lfound) == np.asarray(found)[:256]).all()
    assert (np.asarray(lhops) == np.asarray(hops)[:256]).all()
    print(f"lockstep engine: identical results, "
          f"{float(np.asarray(lhops).mean()):.2f} rounds (= transfers)/search")

    # exact ideal-cache transfer accounting (the paper's Table 1 metric)
    hopf = delta_hops_fn(ix.cfg, ix.state)
    sample = [hopf(int(k)) for k in queries[:100]]
    print(f"transfer model: {np.mean(sample):.2f} ΔNode transfers/search "
          f"for N={keys.size:,}, UB=127")

    # maintenance policies: budget (or defer) the structural work — the
    # update returns a MaintenanceStats pytree; flush() drains to fixpoint
    ixb = make_index("deltatree", initial=keys[:10_000], height=7,
                     max_dnodes=4096, buf_cap=32, maintenance="budgeted:4")
    ixb, ok, stats = ixb.update(OpBatch.inserts(
        rng.integers(1, 5_000_000, size=256).astype(np.int32)))
    print(f"budgeted:4 update -> {stats.asdict()}")
    ixb, stats = ixb.flush()
    print(f"flush -> {stats.asdict()} (I5 restored)")


if __name__ == "__main__":
    main()
