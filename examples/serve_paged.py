"""Serving example: continuous batching over the ΔTree-paged KV cache.

    JAX_ENABLE_X64=1 PYTHONPATH=src python examples/serve_paged.py

Shows: request submission, page allocation (ΔTree inserts), batched decode
via the Pallas paged-attention kernel with block tables resolved by
wait-free ΔTree searches, and page reclamation on finish (ΔTree deletes +
Merge compaction).
"""

import jax

jax.config.update("jax_enable_x64", True)  # packed int64 ΔTree map mode

import numpy as np  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, "src")

from repro.configs import get_smoke_config  # noqa: E402
from repro.models.registry import api  # noqa: E402
from repro.serving import PagerConfig, ServeEngine  # noqa: E402


def main():
    cfg = get_smoke_config("granite_8b")
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    pc = PagerConfig(num_pages=128, page_size=8, max_seqs=32, max_blocks=64,
                     tree_height=5)
    eng = ServeEngine(cfg, params, pc, max_batch=8)

    rng = np.random.default_rng(0)
    print("submitting 5 requests (prompt lens 6..34)...")
    for n in (6, 14, 22, 9, 34):
        sid = eng.submit(rng.integers(1, cfg.vocab_size, n).astype(np.int32),
                         max_new=8)
        print(f"  seq {sid}: {n} prompt tokens -> "
              f"{eng.pager.seq_blocks[sid]} pages")

    for step in range(9):
        out = eng.step()
        if out:
            print(f"step {step}: decoded {out}")

    s = eng.pager.stats
    print(f"\nΔTree pager hot-path stats: {s['searches']} searches "
          f"({s['hops']/max(s['searches'],1):.2f} ΔNode hops each), "
          f"{s['inserts']} page inserts, {s['deletes']} page frees")
    print(f"pages free after completion: {len(eng.pager.free_pages)}"
          f"/{pc.num_pages}")


if __name__ == "__main__":
    main()
