"""End-to-end driver (assignment deliverable b): train a ~100M-param LM for
a few hundred steps on the synthetic packed-document pipeline, with
checkpointing and restart support.

    PYTHONPATH=src python examples/train_100m.py --steps 300

On this CPU container a step takes O(seconds); pass --steps 10 for a smoke
run (the default here keeps CI fast — the full 300-step run is the same
command with a bigger number).
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as TR  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402

# ~100M params: 12 layers, d_model 768, llama-style dense
CONFIG_100M = ModelConfig(
    name="lm-100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32768, tie_embeddings=True,
    dtype="float32", param_dtype="float32", remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # register the config under a temp name so launch.train can find it
    import types
    mod = types.ModuleType("repro.configs.lm_100m")
    mod.CONFIG = CONFIG_100M
    mod.SMOKE = CONFIG_100M
    sys.modules["repro.configs.lm_100m"] = mod

    argv = ["--arch", "lm_100m", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
            "--log-every", "10"]
    if args.resume:
        argv.append("--resume")
    TR.main(argv)


if __name__ == "__main__":
    main()
