"""ΔTree map mode as a key→value store (beyond-paper extension).

    JAX_ENABLE_X64=1 PYTHONPATH=src python examples/deltatree_kvstore.py

Payloads ride in the low bits of the packed int64 node values, so ordering
(and therefore the whole vEB routing machinery) is untouched — see
core/deltatree.py MAP MODE.  The store is an ordinary ``repro.api`` Index
with ``payload_bits > 0``; swap the backend string for ``"forest"`` to
shard it.
"""

import jax

jax.config.update("jax_enable_x64", True)

import sys  # noqa: E402

sys.path.insert(0, "src")

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.api import OpBatch, make_index  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    keys = np.unique(rng.integers(1, 10_000_000, size=50_000).astype(np.int64))
    vals = rng.integers(0, 1 << 20, size=keys.size)
    ix = make_index("deltatree", initial=keys, payloads=vals,
                    height=7, max_dnodes=1 << 12, buf_cap=32,
                    payload_bits=20)
    assert ix.capability.map_mode
    print(f"kv store: {ix.size():,} entries")

    q = keys[rng.integers(0, keys.size, size=8)]
    found, payload, hops = ix.lookup(jnp.asarray(q, jnp.int32))
    for k, f, p in zip(q, np.asarray(found), np.asarray(payload)):
        expect = vals[np.searchsorted(keys, k)]
        print(f"  get({int(k)}) -> {int(p)} (expect {int(expect)})")
        assert f and p == expect

    # upsert-style: delete + insert with a new payload, in one batch
    k0 = int(q[0])
    ix, res = ix.insert_delete(OpBatch.mixed(
        kinds=[2, 1], keys=[k0, k0], payloads=[0, 123456]))
    found, payload, _ = ix.lookup(jnp.asarray([k0], jnp.int32))
    print(f"  after update: get({k0}) -> {int(payload[0])}")
    assert int(payload[0]) == 123456


if __name__ == "__main__":
    main()
