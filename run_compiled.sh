#!/usr/bin/env bash
# Compiled-mode launch harness: run any repo Python entry point with the
# kernels in compiled mode (REPRO_PALLAS_INTERPRET=0) and the process
# environment tuned for steady benchmark numbers.
#
#   ./run_compiled.sh benchmarks/run.py --compiled --only engines
#   ./run_compiled.sh benchmarks/autotune_qtile.py --heights 5,7,9
#   REPRO_DEVICES=8 ./run_compiled.sh benchmarks/run.py --smoke --compiled
#
# What it pins, and why (see DESIGN.md "Compiled performance"):
#   * REPRO_PALLAS_INTERPRET=0 — Pallas lowers for real on TPU; on CPU the
#     walk routes through the XLA-compiled fused mirror instead of the
#     Pallas interpreter (no interpreter tax either way).
#   * tcmalloc LD_PRELOAD when present — XLA's host allocator churn is a
#     real fraction of small-batch walk time; tcmalloc flattens it.
#   * TF_CPP_MIN_LOG_LEVEL=4 — keeps XLA/TSL chatter off the timed stdout
#     (benchmark rows are parsed off stdout line by line).
#   * XLA_FLAGS --xla_force_host_platform_device_count=$REPRO_DEVICES —
#     opt-in fake-device mesh for sharded (forest) runs on one host.
#   * JAX_ENABLE_X64 passes through untouched: benchmarks/run.py spawns
#     its own x64 subprocesses for the suites that need it.
set -euo pipefail

cd "$(dirname "$0")"

if [[ $# -eq 0 ]]; then
    echo "usage: $0 <script.py> [args...]   (e.g. benchmarks/run.py --compiled)" >&2
    exit 2
fi

export REPRO_PALLAS_INTERPRET=0
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"
export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

# Fake host devices for sharded runs: only when asked — a forced device
# count changes single-arena numbers too (XLA partitions its thread pool).
if [[ -n "${REPRO_DEVICES:-}" ]]; then
    export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=${REPRO_DEVICES}"
fi

# tcmalloc, when the container has it (no install here — probe only).
if [[ -z "${LD_PRELOAD:-}" ]]; then
    for so in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
              /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
              /usr/lib/libtcmalloc_minimal.so.4; do
        if [[ -e "$so" ]]; then
            export LD_PRELOAD="$so"
            break
        fi
    done
fi

exec python "$@"
