"""Per-arch smoke tests (assignment: reduced config, one forward/train step
on CPU, output shapes + no NaNs) and layer-level oracles."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.config import ModelConfig
from repro.models.registry import api, input_specs, shape_applicable
from repro.models.layers import mamba2 as m2
from repro.models.layers.attention import (
    attention_naive, flash_attention, init_attention, qkv_proj,
)
from repro.models.layers.mla import init_mla, mla_decode, mla_prefill, mla_train
from repro.models.layers.moe import init_moe, moe_apply, moe_ref


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 32
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    loss, grads = jax.jit(jax.value_and_grad(m.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen1_5_110b", "jamba_1_5_large_398b",
                                  "mamba2_370m", "deepseek_v2_236b",
                                  "whisper_base", "internvl2_2b",
                                  "phi3_5_moe_42b"])
def test_decode_matches_train_forward(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=64.0)
    m = api(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, S, Spre = 2, 24, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    nv = cfg.vision_tokens if cfg.family == "vlm" else 0
    caches = m.init_caches(B, S + nv)
    extra = {}
    if cfg.family == "vlm":
        extra["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, nv, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
        full = m.forward_train(params, tokens=toks, frames=frames)
        logits, caches = m.prefill(params, toks[:, :Spre], frames, caches)
    else:
        full = m.forward_train(params, tokens=toks, **extra)
        logits, caches = m.prefill(params, toks[:, :Spre], caches, **extra)
    full = full[:, nv:]
    errs = [float(jnp.abs(full[:, Spre - 1:Spre] - logits).max())]
    for i in range(Spre, S):
        ln = jnp.full((B,), nv + i, jnp.int32)
        logits, caches = m.decode_step(params, toks[:, i:i + 1], caches, ln)
        errs.append(float(jnp.abs(full[:, i:i + 1] - logits).max()))
    assert max(errs) < 2e-2, (arch, errs)


def test_full_config_param_counts():
    """The assigned configs hit their published total-parameter scale."""
    expect = {
        "jamba_1_5_large_398b": (380e9, 420e9),
        "qwen1_5_110b": (100e9, 120e9),
        "deepseek_v2_236b": (220e9, 250e9),
        "phi3_5_moe_42b": (39e9, 45e9),
        "mamba2_370m": (0.3e9, 0.5e9),
        "granite_8b": (7e9, 9e9),
        "mistral_nemo_12b": (11e9, 14e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        m = api(cfg)
        shapes = jax.eval_shape(m.init_params, jax.random.key(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert lo <= n <= hi, (arch, n)


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                assert shape == "long_500k" and cfg.family not in (
                    "ssm", "hybrid")
                continue
            kind, specs = input_specs(cfg, shape)
            assert kind in ("train", "prefill", "decode")
            assert all(
                hasattr(leaf, "shape") for leaf in jax.tree.leaves(specs))


# ---------------------------------------------------------- layer oracles ---

_cfg = dict(num_layers=2, d_ff=128, vocab_size=256,
            dtype="float32", param_dtype="float32")


def test_ssd_chunked_vs_ref():
    cfg = ModelConfig(name="t", family="ssm", d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, ssm_state=16,
                      ssm_head_dim=8, ssm_chunk=8, **_cfg)
    p = m2.init_mamba2(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, 64), jnp.float32)
    z, xin, b_, c_, dt, _ = m2._pre_ssd(p, cfg, x)
    y_c, _ = m2.ssd_chunked(cfg, xin, b_, c_, dt, p["a_log"], p["d_skip"])
    y_r = m2.ssd_ref(cfg, xin, b_, c_, dt, p["a_log"], p["d_skip"])
    assert float(jnp.abs(y_c - y_r).max()) < 1e-4
    # vectorized (dry-run probe) path agrees too
    cfg_v = dataclasses.replace(cfg, ssd_vectorized=True)
    y_v, _ = m2.ssd_chunked(cfg_v, xin, b_, c_, dt, p["a_log"], p["d_skip"])
    assert float(jnp.abs(y_v - y_r).max()) < 1e-4


def test_moe_dispatch_vs_dense_ref():
    cfg = ModelConfig(name="t", family="moe", d_model=32, num_heads=4,
                      num_kv_heads=4, head_dim=8, moe_experts=8, moe_top_k=2,
                      moe_shared=1, moe_d_ff=48, capacity_factor=8.0, **_cfg)
    p = init_moe(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 32), jnp.float32)
    err = float(jnp.abs(moe_apply(p, cfg, x) - moe_ref(p, cfg, x)).max())
    assert err < 1e-4


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.0, outputs differ from dense ref only on
    dropped tokens, and never NaN."""
    cfg = ModelConfig(name="t", family="moe", d_model=32, num_heads=4,
                      num_kv_heads=4, head_dim=8, moe_experts=4, moe_top_k=2,
                      moe_shared=0, moe_d_ff=48, capacity_factor=1.0, **_cfg)
    p = init_moe(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32), jnp.float32)
    out = moe_apply(p, cfg, x)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("block_skip", [True, False])
def test_flash_vs_naive(block_skip):
    cfg = ModelConfig(name="t", family="dense", d_model=64, num_heads=8,
                      num_kv_heads=2, head_dim=16, attn_chunk=16,
                      qkv_bias=True, **_cfg)
    p = init_attention(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    q, k, v = qkv_proj(p, cfg, x, pos)
    on = attention_naive(q, k, v, True)
    of = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                         block_skip=block_skip)
    assert float(jnp.abs(on - of).max()) < 1e-4


def test_mla_decode_matches_train():
    cfg = ModelConfig(name="t", family="dense", d_model=64, num_heads=4,
                      num_kv_heads=4, head_dim=16, mla=True, q_lora_rank=32,
                      kv_lora_rank=24, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16, **_cfg)
    p = init_mla(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    y_t = mla_train(p, cfg, x, pos)
    y_p, ckv, kr = mla_prefill(p, cfg, x[:, :12], pos[:, :12])
    ckv_c = jnp.zeros((2, 16, 24)).at[:, :12].set(ckv)
    kr_c = jnp.zeros((2, 16, 8)).at[:, :12].set(kr)
    ys = [y_p]
    for i in range(12, 16):
        ln = jnp.full((2,), i, jnp.int32)
        yy, ckv_c, kr_c = mla_decode(p, cfg, x[:, i:i + 1], pos[:, i:i + 1],
                                     ckv_c, kr_c, ln)
        ys.append(yy)
    err = float(jnp.abs(y_t - jnp.concatenate(ys, 1)).max())
    assert err < 1e-3
