"""repro.maintenance — policy-driven scheduler semantics.

Covers: every policy × randomized op traces vs the oracle (searches and
successors must stay correct over items still pending in overflow buffers
— the policy-conditional I5'), flush restoring I5 (bit-for-bit vs an
eager-built tree when no op was force-blocked), the budgeted repair cap,
MaintenanceStats telemetry + the legacy ``rounds`` deprecation shim,
``make_index(maintenance=)`` validation, and the configurable lockstep
q_tile (TreeConfig / REPRO_PALLAS_QTILE).
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    OpBatch,
    make_index,
    supported_maintenance,
)
from repro.core import deltatree as DT
from repro.core.oracle import SetOracle
from repro.maintenance import MaintenanceStats, parse_policy
from tests.test_deltatree import check_invariants

POLICIES = ("eager", "deferred", "budgeted:2")
KEY_HI = 300

BUILD_KW = {
    "deltatree": dict(height=4, max_dnodes=512, buf_cap=8),
    "forest": dict(num_shards=3, height=4, max_dnodes=512, buf_cap=8,
                   key_max=KEY_HI),
}


def _check_reads(ix, oracle, rng):
    keys = rng.integers(1, KEY_HI + 5, size=24).astype(np.int32)
    f, _ = ix.search(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(f), oracle.snapshot_search(keys))
    live = oracle.keys()
    fs, sc = ix.successor(jnp.asarray(keys))
    idx = np.searchsorted(live, keys, side="right")
    ef = idx < live.size
    np.testing.assert_array_equal(np.asarray(fs), ef)
    if live.size:
        np.testing.assert_array_equal(np.asarray(sc)[ef], live[idx[ef]])


@pytest.mark.parametrize("backend", ["deltatree", "forest"])
@pytest.mark.parametrize("policy", POLICIES)
def test_policy_trace_matches_oracle(backend, policy):
    """Interleaved update + search/successor agree with the oracle under
    every policy — including reads over keys still pending in overflow
    buffers — and flush drains to I5 without changing the live set."""
    rng = np.random.default_rng(31)
    initial = np.unique(rng.integers(1, KEY_HI, 80).astype(np.int32))
    ix = make_index(backend, initial=initial, maintenance=policy,
                    **BUILD_KW[backend])
    assert ix.maintenance == policy
    oracle = SetOracle(initial)
    saw_pending = False
    for _ in range(8):
        _check_reads(ix, oracle, rng)
        kinds = rng.integers(0, 3, size=24).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=24).astype(np.int32)
        ix, res, stats = ix.update(OpBatch.mixed(kinds, keys))
        np.testing.assert_array_equal(
            np.asarray(res), oracle.apply_updates(kinds, keys))
        assert isinstance(stats, MaintenanceStats)
        saw_pending |= int(stats.pending) > 0
        if policy == "eager":
            assert int(stats.pending) == 0  # I5
        assert ix.size() == len(oracle.s)
        assert [k for k, _ in ix.live_items()] == sorted(oracle.s)
    if policy != "eager":
        assert saw_pending, "trace never exercised carried buffers"
    ix, fstats = ix.flush()
    assert int(fstats.pending) == 0
    assert [k for k, _ in ix.live_items()] == sorted(oracle.s)
    _check_reads(ix, oracle, rng)


def test_deferred_buffered_live_deleted_reads():
    """Explicit read legs over a deferred tree with non-empty buffers:
    buffered keys are found, deleted keys are not, untouched live keys
    stay found — through BOTH engines, bit for bit (hops included)."""
    cfg_s = DT.TreeConfig(height=4, max_dnodes=512, buf_cap=8,
                          maintenance="deferred")
    cfg_l = DT.TreeConfig(height=4, max_dnodes=512, buf_cap=8,
                          maintenance="deferred", engine="lockstep")
    rng = np.random.default_rng(33)
    initial = np.unique(rng.integers(1, KEY_HI, 90).astype(np.int32))
    t = DT.bulk_build(cfg_s, initial)
    oracle = SetOracle(initial)
    for _ in range(6):
        kinds = rng.integers(1, 3, size=24).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=24).astype(np.int32)
        t, res, stats = DT.update_batch(cfg_s, t, jnp.asarray(kinds),
                                        jnp.asarray(keys))
        oracle.apply_updates(kinds, keys)
    assert int(stats.pending) > 0, "trace must leave buffered items"
    check_invariants(cfg_s, t, require_empty_buffers=False)

    buffered = {int(cfg_s.key_of(v)) for row in np.asarray(t.buf)
                for v in row if v != 0}
    assert buffered and buffered <= oracle.s, "buffered keys must be live"
    live_not_buf = sorted(oracle.s - buffered)[:10]
    deleted = sorted(set(range(1, KEY_HI)) - oracle.s)[:10]
    q = np.asarray(sorted(buffered) + live_not_buf + deleted, np.int32)
    exp = np.asarray([k in oracle.s for k in q])

    f_s, h_s = DT.search_jit(cfg_s, t, jnp.asarray(q))
    f_l, h_l = DT.search_jit(cfg_l, t, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(f_s), exp)
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_l))
    np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_l))

    # successor must see buffered keys too (the buffered-floor fold)
    live = oracle.keys()
    probes = np.asarray([k - 1 for k in sorted(buffered)], np.int32)
    for cfg in (cfg_s, cfg_l):
        fs, sc = DT.successor_jit(cfg, t, jnp.asarray(probes))
        idx = np.searchsorted(live, probes, side="right")
        ef = idx < live.size
        np.testing.assert_array_equal(np.asarray(fs), ef)
        np.testing.assert_array_equal(np.asarray(sc)[ef], live[idx[ef]])

    # flush drains to I5; live set unchanged
    t, fstats = DT.flush(cfg_s, t)
    assert int(fstats.pending) == 0
    check_invariants(cfg_s, t)
    assert (DT.live_keys(cfg_s, t) == live).all()


@pytest.mark.skipif(not jax.config.jax_enable_x64,
                    reason="map mode packs int64 values; needs JAX_ENABLE_X64")
def test_deferred_map_mode_buffered_payloads():
    """Map-mode deferred leg: payloads of buffered (pending) items are
    returned by lookup, and both engines agree bit for bit."""
    bits = 6
    cfg_s = DT.TreeConfig(height=4, max_dnodes=512, buf_cap=8,
                          payload_bits=bits, maintenance="deferred")
    cfg_l = DT.TreeConfig(height=4, max_dnodes=512, buf_cap=8,
                          payload_bits=bits, maintenance="deferred",
                          engine="lockstep")
    rng = np.random.default_rng(34)
    initial = np.unique(rng.integers(1, KEY_HI, 70).astype(np.int32))
    pays = rng.integers(0, 2**bits, size=initial.size).astype(np.int32)
    t = DT.bulk_build(cfg_s, initial, pays)
    expect = dict(zip(initial.tolist(), pays.tolist()))
    for _ in range(5):
        kinds = rng.integers(1, 3, size=20).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=20).astype(np.int32)
        vals = rng.integers(0, 2**bits, size=20).astype(np.int32)
        t, res, stats = DT.update_batch(cfg_s, t, jnp.asarray(kinds),
                                        jnp.asarray(keys), jnp.asarray(vals))
        for kk, ky, pp, rr in zip(kinds, keys, vals, np.asarray(res)):
            if kk == 1 and rr:
                expect[int(ky)] = int(pp)
            elif kk == 2 and rr:
                expect.pop(int(ky), None)
    assert int(stats.pending) > 0
    q = np.asarray(sorted(expect), np.int32)
    f_s, p_s, h_s = DT.lookup_jit(cfg_s, t, jnp.asarray(q))
    f_l, p_l, h_l = DT.lookup_jit(cfg_l, t, jnp.asarray(q))
    assert bool(np.asarray(f_s).all())
    np.testing.assert_array_equal(
        np.asarray(p_s), np.asarray([expect[int(k)] for k in q]))
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_l))
    np.testing.assert_array_equal(np.asarray(p_s), np.asarray(p_l))
    np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_l))


def test_deferred_flush_bit_identical_to_eager():
    """deferred batch + flush(budget=min(K,64)) reproduces the EAGER tree
    bit for bit when no op was force-blocked (large buffers): same arrays,
    not just the same live set."""
    kw = dict(height=4, max_dnodes=512, buf_cap=64)  # roomy: no forcing
    cfg_e = DT.TreeConfig(**kw)
    cfg_d = DT.TreeConfig(**kw, maintenance="deferred")
    rng = np.random.default_rng(35)
    initial = np.unique(rng.integers(1, KEY_HI, 60).astype(np.int32))
    t_e = DT.bulk_build(cfg_e, initial)
    t_d = DT.bulk_build(cfg_d, initial)
    for step in range(4):
        kinds = rng.integers(1, 3, size=24).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=24).astype(np.int32)
        t_e, res_e, st_e = DT.update_batch(cfg_e, t_e, jnp.asarray(kinds),
                                           jnp.asarray(keys))
        t_d, res_d, st_d = DT.update_batch(cfg_d, t_d, jnp.asarray(kinds),
                                           jnp.asarray(keys))
        np.testing.assert_array_equal(np.asarray(res_e), np.asarray(res_d))
        assert int(st_d.rounds) == 1, "deferred should take one round here"
        t_d, _ = DT.flush(cfg_d, t_d, min(24, 64))
        for name, a, b in zip(DT.DeltaTree._fields, t_e, t_d):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"{name} @ step {step}")
    check_invariants(cfg_d, t_d)


def test_budgeted_respects_repair_budget():
    """With roomy buffers (no forced repairs) a budgeted:1 policy does at
    most one Rebalance/Expand/Merge per batch and carries the rest."""
    cfg = DT.TreeConfig(height=4, max_dnodes=512, buf_cap=64,
                        maintenance="budgeted:1")
    rng = np.random.default_rng(36)
    t = DT.empty(cfg)
    oracle = SetOracle()
    carried = False
    for _ in range(10):
        kinds = np.ones(24, np.int32)  # insert-heavy: plenty of flags
        keys = rng.integers(1, KEY_HI, size=24).astype(np.int32)
        t, res, stats = DT.update_batch(cfg, t, jnp.asarray(kinds),
                                        jnp.asarray(keys))
        np.testing.assert_array_equal(
            np.asarray(res), oracle.apply_updates(kinds, keys))
        repairs = int(stats.rebuilds) + int(stats.merges)
        assert repairs <= 1, stats.asdict()
        carried |= int(stats.pending) > 0
        assert (DT.live_keys(cfg, t) == oracle.keys()).all()
    assert carried, "budget never left work pending"
    t, _ = DT.flush(cfg, t)
    check_invariants(cfg, t)


def test_stats_shim_and_fields():
    """MaintenanceStats still unpacks like the old 3-tuple and coerces to
    the legacy round count via int() with a DeprecationWarning."""
    cfg = DT.TreeConfig(height=4, max_dnodes=128, buf_cap=8)
    t = DT.empty(cfg)
    t, res, rounds = DT.update_batch(
        cfg, t, jnp.asarray([1, 1], np.int32), jnp.asarray([5, 9], np.int32))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = int(rounds)
    assert legacy == int(rounds.rounds)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    d = rounds.asdict()
    assert set(d) == {"rounds", "rebuilds", "expands", "merges",
                      "pending", "reclaimed"}
    zero = MaintenanceStats.zero()
    assert int(zero.rounds) == 0


def test_make_index_maintenance_validation():
    for bad in ("warp", "budgeted", "budgeted:0", "budgeted:x", 7):
        with pytest.raises(ValueError):
            make_index("deltatree", maintenance=bad,
                       **BUILD_KW["deltatree"])
    with pytest.raises(ValueError, match="maintenance"):
        make_index("sorted_array", maintenance="deferred", cap=64)
    # eager is universal; baselines flush as a no-op returning stats=None
    ix = make_index("sorted_array", maintenance="eager", cap=64)
    assert ix.maintenance == "eager"
    assert not ix.capability.deferred_maintenance
    ix2, st = ix.flush()
    assert st is None and ix2.spec is ix.spec
    # policies smuggled via a prebuilt cfg= fail at construction
    with pytest.raises(ValueError, match="maintenance"):
        make_index("deltatree",
                   cfg=DT.TreeConfig(height=4, max_dnodes=64,
                                     maintenance="lazyy"))
    assert supported_maintenance("deltatree") == (
        "eager", "deferred", "budgeted")
    assert supported_maintenance("static_veb") == ("eager",)
    assert parse_policy("budgeted:4").budget == 4
    assert str(parse_policy("budgeted:4")) == "budgeted:4"


def test_forest_stats_aggregation():
    """Forest updates aggregate per-shard stats (pending sums across
    shards) and forest flush drains every shard."""
    rng = np.random.default_rng(37)
    initial = np.unique(rng.integers(1, KEY_HI, 100).astype(np.int32))
    ix = make_index("forest", initial=initial, maintenance="deferred",
                    **BUILD_KW["forest"])
    oracle = SetOracle(initial)
    for _ in range(4):
        kinds = rng.integers(1, 3, size=32).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=32).astype(np.int32)
        ix, res, stats = ix.update(OpBatch.mixed(kinds, keys))
        np.testing.assert_array_equal(
            np.asarray(res), oracle.apply_updates(kinds, keys))
    assert int(stats.pending) > 0
    total_buf = int(np.asarray(ix.state.trees.bcount).sum())
    assert total_buf == int(stats.pending)
    ix, fstats = ix.flush()
    assert int(fstats.pending) == 0
    assert int(np.asarray(ix.state.trees.bcount).sum()) == 0
    assert [k for k, _ in ix.live_items()] == sorted(oracle.s)


# --------------------------------------------------------------------------
# q_tile configuration (lockstep kernel tile)
# --------------------------------------------------------------------------


def test_q_tile_config_and_env(monkeypatch):
    from repro.kernels import ops as OPS

    assert OPS.default_q_tile() == 256
    monkeypatch.setenv("REPRO_PALLAS_QTILE", "128")
    assert OPS.default_q_tile() == 128
    monkeypatch.setenv("REPRO_PALLAS_QTILE", "100")
    with pytest.raises(ValueError, match="multiple of 128"):
        OPS.default_q_tile()  # the process-wide knob is lane-aligned
    monkeypatch.delenv("REPRO_PALLAS_QTILE")
    # explicit per-call tiles stay lenient (tests use 16/64 in interpret
    # mode) but must still be positive
    assert OPS._resolve_q_tile(64) == 64
    with pytest.raises(ValueError, match="positive"):
        OPS._resolve_q_tile(-4)
    cfg_bad = DT.TreeConfig(height=4, max_dnodes=64, engine="lockstep",
                            q_tile=-4)
    with pytest.raises(ValueError, match="positive"):
        DT.search_batch(cfg_bad, DT.empty(cfg_bad),
                        jnp.asarray([5], jnp.int32))

    # a TreeConfig q_tile override produces identical results
    rng = np.random.default_rng(38)
    initial = np.unique(rng.integers(1, KEY_HI, 80).astype(np.int32))
    q = jnp.asarray(rng.integers(1, KEY_HI, 64).astype(np.int32))
    cfg128 = DT.TreeConfig(height=4, max_dnodes=256, engine="lockstep",
                           q_tile=128)
    cfg_def = DT.TreeConfig(height=4, max_dnodes=256, engine="lockstep")
    t = DT.bulk_build(cfg128, initial)
    f_a, h_a = DT.search_jit(cfg128, t, q)
    f_b, h_b = DT.search_jit(cfg_def, t, q)
    np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f_b))
    np.testing.assert_array_equal(np.asarray(h_a), np.asarray(h_b))
    from benchmarks.common import resolved_q_tile
    ix = make_index("deltatree", initial=initial, engine="lockstep",
                    height=4, max_dnodes=256, q_tile=128)
    assert resolved_q_tile(ix) == 128


# the hypothesis property legs live in tests/test_maintenance_property.py
# (importorskip on hypothesis must not skip this whole module)
