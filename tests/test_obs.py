"""repro.obs — counter pytrees, trace spans, report CLI (DESIGN.md §9).

The two contracts everything else leans on:

- ``collect_stats=False`` is *free*: the dispatched read lowers to HLO
  byte-identical to the bare engine-hook composition (the pre-obs graph).
- ``collect_stats=True`` stats are engine-invariant: derived from the
  (found, hops) columns the conformance suite already pins bit-identical,
  so the hop histogram must match bit for bit across scalar/lockstep and
  across the forest's fused/vmap dispatches.
"""

import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import make_index
from repro.core import deltatree as DT
from repro.core import engine as E
from repro.core import layout
from repro.core.deltatree import TreeConfig
from repro.distributed import forest as D
from repro.distributed.forest import ForestConfig
from repro.obs import report, trace
from repro.obs.stats import (
    HOP_BINS,
    LATENCY_RESERVOIR,
    MaintenanceStats,
    ReadStats,
    RouterStats,
    SearchStats,
    ServeStats,
)

KEYS = np.arange(10, 400, 7, dtype=np.int64)
CFG = TreeConfig(height=4, max_dnodes=256, buf_cap=8, collect_stats=True)


def _queries():
    """Hits, misses, and born-resolved ROUTE_LEFT sentinel lanes."""
    return jnp.asarray(
        list(KEYS[:6]) + [5, 11, 401, layout.ROUTE_LEFT, layout.ROUTE_LEFT],
        jnp.int32)


# --------------------------------------------------------------- pytrees ---


def test_stats_jit_roundtrip():
    s = SearchStats.of(jnp.asarray([0, 1, 2, 2], jnp.int32),
                       jnp.zeros(4, bool), jnp.zeros(4, bool))
    r = RouterStats.of(jnp.asarray([3, 1], jnp.int32), 0)
    v = ServeStats.zero()

    s2 = jax.jit(lambda x: x.merge(x))(s)
    assert int(s2.queries) == 8 and int(s2.rounds) == 2
    r2 = jax.jit(lambda x: x.merge(x))(r)
    assert np.asarray(r2.lanes).tolist() == [6, 2]
    v2 = jax.jit(lambda x: x.record(1e-3, pending=3, flushed=True))(v)
    assert int(v2.steps) == 1 and int(v2.pending_hwm) == 3
    # ReadStats with router=None flattens to nothing on that leaf
    rs = ReadStats(search=s)
    rs2 = jax.jit(lambda x: x)(rs)
    assert rs2.router is None and int(rs2.search.queries) == 4


def test_reduce_semantics_max_rounds_sum_work():
    """reduce over stacked (S,) legs: rounds-like max, work-like sum."""
    a = SearchStats.of(jnp.asarray([1, 1], jnp.int32),
                       jnp.zeros(2, bool), jnp.zeros(2, bool))
    b = SearchStats.of(jnp.asarray([3, 2], jnp.int32),
                       jnp.zeros(2, bool), jnp.ones(2, bool))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), a, b)
    red = SearchStats.reduce(stacked)
    assert int(red.rounds) == 3 and int(red.hops_max) == 3   # critical path
    assert int(red.queries) == 4 and int(red.hops_sum) == 7  # work sums
    assert int(red.buffer_hits) == 2
    assert np.asarray(red.hops_hist).sum() == 4

    ma = MaintenanceStats(rounds=jnp.int32(2), rebuilds=jnp.int32(1),
                          expands=jnp.int32(0), merges=jnp.int32(3),
                          pending=jnp.int32(4))
    mb = MaintenanceStats(rounds=jnp.int32(5), rebuilds=jnp.int32(2),
                          expands=jnp.int32(1), merges=jnp.int32(0),
                          pending=jnp.int32(1))
    mred = MaintenanceStats.reduce(
        jax.tree.map(lambda *xs: jnp.stack(xs), ma, mb))
    assert int(mred.rounds) == 5          # max: shards run concurrently
    assert int(mred.rebuilds) == 3 and int(mred.pending) == 5  # sums


def test_serve_stats_ring_and_percentiles():
    s = ServeStats.zero()
    n = LATENCY_RESERVOIR + 40   # wrap the ring
    for i in range(n):
        s = s.record((i + 1) * 1e-6, pending=i % 7, flushed=(i % 10 == 0))
    assert int(s.steps) == n
    lat = s.valid_latencies()
    assert lat.size == LATENCY_RESERVOIR
    p = s.percentiles()
    assert 0 < p["p50_us"] <= p["p99_us"]
    d = s.asdict()
    assert d["flushes"] == (n + 9) // 10 and d["pending_hwm"] == 6


def test_maintenance_stats_rehomed():
    import repro.maintenance
    import repro.maintenance.stats
    import repro.obs.stats

    assert repro.maintenance.MaintenanceStats is MaintenanceStats
    assert repro.maintenance.stats.MaintenanceStats is \
        repro.obs.stats.MaintenanceStats


# ------------------------------------------------------ engine dispatch ---


@pytest.mark.parametrize("engine", ["scalar", "lockstep"])
def test_tree_read_stats(engine):
    import dataclasses

    cfg = dataclasses.replace(CFG, engine=engine)
    t = DT.bulk_build(cfg, KEYS)
    q = _queries()
    found, hops, stats = DT.search_jit(cfg, t, q)
    assert isinstance(stats, ReadStats) and stats.router is None
    s = stats.search
    assert int(s.queries) == q.shape[0]
    assert int(s.pad_lanes) == 2               # the two sentinel lanes
    assert int(s.hops_sum) == int(jnp.sum(hops))
    assert int(s.rounds) == int(jnp.max(hops)) == int(s.hops_max)
    ref_hist = np.bincount(np.clip(np.asarray(hops), 0, HOP_BINS - 1),
                           minlength=HOP_BINS)
    assert np.array_equal(np.asarray(s.hops_hist), ref_hist)
    # occupancy[r] = lanes active entering round r
    occ = np.asarray(s.occupancy)
    hnp = np.asarray(hops)
    assert all(occ[r] == int((hnp > r).sum()) for r in range(occ.size))


def test_hop_histogram_parity_across_engines():
    import dataclasses

    q = _queries()
    outs = {}
    for engine in ("scalar", "lockstep"):
        cfg = dataclasses.replace(CFG, engine=engine)
        t = DT.bulk_build(cfg, KEYS)
        outs[engine] = DT.search_jit(cfg, t, q)
    fs, hs, ss = outs["scalar"]
    fl, hl, sl = outs["lockstep"]
    assert np.array_equal(np.asarray(fs), np.asarray(fl))
    assert np.array_equal(np.asarray(hs), np.asarray(hl))
    for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(sl)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_buffer_hits_under_deferred_maintenance():
    import dataclasses

    cfg = dataclasses.replace(CFG, maintenance="deferred")
    t = DT.bulk_build(cfg, KEYS)
    # dense run between existing keys: overflows a leaf, so deferred
    # maintenance parks the spill in overflow buffers (I5' state)
    fresh = jnp.asarray([k for k in range(11, 30) if k not in set(KEYS)],
                        jnp.int32)
    t, res, _ = DT.update_batch(
        cfg, t, jnp.full(fresh.shape, DT.OP_INSERT, jnp.int32), fresh)
    assert bool(np.asarray(res).all())
    assert int(jnp.sum(t.bcount)) > 0   # deferred: items sit in buffers
    q = jnp.concatenate([fresh, jnp.asarray(KEYS[:4], jnp.int32)])
    found, hops, stats = DT.search_jit(cfg, t, q)
    assert bool(np.asarray(found).all())
    member = np.asarray(DT.buffered_member(cfg, t, q))
    expected = int((np.asarray(found) & member).sum())
    assert expected > 0                 # the leg is non-trivial
    assert int(stats.search.buffer_hits) == expected


def test_collect_stats_false_hlo_identical(monkeypatch):
    """The static gate's whole contract: the disabled dispatch lowers
    byte-identically to the bare engine-hook composition (= the pre-obs
    read path), and the enabled one doesn't."""
    monkeypatch.delenv(trace.ENV, raising=False)  # spans would rename scopes
    cfg = TreeConfig(height=4, max_dnodes=64, buf_cap=8)
    t = DT.bulk_build(cfg, KEYS[:20])
    q = jnp.asarray(KEYS[:8], jnp.int32)

    def dispatched(t, q):
        return E.search(cfg, t, q)

    def bare(t, q):
        found, _, hops = E.get_engine(cfg.engine).lookup(cfg, t, q)
        return found, hops

    def norm(txt):
        return re.sub(r"jit_\w+", "jit_fn", txt)

    lo_d = norm(jax.jit(dispatched).lower(t, q).as_text())
    lo_b = norm(jax.jit(bare).lower(t, q).as_text())
    assert lo_d == lo_b

    import dataclasses

    cfg_on = dataclasses.replace(cfg, collect_stats=True)
    lo_on = norm(jax.jit(lambda t, q: E.search(cfg_on, t, q))
                 .lower(t, q).as_text())
    assert lo_on != lo_b


def test_index_handle_collect_stats():
    ix = make_index("deltatree", initial=KEYS, height=4, max_dnodes=256,
                    buf_cap=8, collect_stats=True)
    assert ix.collect_stats
    found, hops, stats = ix.search(_queries())
    assert int(stats.search.queries) == int(_queries().shape[0])
    off = make_index("deltatree", initial=KEYS, height=4, max_dnodes=256,
                    buf_cap=8)
    assert not off.collect_stats
    assert len(off.search(_queries())) == 2
    assert not make_index("sorted_array", initial=KEYS).collect_stats


# ---------------------------------------------------------------- forest ---


def _fcfg(engine="scalar", fused=True):
    import dataclasses

    return ForestConfig(
        num_shards=4,
        tree=dataclasses.replace(CFG, engine=engine),
        fused=fused)


def test_forest_read_stats_router_leg():
    fcfg = _fcfg()
    f = D.bulk_build(fcfg, KEYS)
    q = _queries()
    found, hops, stats = D.search_batch(fcfg, f, q)
    r = stats.router
    assert r is not None
    assert int(np.asarray(r.lanes).sum()) == int(q.shape[0])
    assert int(r.batches) == 1
    assert r.skew() >= 1.0
    # ROUTE_LEFT inputs are already at the clamp target -> clamped counts
    # only keys the router *rewrote*; probe one true out-of-domain key
    _, _, st2 = D.search_batch(fcfg, f, jnp.asarray([-5, 7], jnp.int32))
    assert int(st2.router.clamped) == 1


@pytest.mark.parametrize("engine", ["scalar", "lockstep"])
def test_forest_stats_dispatch_parity(engine):
    """fused and vmap dispatches must produce bit-identical ReadStats."""
    q = _queries()
    outs = []
    for fused in (True, False):
        fcfg = _fcfg(engine, fused)
        f = D.bulk_build(fcfg, KEYS)
        outs.append(D.search_batch(fcfg, f, q))
    (fa, ha, sa), (fb, hb, sb) = outs
    assert np.array_equal(np.asarray(fa), np.asarray(fb))
    assert np.array_equal(np.asarray(ha), np.asarray(hb))
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_forest_load_counters_accumulate_and_survive_flush():
    import dataclasses

    fcfg = dataclasses.replace(
        _fcfg(), tree=dataclasses.replace(CFG, maintenance="deferred"))
    f = D.bulk_build(fcfg, KEYS)
    assert D.shard_load(f) == {"reads": [0] * 4, "updates": [0] * 4}
    q = jnp.asarray(KEYS[:12], jnp.int32)
    f = D.record_reads(fcfg, f, q)
    f = D.record_reads(fcfg, f, q)
    load = D.shard_load(f)
    assert sum(load["reads"]) == 24 and sum(load["updates"]) == 0
    kinds = jnp.asarray([DT.OP_INSERT, DT.OP_SEARCH, DT.OP_INSERT,
                         DT.OP_DELETE], jnp.int32)
    keys = jnp.asarray([13, 17, 20, int(KEYS[3])], jnp.int32)
    f, _, _ = D.update_batch(fcfg, f, kinds, keys)
    load = D.shard_load(f)
    assert sum(load["updates"]) == 3      # OP_SEARCH rows don't count
    f, _ = D.flush(fcfg, f)
    assert D.shard_load(f) == load        # flush preserves the counters


def test_forest_stats_8dev_shard_map():
    """Stats survive a real multi-device shard_map dispatch: lanes sum to
    K and the fused/vmap parity holds under 8 fake devices."""
    from tests._subproc import run_py

    out = run_py("""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.core.deltatree import TreeConfig
from repro.distributed import forest as D
from repro.distributed.forest import ForestConfig
keys = np.arange(10, 400, 7, dtype=np.int64)
cfg = TreeConfig(height=4, max_dnodes=256, buf_cap=8, collect_stats=True,
                 engine="lockstep")
q = jnp.asarray(list(keys[:6]) + [5, 11, 401], jnp.int32)
outs = []
for fused in (True, False):
    fcfg = ForestConfig(num_shards=8, tree=cfg, fused=fused)
    f = D.bulk_build(fcfg, keys)
    outs.append(D.search_batch(fcfg, f, q))
(fa, ha, sa), (fb, hb, sb) = outs
assert np.array_equal(np.asarray(ha), np.asarray(hb))
for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
print("lanes", int(np.asarray(sa.router.lanes).sum()), "of", q.shape[0])
""", devices=8)
    assert "lanes 9 of 9" in out


# ----------------------------------------------------------------- trace ---


def test_trace_gating(monkeypatch):
    import contextlib

    monkeypatch.delenv(trace.ENV, raising=False)
    assert not trace.enabled()
    assert isinstance(trace.annotate("x"), contextlib.nullcontext)
    assert isinstance(trace.span("x"), contextlib.nullcontext)
    monkeypatch.setenv(trace.ENV, "1")
    assert trace.enabled()
    with trace.span("obs-test"), trace.annotate("obs-test-inner"):
        assert int(jnp.int32(1) + 1) == 2
    monkeypatch.setenv(trace.ENV, "0")
    assert not trace.enabled()


def test_trace_span_events_and_chrome_trace(tmp_path, monkeypatch):
    monkeypatch.setenv(trace.ENV, "1")
    trace.reset_counters()
    trace.reset_events()
    with trace.span("obs-evt"):
        pass
    with trace.span("obs-evt"):
        pass
    evs = trace.events()
    assert len(evs) == 2
    assert all(e["name"] == "obs-evt" and e["ph"] == "X" and
               e["dur"] >= 0 for e in evs)
    # counters reset keeps the event ring (whole-run --trace-dir
    # timelines survive per-row counter resets); reset_events clears it
    assert trace.counters()["obs-evt"] == 2
    trace.reset_counters()
    assert trace.counters() == {}
    assert len(trace.events()) == 2

    path = tmp_path / "chrome_trace.json"
    n = trace.write_chrome_trace(str(path))
    assert n == 2
    doc = json.loads(path.read_text())
    assert [e["name"] for e in doc["traceEvents"]] == ["obs-evt", "obs-evt"]

    trace.reset_events()
    assert trace.events() == []


def test_trace_counters_thread_safe(monkeypatch):
    """Concurrent bumps from many threads must not drop counts (the
    module lock satellite: dict updates raced before)."""
    import threading

    monkeypatch.setenv(trace.ENV, "1")
    trace.reset_counters()
    N, T = 2000, 8

    def work():
        for _ in range(N):
            trace.bump("obs-race")

    threads = [threading.Thread(target=work) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert trace.counters()["obs-race"] == N * T
    trace.reset_counters()


def test_run_index_resets_counters_between_rows(monkeypatch):
    """benchmarks.common.run_index resets the span counters per row so
    columns like walk_launches can't leak across measurement rows."""
    monkeypatch.setenv(trace.ENV, "1")
    trace.bump("leaked.counter", 41)
    from benchmarks.common import run_index

    run_index("sorted_array", KEYS, key_hi=500, update_pct=0.0,
              batch=8, total_ops=16)
    assert "leaked.counter" not in trace.counters()


def test_trace_capture_smoke(tmp_path):
    try:
        out = trace.trace_run(
            lambda x: jnp.sum(x * 2), jnp.arange(8), logdir=str(tmp_path))
    except Exception as e:                      # pragma: no cover
        pytest.skip(f"profiler unavailable here: {e}")
    assert int(out) == 56
    assert any(tmp_path.rglob("*"))             # something was dumped


# ---------------------------------------------------------------- report ---


def _bench(ops, ts="t0", extra=None):
    rows = []
    for backend, v in ops.items():
        r = {"suite": "fig11", "bench": "b", "backend": backend,
             "engine": "scalar", "update_pct": 10, "batch": 256,
             "seed": 0, "ops_per_s": v}
        r.update(extra or {})
        rows.append(r)
    return {"timestamp": ts, "args": {"smoke": True}, "rows": rows}


def test_report_render_and_diff(tmp_path, capsys):
    new = _bench({"deltatree": 850.0, "sorted_array": 3000.0}, "t1",
                 extra={"dispatch": None})  # newer schema: extra ID key
    base = _bench({"deltatree": 1000.0, "sorted_array": 1000.0}, "t0")
    pn, pb = tmp_path / "new.json", tmp_path / "base.json"
    pn.write_text(json.dumps(new))
    pb.write_text(json.dumps(base))

    rc = report.main([str(pn)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "## fig11 (2 rows)" in out and "deltatree" in out

    out_md = tmp_path / "report.md"
    rc = report.main([str(pn), "--diff", str(pb), "--out", str(out_md)])
    assert rc == 0   # regressions flagged but not failing by default
    text = out_md.read_text()
    assert "2 matched" in text
    assert "0.850x  << REGRESSION" in text   # deltatree slipped to 0.85x
    assert "3.000x" in text                  # sorted_array sped up

    rc = report.main([str(pn), "--diff", str(pb), "--threshold", "0.95",
                      "--fail-on-regression"])
    assert rc == 1
    rc = report.main([str(pn), "--diff", str(pb), "--threshold", "0.5",
                      "--fail-on-regression"])
    assert rc == 0


def test_report_history(tmp_path, capsys):
    """--history renders one column per BENCH file, rows matched by
    identity label, cells the primary metric (missing files -> '-')."""
    b0 = _bench({"deltatree": 1000.0, "sorted_array": 900.0}, "t0")
    b1 = _bench({"deltatree": 1500.0}, "t1")
    p0, p1 = tmp_path / "b0.json", tmp_path / "b1.json"
    p0.write_text(json.dumps(b0))
    p1.write_text(json.dumps(b1))

    lines = report.history([b1, b0])          # order-insensitive (sorted)
    text = "\n".join(lines)
    assert "# history across 2 files" in text
    assert "t0" in text and "t1" in text
    row = next(ln for ln in lines if "deltatree" in ln)
    assert "1000" in row and "1500" in row
    row = next(ln for ln in lines if "sorted_array" in ln)
    assert "900" in row and row.rstrip().endswith("-")  # absent at t1

    # duplicate timestamps still get one column each
    lines = report.history([b0, dict(b0)])
    assert any("t0'" in ln for ln in lines)

    out_md = tmp_path / "hist.md"
    rc = report.main([str(p0), str(p1), "--history", "--out", str(out_md)])
    assert rc == 0
    assert "# history across 2 files" in out_md.read_text()
    capsys.readouterr()

    with pytest.raises(SystemExit):           # many files need --history
        report.main([str(p0), str(p1)])
    capsys.readouterr()


def test_report_tolerant_matching(tmp_path):
    """A key missing on either side is a wildcard; ambiguity unmatches."""
    new = _bench({"deltatree": 500.0}, extra={"flush_every": 0})
    base = _bench({"deltatree": 1000.0})
    lines, regs = report.diff(new, base)
    assert len(regs) == 1
    # two identical base rows for the same identity -> ambiguous -> skip
    base2 = {"timestamp": "t", "args": {},
             "rows": base["rows"] + [dict(base["rows"][0])]}
    lines, regs = report.diff(new, base2)
    assert regs == [] and any("1 unmatched" in ln for ln in lines)


# ---------------------------------------------------------------- export ---


def test_export_snapshot_prometheus_json():
    from repro.obs import export

    s = SearchStats.of(jnp.asarray([0, 1, 2, 2], jnp.int32),
                       jnp.zeros(4, bool), jnp.zeros(4, bool))
    snap = export.snapshot(search=s, pager={"searches": 7, "hops": 3.5},
                           router=None)
    assert "router" not in snap                  # None groups dropped
    assert snap["search"]["queries"] == 4
    assert snap["pager"]["searches"] == 7
    # everything is plain python (json-serializable), lists included
    doc = json.loads(export.to_json(snap))
    assert doc["search"]["hops_hist"][0] == 1    # the zero-hop lane

    prom = export.to_prometheus(snap)
    assert "# TYPE repro_search_queries gauge" in prom
    assert "repro_search_queries 4" in prom
    assert 'repro_search_hops_hist{index="0"} 1' in prom
    assert "repro_pager_hops 3.5" in prom
    assert export.to_prometheus({}) == ""


def test_export_transfer_stats_group():
    """TransferStats round-trips through snapshot/prometheus with its
    per-block-size series."""
    from repro.core import deltatree as DT
    from repro.obs import export, transfers as OTR

    cfg = TreeConfig(height=4, max_dnodes=256, buf_cap=8,
                     collect_stats=True, collect_transfers=True)
    t = DT.bulk_build(cfg, KEYS)
    ts = OTR.measure(cfg, t, _queries())
    snap = export.snapshot(transfers=ts)
    d = snap["transfers"]
    assert d["queries"] == 11 and d["pad_lanes"] == 2
    prom = export.to_prometheus(snap)
    for b in OTR.TRANSFER_BLOCK_SIZES:
        assert f"repro_transfers_blocks_b{b} " in prom
    json.loads(export.to_json(snap))             # serializable end to end


def test_serve_stats_probe_accounting():
    s = ServeStats.zero()
    s = s.record_probe(12, 9)
    s = s.record(1e-3, pending=2, flushed=False)  # steps don't disturb it
    s = s.record_probe(4, 0)
    assert int(s.probe_queries) == 16 and int(s.probe_hits) == 9
    assert int(s.steps) == 1
    d = s.asdict()
    assert d["probe_queries"] == 16 and d["probe_hits"] == 9
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), s, s)
    red = ServeStats.reduce(stacked)
    assert int(red.probe_queries) == 32 and int(red.probe_hits) == 18
    assert int(red.steps) == 2
