"""Backend conformance: one randomized op-trace, every registered backend.

The same trace (snapshot searches + batch-order insert/delete + successor
probes + live-set dumps) runs against each ``available_backends()`` entry
through the uniform ``Index`` handle and is cross-checked step by step
against ``core.oracle``.  Capability-gated surfaces (map mode, successor)
skip where the backend declares no support; map mode additionally needs
JAX_ENABLE_X64 (packed int64 values).  A subprocess leg replays the forest
trace over 8 fake host devices (real shard_map dispatch).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CapabilityError,
    Index,
    OpBatch,
    available_backends,
    make_index,
)
from repro.core.oracle import MapOracle, SetOracle
from tests._subproc import run_py

BACKENDS = available_backends()
KEY_HI = 300

# trace-scale construction kwargs per backend
BUILD_KW = {
    "deltatree": dict(height=4, max_dnodes=512, buf_cap=8),
    "forest": dict(num_shards=3, height=4, max_dnodes=512, buf_cap=8,
                   key_max=KEY_HI),
    "sorted_array": dict(cap=4096),
    "pointer_bst": dict(cap=4096),
    "static_veb": {},
}
# backends with a payload_bits knob (map-mode capable); the rest are set-only
MAP_BACKENDS = {"deltatree", "forest"}


def _mk(backend: str, initial, payload_bits: int = 0, payloads=None) -> Index:
    kw = dict(BUILD_KW[backend])
    if payload_bits:
        kw["payload_bits"] = payload_bits
    return make_index(backend, initial=initial, payloads=payloads, **kw)


def _check_successor(ix: Index, oracle_keys: list[int], rng) -> None:
    q = rng.integers(1, KEY_HI + 5, size=16).astype(np.int32)
    fs, sc = ix.successor(jnp.asarray(q))
    for qi, fi, si in zip(q, np.asarray(fs), np.asarray(sc)):
        exp = next((k for k in oracle_keys if k > qi), None)
        assert bool(fi) == (exp is not None), (ix.backend, qi, fi, exp)
        if exp is not None:
            assert int(si) == exp, (ix.backend, qi, int(si), exp)


@pytest.mark.parametrize("backend", BACKENDS)
def test_set_trace_matches_oracle(backend):
    rng = np.random.default_rng(11)
    initial = np.unique(rng.integers(1, KEY_HI, 80).astype(np.int32))
    ix = _mk(backend, initial)
    oracle = SetOracle(initial)
    for _ in range(8):
        kinds = rng.integers(0, 3, size=24).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=24).astype(np.int32)
        # wait-free searches observe the pre-step snapshot
        f, _ = ix.search(jnp.asarray(keys))
        np.testing.assert_array_equal(
            np.asarray(f), oracle.snapshot_search(keys))
        # updates apply in batch order; OP_SEARCH rows are no-ops
        ix, res = ix.insert_delete(OpBatch.mixed(kinds, keys))
        np.testing.assert_array_equal(
            np.asarray(res), oracle.apply_updates(kinds, keys))
        assert not ix.alloc_failed()
        assert ix.size() == len(oracle.s)
        assert [k for k, _ in ix.live_items()] == sorted(oracle.s)
        if ix.capability.successor:
            _check_successor(ix, sorted(oracle.s), rng)


@pytest.mark.parametrize("backend", BACKENDS)
def test_map_trace_matches_oracle(backend):
    if backend not in MAP_BACKENDS:
        # declared set-only: the factory must reject payloads and the
        # handle must refuse map-mode reads
        with pytest.raises(ValueError, match="payload"):
            _mk(backend, np.asarray([5], np.int32),
                payloads=np.asarray([1], np.int32))
        ix = _mk(backend, np.asarray([5, 9], np.int32))
        assert not ix.capability.map_mode
        with pytest.raises(CapabilityError):
            ix.lookup(jnp.asarray([5], jnp.int32))
        return
    if not jax.config.jax_enable_x64:
        pytest.skip("map mode packs int64 values; needs JAX_ENABLE_X64")
    bits = 6
    rng = np.random.default_rng(12)
    initial = np.unique(rng.integers(1, KEY_HI, 60).astype(np.int32))
    pays = rng.integers(0, 2**bits, size=initial.size).astype(np.int32)
    ix = _mk(backend, initial, payload_bits=bits, payloads=pays)
    assert ix.capability.map_mode
    oracle = MapOracle(zip(initial, pays))
    for _ in range(6):
        kinds = rng.integers(0, 3, size=20).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=20).astype(np.int32)
        vals = rng.integers(0, 2**bits, size=20).astype(np.int32)
        f, p, _ = ix.lookup(jnp.asarray(keys))
        ef, ep = oracle.snapshot_lookup(keys)
        np.testing.assert_array_equal(np.asarray(f), ef)
        np.testing.assert_array_equal(np.asarray(p)[ef], ep[ef])
        ix, res = ix.insert_delete(OpBatch.mixed(kinds, keys, vals))
        np.testing.assert_array_equal(
            np.asarray(res), oracle.apply_updates(kinds, keys, vals))
        assert ix.live_items() == oracle.items()


@pytest.mark.parametrize("backend", [b for b in BACKENDS
                                     if b not in ("deltatree", "forest")])
def test_successor_capability_gate(backend):
    ix = _mk(backend, np.asarray([5, 9], np.int32))
    if ix.capability.successor:
        fs, sc = ix.successor(jnp.asarray([6], jnp.int32))
        assert bool(fs[0]) and int(sc[0]) == 9
    else:
        with pytest.raises(CapabilityError):
            ix.successor(jnp.asarray([6], jnp.int32))


def test_index_and_opbatch_flow_through_jit():
    """The handle is a pytree (state dynamic, spec static): a jitted step
    can consume and return Index + OpBatch without host round-trips."""
    ix = make_index("deltatree", height=4, max_dnodes=64, buf_cap=8)

    @jax.jit
    def step(ix: Index, batch: OpBatch):
        ix2, res = ix.insert_delete(batch)
        found, _ = ix2.search(batch.keys)
        return ix2, res, found

    ix2, res, found = step(ix, OpBatch.inserts([5, 9, 40]))
    assert isinstance(ix2, Index) and ix2.spec is ix.spec
    assert np.asarray(res).all() and np.asarray(found).all()
    ix3, res2, found2 = step(ix2, OpBatch.deletes([9, 7, 9]))
    np.testing.assert_array_equal(np.asarray(res2), [True, False, False])
    assert [k for k, _ in ix3.live_items()] == [5, 40]


def test_make_index_unknown_backend():
    with pytest.raises(KeyError, match="registered"):
        make_index("btree_of_dreams")


def test_forest_conformance_8dev_subprocess():
    """The same set trace passes with the forest backend fanned out over 8
    fake host devices (true shard_map dispatch, CI matrix leg)."""
    out = run_py("""
import numpy as np, jax.numpy as jnp
from repro.api import make_index, OpBatch
from repro.core.oracle import SetOracle

rng = np.random.default_rng(13)
initial = np.unique(rng.integers(1, 400, 120).astype(np.int32))
ix = make_index("forest", initial=initial, num_shards=8, height=4,
                max_dnodes=256, buf_cap=8, key_max=400)
oracle = SetOracle(initial)
for _ in range(5):
    kinds = rng.integers(0, 3, size=32).astype(np.int32)
    keys = rng.integers(1, 400, size=32).astype(np.int32)
    f, _ = ix.search(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(f), oracle.snapshot_search(keys))
    ix, res = ix.insert_delete(OpBatch.mixed(kinds, keys))
    np.testing.assert_array_equal(np.asarray(res), oracle.apply_updates(kinds, keys))
assert [k for k, _ in ix.live_items()] == sorted(oracle.s)
print("FOREST 8DEV OK")
""", devices=8)
    assert "FOREST 8DEV OK" in out
