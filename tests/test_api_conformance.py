"""Backend conformance: one randomized op-trace, every registered backend.

The same trace (snapshot searches + batch-order insert/delete + successor
probes + live-set dumps) runs against each ``available_backends()`` entry
through the uniform ``Index`` handle and is cross-checked step by step
against ``core.oracle``.  Capability-gated surfaces (map mode, successor)
skip where the backend declares no support; map mode additionally needs
JAX_ENABLE_X64 (packed int64 values).  A subprocess leg replays the forest
trace over 8 fake host devices (real shard_map dispatch).

Engine parity: backends declaring the ``lockstep`` SearchEngine replay the
same randomized trace under ``engine="scalar"`` and ``engine="lockstep"``
and must agree *bit for bit* — found, payloads, successor results, and the
per-query hop counts (the transfer statistic) — including marked leaves
and buffer-resident keys in map mode (mid-maintenance states injected at
the core level).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CapabilityError,
    Index,
    OpBatch,
    available_backends,
    make_index,
    supported_engines,
)
from repro.core.oracle import MapOracle, SetOracle
from tests._subproc import run_py

BACKENDS = available_backends()
ENGINE_BACKENDS = [b for b in BACKENDS
                   if "lockstep" in supported_engines(b)]
KEY_HI = 300

# trace-scale construction kwargs per backend
BUILD_KW = {
    "deltatree": dict(height=4, max_dnodes=512, buf_cap=8),
    "forest": dict(num_shards=3, height=4, max_dnodes=512, buf_cap=8,
                   key_max=KEY_HI),
    "sorted_array": dict(cap=4096),
    "pointer_bst": dict(cap=4096),
    "static_veb": {},
}
# backends with a payload_bits knob (map-mode capable); the rest are set-only
MAP_BACKENDS = {"deltatree", "forest"}


def _mk(backend: str, initial, payload_bits: int = 0, payloads=None,
        engine: str | None = None) -> Index:
    kw = dict(BUILD_KW[backend])
    if payload_bits:
        kw["payload_bits"] = payload_bits
    return make_index(backend, initial=initial, payloads=payloads,
                      engine=engine, **kw)


def _check_successor(ix: Index, oracle_keys: list[int], rng) -> None:
    q = rng.integers(1, KEY_HI + 5, size=16).astype(np.int32)
    fs, sc = ix.successor(jnp.asarray(q))
    for qi, fi, si in zip(q, np.asarray(fs), np.asarray(sc)):
        exp = next((k for k in oracle_keys if k > qi), None)
        assert bool(fi) == (exp is not None), (ix.backend, qi, fi, exp)
        if exp is not None:
            assert int(si) == exp, (ix.backend, qi, int(si), exp)


@pytest.mark.parametrize("backend", BACKENDS)
def test_set_trace_matches_oracle(backend):
    rng = np.random.default_rng(11)
    initial = np.unique(rng.integers(1, KEY_HI, 80).astype(np.int32))
    ix = _mk(backend, initial)
    oracle = SetOracle(initial)
    for _ in range(8):
        kinds = rng.integers(0, 3, size=24).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=24).astype(np.int32)
        # wait-free searches observe the pre-step snapshot
        f, _ = ix.search(jnp.asarray(keys))
        np.testing.assert_array_equal(
            np.asarray(f), oracle.snapshot_search(keys))
        # updates apply in batch order; OP_SEARCH rows are no-ops
        ix, res = ix.insert_delete(OpBatch.mixed(kinds, keys))
        np.testing.assert_array_equal(
            np.asarray(res), oracle.apply_updates(kinds, keys))
        assert not ix.alloc_failed()
        assert ix.size() == len(oracle.s)
        assert [k for k, _ in ix.live_items()] == sorted(oracle.s)
        if ix.capability.successor:
            _check_successor(ix, sorted(oracle.s), rng)


@pytest.mark.parametrize("backend", BACKENDS)
def test_map_trace_matches_oracle(backend):
    if backend not in MAP_BACKENDS:
        # declared set-only: the factory must reject payloads and the
        # handle must refuse map-mode reads
        with pytest.raises(ValueError, match="payload"):
            _mk(backend, np.asarray([5], np.int32),
                payloads=np.asarray([1], np.int32))
        ix = _mk(backend, np.asarray([5, 9], np.int32))
        assert not ix.capability.map_mode
        with pytest.raises(CapabilityError):
            ix.lookup(jnp.asarray([5], jnp.int32))
        return
    if not jax.config.jax_enable_x64:
        pytest.skip("map mode packs int64 values; needs JAX_ENABLE_X64")
    bits = 6
    rng = np.random.default_rng(12)
    initial = np.unique(rng.integers(1, KEY_HI, 60).astype(np.int32))
    pays = rng.integers(0, 2**bits, size=initial.size).astype(np.int32)
    ix = _mk(backend, initial, payload_bits=bits, payloads=pays)
    assert ix.capability.map_mode
    oracle = MapOracle(zip(initial, pays))
    for _ in range(6):
        kinds = rng.integers(0, 3, size=20).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=20).astype(np.int32)
        vals = rng.integers(0, 2**bits, size=20).astype(np.int32)
        f, p, _ = ix.lookup(jnp.asarray(keys))
        ef, ep = oracle.snapshot_lookup(keys)
        np.testing.assert_array_equal(np.asarray(f), ef)
        np.testing.assert_array_equal(np.asarray(p)[ef], ep[ef])
        ix, res = ix.insert_delete(OpBatch.mixed(kinds, keys, vals))
        np.testing.assert_array_equal(
            np.asarray(res), oracle.apply_updates(kinds, keys, vals))
        assert ix.live_items() == oracle.items()


@pytest.mark.parametrize("backend", [b for b in BACKENDS
                                     if b not in ("deltatree", "forest")])
def test_successor_capability_gate(backend):
    ix = _mk(backend, np.asarray([5, 9], np.int32))
    if ix.capability.successor:
        fs, sc = ix.successor(jnp.asarray([6], jnp.int32))
        assert bool(fs[0]) and int(sc[0]) == 9
    else:
        with pytest.raises(CapabilityError):
            ix.successor(jnp.asarray([6], jnp.int32))


def test_index_and_opbatch_flow_through_jit():
    """The handle is a pytree (state dynamic, spec static): a jitted step
    can consume and return Index + OpBatch without host round-trips."""
    ix = make_index("deltatree", height=4, max_dnodes=64, buf_cap=8)

    @jax.jit
    def step(ix: Index, batch: OpBatch):
        ix2, res = ix.insert_delete(batch)
        found, _ = ix2.search(batch.keys)
        return ix2, res, found

    ix2, res, found = step(ix, OpBatch.inserts([5, 9, 40]))
    assert isinstance(ix2, Index) and ix2.spec is ix.spec
    assert np.asarray(res).all() and np.asarray(found).all()
    ix3, res2, found2 = step(ix2, OpBatch.deletes([9, 7, 9]))
    np.testing.assert_array_equal(np.asarray(res2), [True, False, False])
    assert [k for k, _ in ix3.live_items()] == [5, 40]


def test_make_index_unknown_backend():
    with pytest.raises(KeyError, match="registered"):
        make_index("btree_of_dreams")


# --------------------------------------------------------------------------
# SearchEngine parity: scalar vs lockstep, bit for bit
# --------------------------------------------------------------------------


def _assert_engines_agree(ix_s: Index, ix_l: Index, keys) -> None:
    """Reads through both engine handles must match bit for bit, hops
    (the transfer statistic) included."""
    q = jnp.asarray(keys)
    f_s, h_s = ix_s.search(q)
    f_l, h_l = ix_l.search(q)
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_l))
    np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_l))
    sf_s, sc_s = ix_s.successor(q)
    sf_l, sc_l = ix_l.successor(q)
    np.testing.assert_array_equal(np.asarray(sf_s), np.asarray(sf_l))
    np.testing.assert_array_equal(np.asarray(sc_s), np.asarray(sc_l))


@pytest.mark.parametrize("backend", ENGINE_BACKENDS)
def test_engine_parity_set_trace(backend):
    """The same randomized op trace under engine="scalar" and
    engine="lockstep" agrees bit-for-bit at every step (found, hops,
    successor, update results) — deletes leave marked leaves behind, so
    tombstone handling is exercised throughout."""
    rng = np.random.default_rng(21)
    initial = np.unique(rng.integers(1, KEY_HI, 90).astype(np.int32))
    ix_s = _mk(backend, initial, engine="scalar")
    ix_l = _mk(backend, initial, engine="lockstep")
    assert ix_s.engine == "scalar" and ix_l.engine == "lockstep"
    oracle = SetOracle(initial)
    for _ in range(6):
        keys = rng.integers(1, KEY_HI + 5, size=24).astype(np.int32)
        _assert_engines_agree(ix_s, ix_l, keys)
        # both engines still track the oracle, not just each other
        f_l, _ = ix_l.search(jnp.asarray(keys))
        np.testing.assert_array_equal(
            np.asarray(f_l), oracle.snapshot_search(keys))
        kinds = rng.integers(0, 3, size=24).astype(np.int32)
        batch = OpBatch.mixed(kinds, np.clip(keys, 1, KEY_HI - 1))
        ix_s, r_s = ix_s.insert_delete(batch)
        ix_l, r_l = ix_l.insert_delete(batch)
        oracle.apply_updates(np.asarray(batch.kinds), np.asarray(batch.keys))
        np.testing.assert_array_equal(np.asarray(r_s), np.asarray(r_l))
    assert ix_s.live_items() == ix_l.live_items() == \
        [(k, 0) for k in sorted(oracle.s)]


@pytest.mark.parametrize("backend", ENGINE_BACKENDS)
def test_engine_parity_map_trace(backend):
    """Map-mode parity: payloads unpacked from packed int64 leaves must be
    identical through both engines at every step."""
    if not jax.config.jax_enable_x64:
        pytest.skip("map mode packs int64 values; needs JAX_ENABLE_X64")
    bits = 6
    rng = np.random.default_rng(22)
    initial = np.unique(rng.integers(1, KEY_HI, 70).astype(np.int32))
    pays = rng.integers(0, 2**bits, size=initial.size).astype(np.int32)
    ix_s = _mk(backend, initial, payload_bits=bits, payloads=pays,
               engine="scalar")
    ix_l = _mk(backend, initial, payload_bits=bits, payloads=pays,
               engine="lockstep")
    for _ in range(4):
        keys = rng.integers(1, KEY_HI, size=20).astype(np.int32)
        f_s, p_s, h_s = ix_s.lookup(jnp.asarray(keys))
        f_l, p_l, h_l = ix_l.lookup(jnp.asarray(keys))
        np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_l))
        np.testing.assert_array_equal(np.asarray(p_s), np.asarray(p_l))
        np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_l))
        _assert_engines_agree(ix_s, ix_l, keys)
        kinds = rng.integers(0, 3, size=20).astype(np.int32)
        vals = rng.integers(0, 2**bits, size=20).astype(np.int32)
        batch = OpBatch.mixed(kinds, keys, vals)
        ix_s, r_s = ix_s.insert_delete(batch)
        ix_l, r_l = ix_l.insert_delete(batch)
        np.testing.assert_array_equal(np.asarray(r_s), np.asarray(r_l))
    assert ix_s.live_items() == ix_l.live_items()


def test_engine_parity_marked_and_buffered_state():
    """Mid-maintenance ΔTree states — buffer-resident keys and marked
    leaves that no API-level trace can pin down (update_batch drains
    buffers to empty, invariant I5) — read identically through both
    engines: found, payload, hops, successor, all bit for bit."""
    if not jax.config.jax_enable_x64:
        pytest.skip("map mode packs int64 values; needs JAX_ENABLE_X64")
    from repro.core import deltatree as DT

    bits = 6
    rng = np.random.default_rng(23)
    cfg_s = DT.TreeConfig(height=4, max_dnodes=256, buf_cap=8,
                          payload_bits=bits)
    cfg_l = DT.TreeConfig(height=4, max_dnodes=256, buf_cap=8,
                          payload_bits=bits, engine="lockstep")
    vals = np.unique(rng.integers(1, KEY_HI, 100).astype(np.int32))
    pays = rng.integers(0, 2**bits, size=vals.size).astype(np.int32)
    t = DT.bulk_build(cfg_s, vals, pays)
    kinds = rng.choice([1, 2], size=40).astype(np.int32)
    keys = rng.integers(1, KEY_HI, size=40).astype(np.int32)
    pay2 = rng.integers(0, 2**bits, size=40).astype(np.int32)
    t, _, _ = DT.update_batch(cfg_s, t, jnp.asarray(kinds), jnp.asarray(keys),
                              jnp.asarray(pay2))
    assert bool(np.asarray(t.mark).any()), "trace should leave tombstones"

    # inject buffer-resident keys into the ΔNode that owns their descent
    # (keys absent from build AND churn, so the buffer is the only owner)
    absent = np.setdiff1d(np.arange(1, KEY_HI),
                          np.concatenate([vals, keys]))
    bkeys = rng.choice(absent, 4, replace=False).astype(np.int32)
    buf, bcount = t.buf, t.bcount
    for i, k in enumerate(bkeys):
        dn, _, _ = DT._descend(cfg_s, t, cfg_s.qpack(jnp.int32(k)), t.root, 1)
        dn = int(dn)
        slot = int(np.argmax(np.asarray(buf[dn]) == 0))
        buf = buf.at[dn, slot].set((int(k) << bits) | (i + 1))
        bcount = bcount.at[dn].add(1)
    t = t._replace(buf=buf, bcount=bcount)

    q = np.concatenate([rng.integers(1, KEY_HI + 5, 40).astype(np.int32),
                        bkeys])
    f_s, p_s, h_s = DT.lookup_jit(cfg_s, t, jnp.asarray(q))
    f_l, p_l, h_l = DT.lookup_jit(cfg_l, t, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_l))
    np.testing.assert_array_equal(np.asarray(p_s), np.asarray(p_l))
    np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_l))
    # the buffered keys are live with their injected payloads — via both
    np.testing.assert_array_equal(np.asarray(f_l)[-4:], np.ones(4, bool))
    np.testing.assert_array_equal(np.asarray(p_l)[-4:],
                                  np.arange(1, 5, dtype=np.int32))
    sf_s, sc_s = DT.successor_jit(cfg_s, t, jnp.asarray(q))
    sf_l, sc_l = DT.successor_jit(cfg_l, t, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(sf_s), np.asarray(sf_l))
    np.testing.assert_array_equal(np.asarray(sc_s), np.asarray(sc_l))


def test_engine_parity_map_forced_compiled_fallback(monkeypatch):
    """REPRO_PALLAS_INTERPRET=0 with packed int64 rows exercises the
    *compiled* non-Pallas fallback (`kernels.ref.ref_veb_walk_rows`) —
    the production map-mode read path on TPU — and must stay bit-for-bit
    identical to the scalar engine."""
    if not jax.config.jax_enable_x64:
        pytest.skip("map mode packs int64 values; needs JAX_ENABLE_X64")
    from repro.core import deltatree as DT

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    bits = 5
    rng = np.random.default_rng(24)
    # unique cfg values so no earlier trace's interpret choice is reused
    cfg_s = DT.TreeConfig(height=4, max_dnodes=333, buf_cap=7,
                          payload_bits=bits)
    cfg_l = DT.TreeConfig(height=4, max_dnodes=333, buf_cap=7,
                          payload_bits=bits, engine="lockstep")
    vals = np.unique(rng.integers(1, KEY_HI, 80).astype(np.int32))
    pays = rng.integers(0, 2**bits, vals.size).astype(np.int32)
    t = DT.bulk_build(cfg_s, vals, pays)
    kinds = rng.choice([1, 2], size=30).astype(np.int32)
    keys = rng.integers(1, KEY_HI, size=30).astype(np.int32)
    t, _, _ = DT.update_batch(cfg_s, t, jnp.asarray(kinds), jnp.asarray(keys),
                              jnp.zeros(30, jnp.int32))
    q = jnp.asarray(rng.integers(1, KEY_HI + 5, 50).astype(np.int32))
    f_s, p_s, h_s = DT.lookup_jit(cfg_s, t, q)
    f_l, p_l, h_l = DT.lookup_jit(cfg_l, t, q)
    np.testing.assert_array_equal(np.asarray(f_s), np.asarray(f_l))
    np.testing.assert_array_equal(np.asarray(p_s), np.asarray(p_l))
    np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_l))
    sf_s, sc_s = DT.successor_jit(cfg_s, t, q)
    sf_l, sc_l = DT.successor_jit(cfg_l, t, q)
    np.testing.assert_array_equal(np.asarray(sf_s), np.asarray(sf_l))
    np.testing.assert_array_equal(np.asarray(sc_s), np.asarray(sc_l))


def test_engine_selection_validated():
    """Single-engine backends accept engine="scalar" and reject
    "lockstep"; unknown engine names are rejected everywhere."""
    for backend in BACKENDS:
        if backend in ENGINE_BACKENDS:
            continue
        ix = _mk(backend, np.asarray([5, 9], np.int32), engine="scalar")
        assert ix.engine == "scalar"
        with pytest.raises(ValueError, match="supports engines"):
            _mk(backend, np.asarray([5, 9], np.int32), engine="lockstep")
    with pytest.raises(ValueError, match="supports engines"):
        make_index("deltatree", engine="warp_drive")
    # engine typos inside a prebuilt cfg fail at construction, not at
    # the first read
    from repro.core.deltatree import TreeConfig

    with pytest.raises(ValueError, match="names engine"):
        make_index("deltatree", cfg=TreeConfig(height=4, max_dnodes=64,
                                               engine="locksetp"))


def test_late_registered_engine_selectable():
    """Engines registered after import become selectable by name on
    engine-aware backends (validation tracks the live registry)."""
    from repro.core import engine as E

    E.register_engine(E.SearchEngine(
        name="scalar_twin", lookup=E._scalar_lookup,
        successor=E._scalar_successor))
    try:
        assert "scalar_twin" in supported_engines("deltatree")
        assert "scalar_twin" not in supported_engines("sorted_array")
        ix = _mk("deltatree", np.asarray([5, 9], np.int32),
                 engine="scalar_twin")
        assert ix.engine == "scalar_twin"
        f, _ = ix.search(jnp.asarray([5, 6], jnp.int32))
        np.testing.assert_array_equal(np.asarray(f), [True, False])
    finally:
        E._ENGINES.pop("scalar_twin", None)


def test_forest_conformance_8dev_subprocess():
    """The same set trace passes with the forest backend fanned out over 8
    fake host devices (true shard_map dispatch, CI matrix leg)."""
    out = run_py("""
import numpy as np, jax.numpy as jnp
from repro.api import make_index, OpBatch
from repro.core.oracle import SetOracle

rng = np.random.default_rng(13)
initial = np.unique(rng.integers(1, 400, 120).astype(np.int32))
ix = make_index("forest", initial=initial, num_shards=8, height=4,
                max_dnodes=256, buf_cap=8, key_max=400)
oracle = SetOracle(initial)
for _ in range(5):
    kinds = rng.integers(0, 3, size=32).astype(np.int32)
    keys = rng.integers(1, 400, size=32).astype(np.int32)
    f, _ = ix.search(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(f), oracle.snapshot_search(keys))
    ix, res = ix.insert_delete(OpBatch.mixed(kinds, keys))
    np.testing.assert_array_equal(np.asarray(res), oracle.apply_updates(kinds, keys))
assert [k for k, _ in ix.live_items()] == sorted(oracle.s)
print("FOREST 8DEV OK")
""", devices=8)
    assert "FOREST 8DEV OK" in out
