"""Fused cross-shard lockstep frontier ≡ dense vmap dispatch (DESIGN.md §8).

The fused forest read path (one multi-root ``delta_walk`` frontier over the
base-offset fusion of co-resident shard arenas) must be *bit-identical* to
the dense per-shard vmap dispatch — found/payload/succ AND the per-query
hops transfer statistic — on randomized op traces for S ∈ {1, 4, 8},
including map-mode x64 and the real 8-fake-device shard_map leg
(subprocess tests), and under deferred maintenance (the I5' buffered-floor
fold restricted per lane to its owner shard).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import TreeConfig
from repro.core.oracle import SetOracle
from repro.distributed import forest as F
from tests._subproc import run_py

KEY_HI = 1000


def _cfgs(num_shards, maintenance="eager"):
    """(scalar/vmap, lockstep/vmap, lockstep/fused) forest configs over
    one shared arena layout — reads on the same Forest state compare the
    dispatch paths array-for-array."""

    def mk(engine, fused):
        return F.ForestConfig(
            num_shards=num_shards, key_max=KEY_HI, fused=fused,
            tree=TreeConfig(height=4, max_dnodes=256, buf_cap=8,
                            engine=engine, maintenance=maintenance))

    return mk("scalar", True), mk("lockstep", False), mk("lockstep", True)


def _assert_reads_agree(fc_ref, fc_fused, f, q):
    a = F.search_batch(fc_ref, f, q)
    b = F.search_batch(fc_fused, f, q)
    for x, y in zip(a, b):   # found AND hops, bit for bit
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    sa = F.successor_jit(fc_ref, f, q)
    sb = F.successor_jit(fc_fused, f, q)
    for x, y in zip(sa, sb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("num_shards", [1, 4, 8])
def test_fused_matches_vmap_dispatch(num_shards):
    """Randomized op trace: every read step compares the fused frontier
    against BOTH vmap dispatches (scalar + lockstep engines) and the
    oracle — found, hops, successor, all bit for bit."""
    fc_s, fc_v, fc_f = _cfgs(num_shards)
    rng = np.random.default_rng(31 + num_shards)
    initial = np.unique(rng.integers(1, KEY_HI, 200).astype(np.int32))
    f = F.bulk_build(fc_s, initial)
    oracle = SetOracle(initial)
    for _ in range(5):
        q = jnp.asarray(rng.integers(0, KEY_HI + 50, 64).astype(np.int32))
        _assert_reads_agree(fc_s, fc_f, f, q)
        _assert_reads_agree(fc_v, fc_f, f, q)
        found, _ = F.search_batch(fc_f, f, q)
        np.testing.assert_array_equal(
            np.asarray(found), oracle.snapshot_search(np.asarray(q)))
        kinds = rng.choice([1, 2], 32).astype(np.int32)
        keys = rng.integers(1, KEY_HI, 32).astype(np.int32)
        f, res, _ = F.update_batch(fc_s, f, jnp.asarray(kinds),
                                   jnp.asarray(keys))
        np.testing.assert_array_equal(np.asarray(res),
                                      oracle.apply_updates(kinds, keys))
    live = oracle.keys()
    q = np.asarray(rng.integers(0, KEY_HI + 100, 96).astype(np.int32))
    sf, sv = F.successor_jit(fc_f, f, jnp.asarray(q))
    idx = np.searchsorted(live, q, side="right")
    ef = idx < live.size
    np.testing.assert_array_equal(np.asarray(sf), ef)
    np.testing.assert_array_equal(
        np.asarray(sv)[ef], live[np.minimum(idx, live.size - 1)][ef])


def test_fused_deferred_maintenance_reads():
    """Under deferred maintenance (I5': pending items live in overflow
    buffers) the fused path folds each lane's *owner-shard* buffered
    floor — a later shard's pending item must arrive via the cross-shard
    fallback, never directly — and stays bit-identical to vmap."""
    fc_s, fc_v, fc_f = _cfgs(4, maintenance="deferred")
    rng = np.random.default_rng(37)
    vals = np.unique(rng.integers(1, KEY_HI, 250).astype(np.int32))
    f = F.bulk_build(fc_s, vals)
    for _ in range(4):
        kinds = rng.choice([1, 2], 48).astype(np.int32)
        keys = rng.integers(1, KEY_HI, 48).astype(np.int32)
        f, _, _ = F.update_batch(fc_s, f, jnp.asarray(kinds),
                                 jnp.asarray(keys))
    assert int(np.asarray(f.trees.bcount).sum()) > 0, \
        "trace should leave buffered items"
    q = jnp.asarray(rng.integers(0, KEY_HI + 50, 160).astype(np.int32))
    _assert_reads_agree(fc_v, fc_f, f, q)
    _assert_reads_agree(fc_s, fc_f, f, q)
    # buffered items are live through the fused read path
    live = F.live_keys(fc_s, f)
    idx = np.searchsorted(live, np.asarray(q), side="right")
    ef = idx < live.size
    sf, sv = F.successor_jit(fc_f, f, q)
    np.testing.assert_array_equal(np.asarray(sf), ef)
    np.testing.assert_array_equal(
        np.asarray(sv)[ef], live[np.minimum(idx, live.size - 1)][ef])


def test_fused_capability_and_dispatch_selection():
    """Capability.fused_forest reflects engine × fused flag; the scalar
    engine (no forest_batch) always reads through the vmap dispatch."""
    from repro.api import make_index

    initial = np.asarray([5, 9, 40], np.int32)
    kw = dict(initial=initial, num_shards=2, height=4, max_dnodes=64,
              buf_cap=8, key_max=64)
    assert make_index("forest", engine="lockstep",
                      **kw).capability.fused_forest
    assert not make_index("forest", engine="lockstep", fused=False,
                          **kw).capability.fused_forest
    assert not make_index("forest", engine="scalar",
                          **kw).capability.fused_forest
    assert not make_index("deltatree", engine="lockstep", initial=initial,
                          height=4, max_dnodes=64).capability.fused_forest


def test_fused_map_mode_x64():
    out = run_py("""
import numpy as np, jax.numpy as jnp
from repro.core import TreeConfig
from repro.core.oracle import MapOracle
from repro.distributed import forest as F

def mk(engine, fused):
    return F.ForestConfig(num_shards=4, key_max=600, fused=fused,
                          tree=TreeConfig(height=4, max_dnodes=256, buf_cap=8,
                                          payload_bits=8, engine=engine))
fc_s, fc_v, fc_f = mk("scalar", True), mk("lockstep", False), mk("lockstep", True)
rng = np.random.default_rng(41)
vals = np.unique(rng.integers(1, 600, 200).astype(np.int32))
pays = rng.integers(0, 255, vals.size).astype(np.int32)
f = F.bulk_build(fc_s, vals, pays)
oracle = MapOracle(zip(vals, pays))
for _ in range(4):
    kinds = rng.integers(1, 3, 24).astype(np.int32)
    keys = rng.integers(1, 600, 24).astype(np.int32)
    pp = rng.integers(0, 255, 24).astype(np.int32)
    q = jnp.asarray(rng.integers(0, 650, 64).astype(np.int32))
    ref = F.lookup_batch(fc_v, f, q)
    fus = F.lookup_batch(fc_f, f, q)
    for a, b in zip(ref, fus):   # found, payload, hops
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ef, ep = oracle.snapshot_lookup(np.asarray(q))
    np.testing.assert_array_equal(np.asarray(fus[0]), ef)
    np.testing.assert_array_equal(np.asarray(fus[1])[ef], ep[ef])
    sa = F.successor_jit(fc_v, f, q)
    sb = F.successor_jit(fc_f, f, q)
    for a, b in zip(sa, sb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    f, _, _ = F.update_batch(fc_s, f, jnp.asarray(kinds), jnp.asarray(keys),
                             jnp.asarray(pp))
    oracle.apply_updates(kinds, keys, pp)
print("FUSED MAP MODE OK")
""", x64=True)
    assert "FUSED MAP MODE OK" in out


def test_fused_shard_map_8_devices():
    """The fused frontier under a real multi-device mesh: the batch
    bucket-sorts by owner *device* ((D, K) lanes, not (S, K)) and each
    device fuses its co-resident shards — S=4 exercises 1 shard/device on
    a 4-mesh, S=8 a full 8-mesh; both must match vmap and the oracle."""
    out = run_py("""
import jax, numpy as np, jax.numpy as jnp
assert jax.device_count() == 8, jax.devices()
from repro.core import TreeConfig
from repro.core.oracle import SetOracle
from repro.distributed import forest as F
from repro.distributed.router import forest_mesh

rng = np.random.default_rng(43)
for S in (4, 8):
    assert forest_mesh(S).devices.size == S
    def mk(engine, fused):
        return F.ForestConfig(num_shards=S, key_max=800, fused=fused,
                              tree=TreeConfig(height=4, max_dnodes=128,
                                              buf_cap=8, engine=engine))
    fc_s, fc_v, fc_f = mk("scalar", True), mk("lockstep", False), mk("lockstep", True)
    vals = np.unique(rng.integers(1, 800, 300).astype(np.int32))
    f = F.bulk_build(fc_s, vals)
    oracle = SetOracle(vals)
    for _ in range(3):
        kinds = rng.integers(1, 3, 32).astype(np.int32)
        keys = rng.integers(1, 800, 32).astype(np.int32)
        q = jnp.asarray(rng.integers(0, 850, 96).astype(np.int32))
        for ref in (fc_s, fc_v):
            a = F.search_batch(ref, f, q); b = F.search_batch(fc_f, f, q)
            for x, y in zip(a, b):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            sa = F.successor_jit(ref, f, q); sb = F.successor_jit(fc_f, f, q)
            for x, y in zip(sa, sb):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        found, _ = F.search_batch(fc_f, f, q)
        assert (np.asarray(found) == oracle.snapshot_search(np.asarray(q))).all()
        f, res, _ = F.update_batch(fc_s, f, jnp.asarray(kinds), jnp.asarray(keys))
        assert (np.asarray(res) == oracle.apply_updates(kinds, keys)).all()
print("FUSED SHARD_MAP OK")
""", devices=8)
    assert "FUSED SHARD_MAP OK" in out
