import os
import sys

# Tests run on the default single CPU device (the dry-run's 512-device flag
# must NOT leak here). Subprocess-based tests set their own XLA_FLAGS.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
