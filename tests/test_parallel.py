"""Multi-device correctness (8 host devices via subprocess): sharded train
step == single-device, split-K decode attention == dense, compressed
cross-pod mean, and elastic resharding restore."""

from tests._subproc import run_py


def test_sharded_train_step_matches_single_device():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models.registry import api
from repro.optim import AdamWConfig, adamw_init
from repro.parallel import shardings as SH
from repro.parallel.ax import logical_rules
from repro.train import make_train_step
from repro.launch.mesh import make_host_mesh

cfg = get_smoke_config("granite_8b")
m = api(cfg)
ocfg = AdamWConfig(lr=1e-3, state_dtype="float32")
step = make_train_step(cfg, ocfg)
params = m.init_params(jax.random.PRNGKey(0))
opt = adamw_init(ocfg, params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}

# single device
p1, o1, m1 = jax.jit(step)(params, opt, batch)

# 4x2 mesh, sharded
mesh = make_host_mesh(4, 2)
pspecs = SH.param_specs(params)
psh = SH.to_named(pspecs, mesh)
osh = SH.to_named(SH.opt_specs(pspecs), mesh)
with mesh, logical_rules(mesh):
    params2 = jax.device_put(params, psh)
    opt2 = jax.device_put(opt, osh)
    from jax.sharding import NamedSharding
    bsh = NamedSharding(mesh, SH.batch_spec(mesh, 8, 2))
    batch2 = {k: jax.device_put(v, bsh) for k, v in batch.items()}
    p2, o2, m2 = jax.jit(step, in_shardings=(psh, osh, None),
                         out_shardings=(psh, osh, None))(params2, opt2, batch2)

assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1["loss"], m2["loss"])
d = max(float(jnp.abs(a - b).max()) for a, b in
        zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 5e-3, d
print("SHARDED OK", float(m1["loss"]), d)
""", devices=8)
    assert "SHARDED OK" in out


def test_split_k_decode_attention():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.models.layers.attention import decode_attention
from repro.parallel.decode_attn import split_k_decode_attention

mesh = make_host_mesh(1, 8)
rng = np.random.default_rng(0)
B, H, KVH, D, S = 4, 8, 2, 32, 64
q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, S, KVH, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, S, KVH, D)), jnp.float32)
ln = jnp.asarray([5, 17, 64, 33], jnp.int32)
ref = decode_attention(q, k, v, ln)
with mesh:
    got = split_k_decode_attention(mesh, q, k, v, ln)
err = float(jnp.abs(ref - got).max())
assert err < 1e-5, err
print("SPLITK OK", err)
""", devices=8)
    assert "SPLITK OK" in out


def test_compressed_pmean():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.optim.compress import compressed_pmean

mesh = make_host_mesh(8, 1)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
with mesh:
    got = shard_map(lambda t: compressed_pmean(t, "data"), mesh=mesh,
                    in_specs=P("data"), out_specs=P("data"))(x)
exp = jnp.broadcast_to(x.mean(0, keepdims=True), x.shape)
err = float(jnp.abs(got - exp).max())
assert err < 0.05, err   # int8 grid error
print("PMEAN OK", err)
""", devices=8)
    assert "PMEAN OK" in out


def test_elastic_resharding_restore(tmp_path):
    """Save on a 4x2 mesh, restore onto 2x1 — the lose-a-pod path."""
    out = run_py(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.models.registry import api
from repro.parallel import shardings as SH
from repro.launch.mesh import make_host_mesh

cfg = get_smoke_config("granite_8b")
m = api(cfg)
params = m.init_params(jax.random.PRNGKey(0))
mesh_a = make_host_mesh(4, 2)
psh_a = SH.to_named(SH.param_specs(params), mesh_a)
pa = jax.device_put(params, psh_a)
ck = CheckpointManager(r'{tmp_path}', async_save=False)
ck.save(1, pa)

mesh_b = make_host_mesh(2, 1)
psh_b = SH.to_named(SH.param_specs(params), mesh_b)
step, pb, _ = ck.restore(None, params, shardings=psh_b)
d = max(float(np.abs(np.asarray(a) - np.asarray(b)).max()) for a, b in
        zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
assert d == 0.0, d
# restored arrays live on the 2-device mesh
sh = jax.tree.leaves(pb)[0].sharding
assert len(sh.device_set) <= 2, sh
print("RESHARD OK")
""", devices=8)
    assert "RESHARD OK" in out
