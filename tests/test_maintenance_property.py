"""Hypothesis property tests for the maintenance scheduler: arbitrary op
sequences under every policy × engine == oracle (interleaved searches and
successors stay correct over keys pending in overflow buffers), and flush
restores invariant I5."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import deltatree as DT
from repro.core.oracle import SetOracle
from tests.test_deltatree import check_invariants
from tests.test_maintenance import POLICIES

op_batches = st.lists(
    st.lists(
        st.tuples(st.integers(1, 2), st.integers(1, 40)),
        min_size=1, max_size=12,
    ),
    min_size=1, max_size=5,
)


@settings(max_examples=25, deadline=None)
@given(batches=op_batches,
       policy=st.sampled_from(POLICIES),
       engine=st.sampled_from(["scalar", "lockstep"]))
def test_property_policies_match_oracle(batches, policy, engine):
    """For every policy × engine, interleaved update + search + successor
    agree with the oracle (searches include keys pending in buffers under
    deferred/budgeted), and flush restores I5."""
    cfg = DT.TreeConfig(height=3, max_dnodes=256, buf_cap=4,
                        maintenance=policy, engine=engine)
    t = DT.empty(cfg)
    oracle = SetOracle()
    for batch in batches:
        kinds = np.asarray([k for k, _ in batch], np.int32)
        keys = np.asarray([v for _, v in batch], np.int32)
        found, _ = DT.search_jit(cfg, t, jnp.asarray(keys))
        assert (np.asarray(found) == oracle.snapshot_search(keys)).all()
        fs, sc = DT.successor_jit(cfg, t, jnp.asarray(keys))
        live = oracle.keys()
        idx = np.searchsorted(live, keys, side="right")
        ef = idx < live.size
        assert (np.asarray(fs) == ef).all()
        if live.size:
            assert (np.asarray(sc)[ef] == live[idx[ef]]).all()
        t, res, stats = DT.update_batch(cfg, t, jnp.asarray(kinds),
                                        jnp.asarray(keys))
        assert (np.asarray(res) == oracle.apply_updates(kinds, keys)).all()
        assert not bool(t.alloc_fail)
        assert (DT.live_keys(cfg, t) == oracle.keys()).all()
    check_invariants(cfg, t, require_empty_buffers=(policy == "eager"))
    t, fstats = DT.flush(cfg, t)
    assert int(fstats.pending) == 0
    assert (DT.live_keys(cfg, t) == oracle.keys()).all()
    check_invariants(cfg, t)
