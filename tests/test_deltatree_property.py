"""Hypothesis property tests: arbitrary op sequences == oracle (paper's
dictionary semantics), for both set and map modes and several UB sizes."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import TreeConfig, empty, live_keys, search_jit, update_batch
from repro.core.oracle import SetOracle
from tests.test_deltatree import check_invariants

op_batches = st.lists(
    st.lists(
        st.tuples(st.integers(1, 2), st.integers(1, 40)),
        min_size=1, max_size=12,
    ),
    min_size=1, max_size=6,
)


@settings(max_examples=30, deadline=None)
@given(batches=op_batches, height=st.sampled_from([3, 4, 5]))
def test_op_sequences_match_oracle(batches, height):
    cfg = TreeConfig(height=height, max_dnodes=512, buf_cap=8)
    t = empty(cfg)
    oracle = SetOracle()
    for batch in batches:
        kinds = np.asarray([k for k, _ in batch], np.int32)
        keys = np.asarray([v for _, v in batch], np.int32)
        found, _ = search_jit(cfg, t, jnp.asarray(keys))
        assert (np.asarray(found) == oracle.snapshot_search(keys)).all()
        t, res, _ = update_batch(cfg, t, jnp.asarray(kinds), jnp.asarray(keys))
        exp = oracle.apply_updates(kinds, keys)
        assert (np.asarray(res) == exp).all()
        assert not bool(t.alloc_fail)
    assert (live_keys(cfg, t) == oracle.keys()).all()
    check_invariants(cfg, t)


@settings(max_examples=15, deadline=None)
@given(
    keys=st.lists(st.integers(1, 10_000), min_size=1, max_size=60,
                  unique=True),
    height=st.sampled_from([3, 5, 7]),
)
def test_insert_all_then_find_all(keys, height):
    cfg = TreeConfig(height=height, max_dnodes=1024, buf_cap=8)
    t = empty(cfg)
    arr = np.asarray(keys, np.int32)
    for chunk in np.array_split(arr, max(1, len(arr) // 8)):
        kinds = np.ones(chunk.size, np.int32)
        t, res, _ = update_batch(cfg, t, jnp.asarray(kinds), jnp.asarray(chunk))
        assert bool(np.asarray(res).all())
    f, _ = search_jit(cfg, t, jnp.asarray(arr))
    assert bool(np.asarray(f).all())
    assert (np.sort(live_keys(cfg, t)) == np.sort(arr)).all()
    check_invariants(cfg, t)
