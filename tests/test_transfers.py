"""Measured memory-transfer accounting (DESIGN.md §14).

The loop this module closes: the analytical ideal-cache model
(`core.baselines.count_block_transfers` over the host replay in
`core.transfers`) and the *measured* device-side `TransferStats` replay
(`obs.transfers`) must agree **exactly** on a quiescent tree — same
distinct-block counts per search for every block size — and the
measured statistic must be bit-identical across engines (scalar /
lockstep) and dispatches (fused / vmap forest), because it is derived
in the dispatch layer from the same gather indices every engine pins.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deltatree as DT
from repro.core import layout
from repro.core.deltatree import TreeConfig
from repro.distributed import forest as D
from repro.distributed.forest import ForestConfig
from repro.obs import transfers as OTR
from repro.obs.stats import ReadStats, TransferStats
from repro.obs.transfers import TRANSFER_BLOCK_SIZES

from _subproc import run_py

KEYS = np.arange(10, 400, 7, dtype=np.int64)
CFG = TreeConfig(height=4, max_dnodes=256, buf_cap=8,
                 collect_stats=True, collect_transfers=True)


def _queries():
    """Hits, misses, and born-resolved ROUTE_LEFT sentinel lanes."""
    return jnp.asarray(
        list(KEYS[:6]) + [5, 11, 401, layout.ROUTE_LEFT, layout.ROUTE_LEFT],
        jnp.int32)


# ------------------------------------------------- measured == model ---


@pytest.mark.parametrize("height,n", [(4, 300), (5, 900), (7, 2500)])
def test_measured_equals_model_exactly(height, n):
    """On a quiescent (bulk-built) tree the measured distinct-block
    transfers per search equal `count_block_transfers` exactly — ratio
    1.0, not approximately — for every supported block size."""
    rng = np.random.default_rng(height)
    keys = np.unique(rng.integers(1, 50_000, size=n).astype(np.int64))
    cfg = TreeConfig(height=height, max_dnodes=4096, buf_cap=8,
                     collect_stats=True, collect_transfers=True)
    t = DT.bulk_build(cfg, keys)
    q = rng.integers(1, 50_000, size=256).astype(np.int64)  # hits + misses
    cm = OTR.compare_model(cfg, t, jnp.asarray(q, jnp.int32))
    for b in TRANSFER_BLOCK_SIZES:
        assert cm[b]["measured"] == pytest.approx(cm[b]["model"], abs=0), \
            (b, cm[b])
        assert cm[b]["ratio"] == 1.0


def test_transfer_stats_field_consistency():
    t = DT.bulk_build(CFG, KEYS)
    q = _queries()
    ts = OTR.measure(CFG, t, q)
    assert isinstance(ts, TransferStats)
    k = int(q.shape[0])
    assert int(ts.queries) == k and int(ts.batches) == 1
    assert int(ts.pad_lanes) == 2            # the two ROUTE_LEFT lanes
    assert int(ts.buffer_probes) == k - 2    # one probe per real query
    # every real query terminates in exactly one leaf touch
    assert int(ts.leaf_touches) == k - 2
    assert int(ts.router_touches) > 0
    assert int(ts.dnode_visits) >= k - 2     # >= one ΔNode per real query
    # block totals are monotone in block size (coarser blocks, fewer)
    blocks = np.asarray(ts.blocks)
    assert blocks.shape == (len(TRANSFER_BLOCK_SIZES),)
    assert all(blocks[i] >= blocks[i + 1] for i in range(blocks.size - 1))
    d = ts.asdict()
    for b in TRANSFER_BLOCK_SIZES:
        assert d[f"blocks_b{b}"] == int(blocks[TRANSFER_BLOCK_SIZES.index(b)])
        assert d[f"blocks_b{b}_mean"] > 0


def test_pad_lanes_contribute_zero():
    """A batch of only ROUTE_LEFT sentinels touches nothing."""
    t = DT.bulk_build(CFG, KEYS)
    q = jnp.full(8, layout.ROUTE_LEFT, jnp.int32)
    ts = OTR.measure(CFG, t, q)
    assert int(ts.pad_lanes) == 8 and int(ts.buffer_probes) == 0
    assert int(ts.dnode_visits) == 0
    assert int(ts.router_touches) == 0 and int(ts.leaf_touches) == 0
    assert np.asarray(ts.blocks).sum() == 0


def test_transfer_stats_merge_reduce():
    t = DT.bulk_build(CFG, KEYS)
    a = OTR.measure(CFG, t, _queries())
    m = jax.jit(lambda x: x.merge(x))(a)
    assert int(m.queries) == 2 * int(a.queries)
    assert int(m.batches) == 2
    assert np.array_equal(np.asarray(m.blocks), 2 * np.asarray(a.blocks))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), a, a)
    r = TransferStats.reduce(stacked)
    for la, lm in zip(jax.tree.leaves(r), jax.tree.leaves(m)):
        assert np.array_equal(np.asarray(la), np.asarray(lm))


# ----------------------------------------------- engine / dispatch parity ---


def test_transfer_stats_engine_parity():
    """scalar and lockstep reads return bit-identical TransferStats —
    the stat is derived in the dispatch layer, not per engine."""
    q = _queries()
    outs = {}
    for engine in ("scalar", "lockstep"):
        cfg = dataclasses.replace(CFG, engine=engine)
        t = DT.bulk_build(cfg, KEYS)
        outs[engine] = DT.search_jit(cfg, t, q)[2]
    sa, sl = outs["scalar"], outs["lockstep"]
    assert isinstance(sa, ReadStats)
    assert sa.transfers is not None and sl.transfers is not None
    for a, b in zip(jax.tree.leaves(sa.transfers),
                    jax.tree.leaves(sl.transfers)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("engine", ["scalar", "lockstep"])
def test_transfer_stats_forest_dispatch_parity(engine):
    """fused and vmap forest dispatches produce bit-identical
    TransferStats (replay runs in shard-local address space on the
    stacked arenas, fed the same shard ids by both paths)."""
    q = _queries()
    outs = []
    for fused in (True, False):
        fcfg = ForestConfig(num_shards=4,
                            tree=dataclasses.replace(CFG, engine=engine),
                            fused=fused)
        f = D.bulk_build(fcfg, KEYS)
        outs.append(D.search_batch(fcfg, f, q)[2])
    sa, sb = outs
    assert sa.transfers is not None and sb.transfers is not None
    assert int(sa.transfers.pad_lanes) == 2
    assert int(sa.transfers.buffer_probes) == int(q.shape[0]) - 2
    for a, b in zip(jax.tree.leaves(sa.transfers),
                    jax.tree.leaves(sb.transfers)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------- model fit ---


def test_fit_log_b_r2():
    """Height sweep of measured transfers fits c*log_B(N) + d with
    R^2 >= 0.98 — the paper's O(log_B N) transfer bound, observed."""
    fit = OTR.fit_log_b()
    assert fit["r2"] >= 0.98, fit
    assert fit["c"] > 0
    assert len(fit["points"]) == 11
    # measured mean transfers grow monotonically with N overall
    first, last = fit["points"][0][1], fit["points"][-1][1]
    assert last > first


# ------------------------------------------------------- static gate ---


def test_collect_transfers_gate_hlo():
    """collect_transfers is a sub-gate of collect_stats: with
    collect_stats=False it changes nothing (byte-identical HLO to the
    bare composition), and with collect_stats=True it adds the replay
    (different HLO from stats-only)."""
    import re

    from repro.core import engine as E

    base = TreeConfig(height=4, max_dnodes=64, buf_cap=8)
    t = DT.bulk_build(base, KEYS[:20])
    q = jnp.asarray(KEYS[:8], jnp.int32)

    def norm(txt):
        return re.sub(r"jit_\w+", "jit_fn", txt)

    def lower(cfg):
        return norm(jax.jit(lambda t, q: E.search(cfg, t, q))
                    .lower(t, q).as_text())

    def bare(t, q):
        found, _, hops = E.get_engine(base.engine).lookup(base, t, q)
        return found, hops

    lo_b = norm(jax.jit(bare).lower(t, q).as_text())
    off = dataclasses.replace(base, collect_transfers=True)
    assert lower(off) == lo_b        # dead sub-gate: still the bare graph
    stats_only = dataclasses.replace(base, collect_stats=True)
    both = dataclasses.replace(stats_only, collect_transfers=True)
    assert lower(both) != lower(stats_only)   # replay actually lowers


def test_compiled_fused_hlo_identity_subprocess():
    """Compiled-mode leg (REPRO_PALLAS_INTERPRET=0): around the fused
    single-launch walk, collect_stats=False still lowers byte-identical
    HLO to the bare engine-hook composition."""
    out = run_py("""
import os
os.environ["REPRO_PALLAS_INTERPRET"] = "0"
os.environ.pop("REPRO_TRACE", None)   # spans would rename scopes
import re
import numpy as np, jax, jax.numpy as jnp
from repro.core import deltatree as DT
from repro.core import engine as E
from repro.core.deltatree import TreeConfig
from repro.kernels.ops import default_interpret
assert default_interpret() is False

cfg = TreeConfig(height=4, max_dnodes=64, buf_cap=8, engine="lockstep")
keys = np.arange(10, 150, 7, dtype=np.int64)
t = DT.bulk_build(cfg, keys)
q = jnp.asarray(keys[:8], jnp.int32)

def dispatched(t, q):
    return E.search(cfg, t, q)

def bare(t, q):
    found, _, hops = E.get_engine(cfg.engine).lookup(cfg, t, q)
    return found, hops

def norm(txt):
    return re.sub(r"jit_\\w+", "jit_fn", txt)

lo_d = norm(jax.jit(dispatched).lower(t, q).as_text())
lo_b = norm(jax.jit(bare).lower(t, q).as_text())
assert lo_d == lo_b, "stats-off dispatch is not free around the fused walk"

import dataclasses
on = dataclasses.replace(cfg, collect_stats=True, collect_transfers=True)
lo_on = norm(jax.jit(lambda t, q: E.search(on, t, q)).lower(t, q).as_text())
assert lo_on != lo_b
print("FUSED_HLO_IDENTITY_OK")
""")
    assert "FUSED_HLO_IDENTITY_OK" in out


# ------------------------------------------------------------ plumbing ---


def test_index_handle_collect_transfers():
    from repro.api import make_index

    ix = make_index("deltatree", initial=KEYS, height=4, max_dnodes=256,
                    buf_cap=8, collect_stats=True, collect_transfers=True)
    found, hops, stats = ix.search(_queries())
    ts = stats.transfers
    assert ts is not None and int(ts.pad_lanes) == 2
    # stats-only index: transfers leg absent, search stats still there
    ix2 = make_index("deltatree", initial=KEYS, height=4, max_dnodes=256,
                     buf_cap=8, collect_stats=True)
    _, _, st2 = ix2.search(_queries())
    assert st2.transfers is None and int(st2.search.queries) == 11
