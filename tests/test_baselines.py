"""Baseline search structures (paper §5 comparison set)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.baselines import (
    HashTable, PointerBST, SortedArray, StaticVEB, count_block_transfers,
    OP_INSERT, OP_DELETE,
)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(2)
    vals = np.unique(rng.integers(1, 50_000, size=4000).astype(np.int32))
    q = rng.integers(1, 50_000, size=1000).astype(np.int32)
    return rng, vals, q


@pytest.mark.parametrize("B", [SortedArray, StaticVEB, PointerBST, HashTable])
def test_search_membership(B, data):
    rng, vals, q = data
    st = B.build(vals)
    got = np.asarray(B.search(st, jnp.asarray(q)))
    np.testing.assert_array_equal(got, np.isin(q, vals))


@pytest.mark.parametrize("B", [SortedArray, PointerBST])
def test_updates(B, data):
    rng, vals, q = data
    st = B.build(vals)
    s = set(vals.tolist())
    kinds = rng.choice([OP_INSERT, OP_DELETE], size=64).astype(np.int32)
    keys = rng.integers(1, 50_000, size=64).astype(np.int32)
    st, res = B.update(st, jnp.asarray(kinds), jnp.asarray(keys))
    exp = np.zeros(64, bool)
    for i, (k, v) in enumerate(zip(kinds, keys)):
        v = int(v)
        if k == OP_INSERT:
            exp[i] = v not in s
            s.add(v)
        else:
            exp[i] = v in s
            s.discard(v)
    np.testing.assert_array_equal(np.asarray(res), exp)
    got = np.asarray(B.search(st, jnp.asarray(q)))
    np.testing.assert_array_equal(got, np.isin(q, np.asarray(sorted(s))))


def test_transfer_ordering(data):
    """Paper's Table 1 story: pointer-chasing touches the most blocks; the
    vEB layouts the fewest."""
    rng, vals, q = data
    B = 64
    res = {}
    for Bl in (SortedArray, StaticVEB, PointerBST):
        st = Bl.build(vals)
        res[Bl.name] = count_block_transfers(Bl.touch_fn(st), q[:200], B)
    assert res["static_veb"] < res["sorted_array"] < res["pointer_bst"], res
