"""ΔTree behaviour vs the set/map oracle + structural invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    TreeConfig, bulk_build, empty, live_keys, search_jit, update_batch,
)
from repro.core import layout
from repro.core.oracle import SetOracle, OP_INSERT, OP_DELETE


def check_invariants(cfg: TreeConfig, t, require_empty_buffers=True) -> None:
    """Structural invariants I1-I5 from the module docstring.

    ``require_empty_buffers=False`` checks the policy-conditional variant:
    non-eager maintenance policies relax I5 to I5' (buffered values' root
    descents land in their holding ΔNode — asserted by the maintenance
    suite via searches), so only I1-I4 plus buffer bookkeeping hold here.
    """
    pos = np.asarray(layout.veb_pos_table(cfg.height))
    value = np.asarray(t.value)
    child = np.asarray(t.child)
    buf = np.asarray(t.buf)
    alive = np.asarray(t.alive)
    nlive = np.asarray(t.nlive)
    mark = np.asarray(t.mark)
    parent = np.asarray(t.parent)
    pslot = np.asarray(t.pslot)
    bottom0 = cfg.bottom0
    rl = int(np.asarray(cfg.route_left))

    if require_empty_buffers:
        assert int(np.asarray(t.bcount).sum()) == 0, "I5: buffers drained"
        assert (buf == layout.EMPTY).all(), "I5"
    else:  # bcount bookkeeping still exact per ΔNode
        assert (np.asarray(t.bcount)
                == (buf != layout.EMPTY).sum(axis=1)).all(), "bcount"

    for dn in range(cfg.max_dnodes):
        if not alive[dn]:
            assert (value[dn] == layout.EMPTY).all()
            continue
        count_live = 0
        for b in range(1, 2**cfg.height):
            v = value[dn, pos[b]]
            if b % 2 == 1 and b > 1 and v != layout.EMPTY:
                assert value[dn, pos[b - 1]] != layout.EMPTY, (
                    "I2", dn, b)  # odd occupied => even sibling occupied
            if b >= bottom0 and child[dn, b - bottom0] >= 0:
                assert v != layout.EMPTY, ("I3", dn, b)
                cid = child[dn, b - bottom0]
                assert alive[cid] and parent[cid] == dn and \
                    pslot[cid] == b - bottom0, ("child link", dn, b)
            at_bottom = b >= bottom0
            left = layout.EMPTY if at_bottom else value[dn, pos[2 * b]]
            is_leaf = at_bottom or left == layout.EMPTY
            if is_leaf and v not in (layout.EMPTY, rl) and not mark[dn, pos[b]]:
                if not (at_bottom and child[dn, b - bottom0] >= 0):
                    count_live += 1
        assert count_live == nlive[dn], ("nlive", dn, count_live, nlive[dn])

    # Walk-cap safety: the fused walk kernel caps its in-kernel loop at
    # cfg.walk_round_cap rounds (one ΔNode hop per round), so the deepest
    # alive ΔNode must sit strictly under the cap — otherwise the kernel
    # would truncate a descent and return a wrong leaf silently.
    depth: dict[int, int] = {}

    def _depth(dn: int) -> int:
        if dn not in depth:
            p = int(parent[dn])
            depth[dn] = 1 if p < 0 else _depth(p) + 1
        return depth[dn]

    max_depth = max((_depth(dn) for dn in range(cfg.max_dnodes)
                     if alive[dn]), default=0)
    cap = cfg.walk_round_cap
    assert max_depth < cap, ("walk cap", max_depth, cap)


@pytest.mark.parametrize("height,nsteps", [(3, 15), (4, 20), (7, 12)])
def test_random_ops_vs_oracle(height, nsteps):
    cfg = TreeConfig(height=height, max_dnodes=4096, buf_cap=16)
    rng = np.random.default_rng(height)
    t = empty(cfg)
    oracle = SetOracle()
    for step in range(nsteps):
        K = 24
        kinds = rng.integers(1, 3, size=K).astype(np.int32)
        keys = rng.integers(1, 150, size=K).astype(np.int32)
        found, _ = search_jit(cfg, t, jnp.asarray(keys))
        assert (np.asarray(found) == oracle.snapshot_search(keys)).all()
        t, res, stats = update_batch(cfg, t, jnp.asarray(kinds),
                                     jnp.asarray(keys))
        exp = oracle.apply_updates(kinds, keys)
        assert (np.asarray(res) == exp).all(), step
        assert not bool(t.alloc_fail)
        assert int(stats.rounds) < cfg.max_rounds
        assert int(stats.pending) == 0  # I5 under the eager default
        assert (live_keys(cfg, t) == oracle.keys()).all()
    check_invariants(cfg, t)


def test_merge_reclaims_dnodes():
    cfg = TreeConfig(height=5, max_dnodes=2048, buf_cap=32)
    rng = np.random.default_rng(0)
    vals = np.unique(rng.integers(1, 50_000, size=3000).astype(np.int32))
    t = bulk_build(cfg, vals)
    n0 = int(np.asarray(t.alive).sum())
    oracle = SetOracle(vals)
    todel = rng.permutation(vals)[: int(0.9 * vals.size)]
    for chunk in np.array_split(todel, 20):
        kinds = np.full(chunk.size, OP_DELETE, np.int32)
        t, res, _ = update_batch(cfg, t, jnp.asarray(kinds), jnp.asarray(chunk))
        assert bool(np.asarray(res).all())
        oracle.apply_updates(kinds, chunk)
    n1 = int(np.asarray(t.alive).sum())
    # Merge is sibling-local (paper Fig. 10): it reclaims leaf-level ΔNodes
    # but never collapses interior ones, so expect substantial-not-total
    # reclamation after deleting 90% of keys.
    assert n1 <= 0.6 * n0, (n0, n1)
    assert (live_keys(cfg, t) == oracle.keys()).all()
    check_invariants(cfg, t)


def test_bulk_build_and_search():
    cfg = TreeConfig(height=7, max_dnodes=1 << 12, buf_cap=16)
    rng = np.random.default_rng(1)
    vals = np.unique(rng.integers(1, 1_000_000, size=40_000).astype(np.int32))
    t = bulk_build(cfg, vals)
    q = rng.integers(1, 1_000_000, size=2000).astype(np.int32)
    f, hops = search_jit(cfg, t, jnp.asarray(q))
    assert (np.asarray(f) == np.isin(q, vals)).all()
    # O(log_B N): a 40k-key tree with UB=127 must resolve in <= 4 hops
    assert int(np.asarray(hops).max()) <= 4
    check_invariants(cfg, t)


def test_delete_then_reinsert_revives():
    cfg = TreeConfig(height=4, max_dnodes=128, buf_cap=8)
    t = empty(cfg)
    ins = lambda t, k: update_batch(
        cfg, t, jnp.asarray([OP_INSERT], np.int32), jnp.asarray([k], np.int32))
    dele = lambda t, k: update_batch(
        cfg, t, jnp.asarray([OP_DELETE], np.int32), jnp.asarray([k], np.int32))
    t, r, _ = ins(t, 42); assert bool(r[0])
    t, r, _ = ins(t, 42); assert not bool(r[0])   # duplicate
    t, r, _ = dele(t, 42); assert bool(r[0])
    t, r, _ = dele(t, 42); assert not bool(r[0])  # already deleted
    t, r, _ = ins(t, 42); assert bool(r[0])       # revive
    f, _ = search_jit(cfg, t, jnp.asarray([42], np.int32))
    assert bool(f[0])


def test_successor_queries():
    """Ordered-dictionary extension: successor == sorted-array successor,
    including around tombstones and after maintenance churn."""
    import numpy as np
    from repro.core.deltatree import successor_jit

    cfg = TreeConfig(height=5, max_dnodes=4096, buf_cap=16)
    rng = np.random.default_rng(9)
    vals = np.unique(rng.integers(1, 100_000, size=3000).astype(np.int32))
    t = bulk_build(cfg, vals)
    oracle = SetOracle(vals)
    # churn: deletes create tombstone routers; inserts grow leaves
    for _ in range(6):
        kinds = rng.choice([OP_INSERT, OP_DELETE], size=48).astype(np.int32)
        keys = np.concatenate([
            rng.choice(vals, size=24),
            rng.integers(1, 100_000, size=24),
        ]).astype(np.int32)[:48]
        t, _, _ = update_batch(cfg, t, jnp.asarray(kinds), jnp.asarray(keys))
        oracle.apply_updates(kinds, keys)
    live = oracle.keys()
    q = rng.integers(0, 100_001, size=400).astype(np.int32)
    found, succ = successor_jit(cfg, t, jnp.asarray(q))
    idx = np.searchsorted(live, q, side="right")
    exp_found = idx < live.size
    exp_succ = np.where(exp_found, live[np.minimum(idx, live.size - 1)], 0)
    np.testing.assert_array_equal(np.asarray(found), exp_found)
    np.testing.assert_array_equal(
        np.asarray(succ)[exp_found], exp_succ[exp_found])
