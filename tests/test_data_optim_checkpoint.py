"""Data pipeline determinism, optimizer behaviour, checkpoint roundtrips."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step
from repro.data import DataConfig, Pipeline, batch_at_step
from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, cosine_lr, dequantize_int8,
    quantize_int8,
)


def test_data_deterministic_by_step():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=7)
    b1 = batch_at_step(cfg, 5)
    b2 = batch_at_step(cfg, 5)
    assert (b1["tokens"] == b2["tokens"]).all()
    b3 = batch_at_step(cfg, 6)
    assert not (b1["tokens"] == b3["tokens"]).all()
    # next-token labels
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_pipeline_prefetch_ordering():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    pipe = Pipeline(cfg, start_step=3)
    try:
        steps = [next(pipe)[0] for _ in range(4)]
        assert steps == [3, 4, 5, 6]
        s, b = 3, batch_at_step(cfg, 3)
    finally:
        pipe.close()


def test_adamw_minimizes_quadratic():
    ocfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                       total_steps=200)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(ocfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(ocfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_grad_clip_and_schedule():
    ocfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=10,
                       total_steps=100, min_lr_frac=0.1)
    assert float(cosine_lr(ocfg, jnp.asarray(0))) == 0.0
    assert abs(float(cosine_lr(ocfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(cosine_lr(ocfg, jnp.asarray(100))) <= 0.1 + 1e-6
    params = {"w": jnp.zeros(3)}
    state = adamw_init(ocfg, params)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, metrics = adamw_update(ocfg, params, g, state)
    assert float(metrics["grad_norm"]) > 99.0


def test_int8_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32)) * 5
    q, s = quantize_int8(x)
    err = float(jnp.abs(dequantize_int8(q, s) - x).max())
    assert err <= float(s) * 0.51 + 1e-6  # half-ulp of the int8 grid


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": [jnp.ones((2, 3)), {"c": jnp.asarray(7)}]}
    ck = CheckpointManager(tmp_path, async_save=False)
    ck.save(3, tree, extra={"data_step": 3})
    ck.save(9, tree, extra={"data_step": 9})
    assert latest_step(tmp_path) == 9
    step, tree2, extra = ck.restore(None, tree)
    assert step == 9 and extra["data_step"] == 9
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(tree2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_async_and_gc(tmp_path):
    tree = {"w": jnp.zeros(4)}
    ck = CheckpointManager(tmp_path, keep_last=2, async_save=True)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"
