"""Range scans & bulk ordered ops (DESIGN.md §15).

Conformance for the ordered-read tentpole: every backend declaring
``Capability.range_scan`` / ``successor_k`` is checked against a numpy
oracle over randomized traces — through the host-facing
``Index.range_scan`` (inclusive ``[lo, hi]``, cursor pagination) and the
raw batched 5-tuple hook.  Engine parity (scalar vs lockstep) and forest
dispatch parity (fused frontier vs dense vmap) must hold *bit for bit*,
keys AND payloads AND hops, including buffered items carried by deferred
maintenance (invariant I5').  Subprocess legs replay the forest scan over
8 fake host devices (real shard_map dispatch) and the serve scheduler's
``scan()`` under x64.  The satellite legs pin the ``reclaimed``
maintenance counter and the ``live_items`` global-order contract the
scan oracle depends on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CapabilityError,
    OpBatch,
    ScanCursor,
    make_index,
)
from repro.core.oracle import SetOracle
from tests._subproc import run_py

KEY_HI = 300

BUILD_KW = {
    "deltatree": dict(height=4, max_dnodes=512, buf_cap=8),
    "forest": dict(num_shards=3, height=4, max_dnodes=512, buf_cap=8,
                   key_max=KEY_HI),
    "sorted_array": dict(cap=4096),
    "static_veb": {},
}
SCAN_BACKENDS = tuple(BUILD_KW)           # everything but pointer_bst
ENGINE_BACKENDS = ("deltatree", "forest")


def _mk(backend, initial, engine=None, **kw):
    return make_index(backend, initial=initial, engine=engine,
                      **{**BUILD_KW[backend], **kw})


def _oracle_band(live, lo, hi, k):
    """First ``k`` live keys in the inclusive band [lo, hi]."""
    a = np.asarray(sorted(live))
    return a[(a >= lo) & (a <= hi)][:k]


def _check_scan_reads(ix, oracle, rng, max_items=16):
    for _ in range(4):
        lo = int(rng.integers(1, KEY_HI))
        hi = int(rng.integers(lo, KEY_HI + 5))
        res = ix.range_scan(lo, hi, max_items=max_items)
        exp = _oracle_band(oracle.s, lo, hi, max_items)
        np.testing.assert_array_equal(res.keys, exp)
        in_band = sum(lo <= x <= hi for x in oracle.s)
        assert res.more == (in_band > max_items), (lo, hi, res)
        assert (res.cursor is None) == (not res.more or res.count == 0)


@pytest.mark.parametrize("backend", SCAN_BACKENDS)
def test_range_scan_trace_matches_oracle(backend):
    """Randomized update trace: after every batch, range scans over
    random inclusive bands agree with the oracle, and the ``more`` /
    cursor flags reflect the true band population."""
    rng = np.random.default_rng(41)
    initial = np.unique(rng.integers(1, KEY_HI, 80).astype(np.int32))
    ix = _mk(backend, initial)
    assert ix.capability.range_scan and ix.capability.successor_k
    oracle = SetOracle(initial)
    for _ in range(6):
        _check_scan_reads(ix, oracle, rng)
        kinds = rng.integers(0, 3, size=24).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=24).astype(np.int32)
        ix, res = ix.insert_delete(OpBatch.mixed(kinds, keys))
        np.testing.assert_array_equal(
            np.asarray(res), oracle.apply_updates(kinds, keys))
    # empty / inverted bands emit nothing and never truncate
    for lo, hi in ((200, 150), (KEY_HI + 1, KEY_HI + 50)):
        res = ix.range_scan(lo, hi)
        assert res.count == 0 and not res.more and res.cursor is None


@pytest.mark.parametrize("backend", SCAN_BACKENDS)
def test_successor_k_matches_oracle(backend):
    rng = np.random.default_rng(42)
    initial = np.unique(rng.integers(1, KEY_HI, 90).astype(np.int32))
    ix = _mk(backend, initial)
    q = rng.integers(0, KEY_HI + 5, size=12).astype(np.int32)
    k = 6
    keys, pays, n, hops, more = ix.successor_k(jnp.asarray(q), k)
    live = np.asarray(sorted(SetOracle(initial).s))
    for i, qi in enumerate(q):
        exp = live[live > qi][:k]
        assert int(n[i]) == exp.size
        np.testing.assert_array_equal(np.asarray(keys)[i, :exp.size], exp)
        np.testing.assert_array_equal(
            np.asarray(keys)[i, exp.size:], 0)     # zero-padded past n
        assert bool(more[i]) == (live[live > qi].size > k)


def test_range_scan_capability_gate():
    ix = make_index("pointer_bst", initial=np.asarray([5, 9], np.int32),
                    cap=64)
    assert not ix.capability.range_scan
    with pytest.raises(CapabilityError):
        ix.range_scan(1, 100)
    with pytest.raises(CapabilityError):
        ix.successor_k(jnp.asarray([5], jnp.int32), 4)


@pytest.mark.parametrize("backend", SCAN_BACKENDS)
def test_cursor_pagination_replays_live_items(backend):
    """Full-range pagination with a small emit buffer: chaining each
    page's ScanCursor replays ``live_items`` exactly, then terminates
    with cursor=None."""
    rng = np.random.default_rng(43)
    initial = np.unique(rng.integers(1, KEY_HI, 70).astype(np.int32))
    ix = _mk(backend, initial)
    got, cursor, pages = [], None, 0
    while True:
        if cursor is None:
            res = ix.range_scan(1, KEY_HI + 5, max_items=7)
        else:
            res = ix.range_scan(0, 0, max_items=7, cursor=cursor)
        got.extend(res.keys.tolist())
        pages += 1
        if res.cursor is None:
            break
        assert isinstance(res.cursor, ScanCursor)
        cursor = res.cursor
    assert got == [k for k, _ in ix.live_items()] == initial.tolist()
    assert pages == -(-initial.size // 7)


@pytest.mark.parametrize("backend", ENGINE_BACKENDS)
def test_scan_engine_parity(backend):
    """scalar vs lockstep on the raw batched hook: keys, payloads, n,
    hops (the transfer statistic), and more — bit for bit, tombstones
    included (the trace deletes throughout)."""
    rng = np.random.default_rng(44)
    initial = np.unique(rng.integers(1, KEY_HI, 90).astype(np.int32))
    ix_s = _mk(backend, initial, engine="scalar")
    ix_l = _mk(backend, initial, engine="lockstep")
    for _ in range(3):
        kinds = rng.integers(0, 3, size=24).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=24).astype(np.int32)
        batch = OpBatch.mixed(kinds, keys)
        ix_s, _ = ix_s.insert_delete(batch)
        ix_l, _ = ix_l.insert_delete(batch)
        lo = rng.integers(0, KEY_HI, size=16).astype(np.int32)
        hi = (lo + rng.integers(1, 80, size=16)).astype(np.int32)
        for ix_pair in ((ix_s, ix_l),):
            outs = [ix.spec.backend.scan(ix.spec.cfg, ix.state,
                                         jnp.asarray(lo), jnp.asarray(hi), 8)
                    for ix in ix_pair]
            for a, b in zip(*outs):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forest_dispatch_parity_and_oracle():
    """Fused cross-shard frontier vs dense per-shard vmap: the merged
    global-order scan rows agree bit for bit, and both match the
    oracle."""
    rng = np.random.default_rng(45)
    initial = np.unique(rng.integers(1, KEY_HI, 100).astype(np.int32))
    ix_f = _mk("forest", initial, engine="lockstep")
    ix_v = _mk("forest", initial, engine="lockstep", fused=False)
    assert ix_f.capability.fused_forest and not ix_v.capability.fused_forest
    lo = rng.integers(0, KEY_HI, size=12).astype(np.int32)
    hi = (lo + rng.integers(1, 120, size=12)).astype(np.int32)
    out_f = ix_f.spec.backend.scan(ix_f.spec.cfg, ix_f.state,
                                   jnp.asarray(lo), jnp.asarray(hi), 10)
    out_v = ix_v.spec.backend.scan(ix_v.spec.cfg, ix_v.state,
                                   jnp.asarray(lo), jnp.asarray(hi), 10)
    for a, b in zip(out_f, out_v):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    keys, _, n, _, more = out_f
    live = np.asarray(sorted(SetOracle(initial).s))
    for i in range(lo.size):
        exp = live[(live > lo[i]) & (live <= hi[i])][:10]  # hook: excl start
        assert int(n[i]) == exp.size
        np.testing.assert_array_equal(np.asarray(keys)[i, :exp.size], exp)


@pytest.mark.parametrize("backend", ENGINE_BACKENDS)
def test_scan_deferred_merges_buffered_items(backend):
    """Non-eager maintenance carries inserts in overflow buffers (I5');
    scans must still return them, merged into key order, on both
    engines bit-identically."""
    rng = np.random.default_rng(46)
    initial = np.unique(rng.integers(1, KEY_HI, 60).astype(np.int32))
    ixs = [_mk(backend, initial, engine=e, maintenance="deferred")
           for e in ("scalar", "lockstep")]
    oracle = SetOracle(initial)
    saw_pending = False
    for _ in range(5):
        kinds = rng.integers(0, 3, size=20).astype(np.int32)
        keys = rng.integers(1, KEY_HI, size=20).astype(np.int32)
        batch = OpBatch.mixed(kinds, keys)
        stats = None
        for j, ix in enumerate(ixs):
            ixs[j], _, stats = ix.update(batch)
        oracle.apply_updates(kinds, keys)
        saw_pending |= int(stats.pending) > 0
        _check_scan_reads(ixs[0], oracle, rng, max_items=12)
        lo = rng.integers(0, KEY_HI, size=10).astype(np.int32)
        hi = (lo + rng.integers(1, 100, size=10)).astype(np.int32)
        outs = [ix.spec.backend.scan(ix.spec.cfg, ix.state, jnp.asarray(lo),
                                     jnp.asarray(hi), 12) for ix in ixs]
        for a, b in zip(*outs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert saw_pending, "trace never exercised carried buffers"


def test_live_items_global_key_order():
    """Satellite contract: live_items is ascending in the GLOBAL key
    space for sharded backends too — the ordering range_scan pagination
    is checked against."""
    rng = np.random.default_rng(47)
    initial = np.unique(rng.integers(1, KEY_HI, 80).astype(np.int32))
    for backend in SCAN_BACKENDS:
        ix = _mk(backend, initial)
        keys = [k for k, _ in ix.live_items()]
        assert keys == sorted(keys) == initial.tolist(), backend


def test_reclaimed_counter_tracks_freed_arena_slots():
    """MaintenanceStats.reclaimed counts arena slots returned to the
    freelist by Merge — nonzero on delete-heavy eager traces, and under
    a budget the counter accumulates across update + flush while the
    live set still tracks the oracle."""
    from tests.test_deltatree import check_invariants

    rng = np.random.default_rng(48)
    vals = np.unique(rng.integers(1, KEY_HI, 120).astype(np.int32))
    for policy in ("eager", "budgeted:2"):
        ix = make_index("deltatree", initial=vals, maintenance=policy,
                        height=4, max_dnodes=512, buf_cap=8)
        oracle = SetOracle(vals)
        reclaimed = 0
        for i in range(6):
            # delete LIVE keys so ΔNodes actually empty out and Merge
            # returns their arena slots to the freelist
            live = np.asarray(sorted(oracle.s))
            kinds = np.full(16, 2, np.int32)
            keys = rng.choice(live, size=min(16, live.size),
                              replace=False).astype(np.int32)
            kinds = kinds[: keys.size]
            ix, res, stats = ix.update(OpBatch.mixed(kinds, keys))
            np.testing.assert_array_equal(
                np.asarray(res), oracle.apply_updates(kinds, keys))
            assert int(stats.reclaimed) >= 0
            reclaimed += int(stats.reclaimed)
        ix, fstats = ix.flush()
        reclaimed += int(fstats.reclaimed)
        assert reclaimed > 0, policy
        assert [k for k, _ in ix.live_items()] == sorted(oracle.s)
        check_invariants(ix.spec.cfg, ix.state)


def test_forest_scan_8_fake_devices():
    """The fused cross-shard scan over a real 8-device shard_map mesh:
    global-order rows and successor_k against the oracle."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.api import make_index
rng = np.random.default_rng(51)
vals = np.unique(rng.integers(1, 2000, 300).astype(np.int32))
ix = make_index("forest", initial=vals, num_shards=8, height=4,
                max_dnodes=512, buf_cap=8, key_max=2000, engine="lockstep")
assert ix.capability.fused_forest
lo = rng.integers(0, 2000, size=16).astype(np.int32)
hi = (lo + rng.integers(1, 400, size=16)).astype(np.int32)
keys, pays, n, hops, more = ix.spec.backend.scan(
    ix.spec.cfg, ix.state, jnp.asarray(lo), jnp.asarray(hi), 12)
for i in range(16):
    exp = vals[(vals > lo[i]) & (vals <= hi[i])][:12]
    assert int(n[i]) == exp.size, (i, int(n[i]), exp)
    np.testing.assert_array_equal(np.asarray(keys)[i, :exp.size], exp)
res = ix.range_scan(100, 900, max_items=64)
exp = vals[(vals >= 100) & (vals <= 900)][:64]
np.testing.assert_array_equal(res.keys, exp)
sk, _, sn, _, _ = ix.successor_k(jnp.asarray(lo), 5)
for i in range(16):
    exp = vals[vals > lo[i]][:5]
    np.testing.assert_array_equal(np.asarray(sk)[i, :exp.size], exp)
print("FOREST SCAN 8DEV OK", jax.device_count())
""", devices=8)
    assert "FOREST SCAN 8DEV OK 8" in out


def test_serve_scan_x64():
    """ServeScheduler.scan(): one batched dispatch returns each live
    sequence's page list in block order (vs the pager's block tables),
    and the ScanStats snapshot lands in metrics()."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models.registry import api
from repro.serve import SchedulerConfig, ServeScheduler
from repro.serving import PagerConfig

cfg = get_smoke_config("granite_8b")
m = api(cfg)
params = m.init_params(jax.random.PRNGKey(0))
rng = np.random.default_rng(7)
pc = PagerConfig(num_pages=64, page_size=4, max_seqs=16, max_blocks=64,
                 tree_height=4)
sch = ServeScheduler(cfg, params, pc, SchedulerConfig(max_live=4))
sids = [sch.submit(rng.integers(1, cfg.vocab_size, size=n).astype(np.int32),
                   max_new=4) for n in (5, 9, 3, 7)]
for _ in range(2):
    sch.step()
res = sch.scan(sids)
emitted = 0
for s in sids:
    nb = sch.pager.seq_blocks.get(s, 0)
    got = np.asarray(res[s])
    assert len(got) == nb, (s, len(got), nb)
    if nb:
        np.testing.assert_array_equal(
            got, sch.pager.block_tables([s], nb)[0][:nb])
    emitted += nb
assert emitted > 0
snap = sch.metrics()
assert snap["scan"]["scans"] == 1 and snap["scan"]["lanes"] == len(sids)
assert snap["scan"]["emitted"] == emitted
assert "repro_scan_emitted" in sch.metrics("prometheus")
print("SERVE SCAN OK", emitted)
""", x64=True, timeout=1800)
    assert "SERVE SCAN OK" in out


def test_scan_stats_fold():
    from repro.obs import ScanStats

    a = ScanStats.of(jnp.asarray([3, 0, 2]), jnp.asarray([7, 0, 11]),
                     jnp.asarray([True, False, False]))
    b = ScanStats.of(jnp.asarray([1]), jnp.asarray([2]),
                     jnp.asarray([False]))
    d = a.merge(b).asdict()
    assert d == {"scans": 2, "lanes": 4, "emitted": 6, "truncated": 1,
                 "hops_sum": 20, "hops_max": 11}
    r = ScanStats.reduce(jax.tree.map(lambda *xs: jnp.stack(xs), a, b))
    assert r.asdict()["hops_max"] == 11 and r.asdict()["scans"] == 2
