"""Serving engine + ΔTree pager (subprocess: needs JAX_ENABLE_X64)."""

from tests._subproc import run_py


def test_pager_map_semantics():
    out = run_py("""
import numpy as np
from repro.serving.pager import DeltaPager, PagerConfig

pc = PagerConfig(num_pages=128, page_size=4, max_seqs=32, max_blocks=64,
                 tree_height=4)
pg = DeltaPager(pc)
p0 = pg.allocate(0, 3)
p1 = pg.allocate(1, 2)
assert len(set(p0) | set(p1)) == 5
bt = pg.block_tables([0, 1], 4)
assert (bt[0, :3] == p0).all() and bt[0, 3] == -1
assert (bt[1, :2] == p1).all() and (bt[1, 2:] == -1).all()
# grow seq 0
p0b = pg.allocate(0, 2)
bt = pg.block_tables([0], 5)
assert (bt[0] == p0 + p0b).all()
pg.free_seq(0)
assert len(pg.free_pages) == 128 - 2
bt = pg.block_tables([0, 1], 4)
assert (bt[0] == -1).all()
pg.free_seq(1)
assert sorted(pg.free_pages) == list(range(128))
print("PAGER OK", pg.stats)
""", x64=True)
    assert "PAGER OK" in out


def test_engine_matches_dense_decode():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models.registry import api
from repro.serving import ServeEngine, PagerConfig

cfg = get_smoke_config("granite_8b")
m = api(cfg)
params = m.init_params(jax.random.PRNGKey(0))
rng = np.random.default_rng(7)
pc = PagerConfig(num_pages=64, page_size=4, max_seqs=16, max_blocks=64,
                 tree_height=4)
eng = ServeEngine(cfg, params, pc, max_batch=4)
prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
           for n in (5, 9, 3)]
sids = [eng.submit(p, max_new=6) for p in prompts]
for _ in range(8):
    eng.step()
for p, sid in zip(prompts, sids):
    caches = m.init_caches(1, 64)
    logits, caches = m.prefill(params, jnp.asarray(p)[None], caches)
    toks = [int(jnp.argmax(logits[0, -1]))]
    ln = len(p)
    for _ in range(5):
        lg, caches = m.decode_step(params,
            jnp.asarray([[toks[-1]]], jnp.int32), caches,
            jnp.asarray([ln], jnp.int32))
        toks.append(int(jnp.argmax(lg[0, 0])))
        ln += 1
    assert eng.active[sid].out == toks, (sid, eng.active[sid].out, toks)
assert len(eng.pager.free_pages) == pc.num_pages  # all pages reclaimed
assert eng.pager.stats["searches"] > 0
print("ENGINE OK")
""", x64=True, timeout=1200)
    assert "ENGINE OK" in out


def test_train_restart_bit_exact(tmp_path):
    """Kill-and-resume equals an uninterrupted run (determinism by step)."""
    out = run_py(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.launch import train as TR

pA = TR.main(["--arch", "granite_8b", "--smoke", "--steps", "8",
              "--batch", "2", "--seq", "32", "--log-every", "100"])

# interrupted: 4 steps + checkpoint, then resume to 8
pB = TR.main(["--arch", "granite_8b", "--smoke", "--steps", "4",
              "--batch", "2", "--seq", "32", "--ckpt-dir", r'{tmp_path}',
              "--ckpt-every", "100", "--log-every", "100"])
pC = TR.main(["--arch", "granite_8b", "--smoke", "--steps", "8",
              "--batch", "2", "--seq", "32", "--ckpt-dir", r'{tmp_path}',
              "--resume", "--log-every", "100"])
d = max(float(jnp.abs(a - b).max()) for a, b in
        zip(jax.tree.leaves(pA), jax.tree.leaves(pC)))
assert d == 0.0, d
print("RESTART OK")
""", timeout=1800)
    assert "RESTART OK" in out
