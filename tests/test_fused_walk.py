"""Fused single-launch walk driver: bit-parity vs the per-round driver
and the scalar engine, sentinel/multi-root contracts, the derived round
cap, the q_tile autotune table, and compiled-mode (REPRO_PALLAS_INTERPRET=0)
subprocess legs including ``engine="auto"`` resolution."""

import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import TreeConfig, bulk_build, search_jit, update_batch
from repro.kernels.ops import (
    delta_walk, delta_walk_fused, walk_round_cap,
)
from repro.kernels.veb_search import walk_big

from _subproc import run_py


def _churned_tree(h, m, nvals, seed, n_updates=128):
    rng = np.random.default_rng(seed)
    cfg = TreeConfig(height=h, max_dnodes=m, buf_cap=16)
    vals = np.unique(rng.integers(1, 100_000, size=nvals).astype(np.int32))
    t = bulk_build(cfg, vals)
    kinds = rng.choice([1, 2], size=n_updates).astype(np.int32)
    keys = rng.integers(1, 100_000, size=n_updates).astype(np.int32)
    t, _, _ = update_batch(cfg, t, jnp.asarray(kinds), jnp.asarray(keys))
    q = rng.integers(1, 100_000, size=500).astype(np.int32)
    return cfg, t, jnp.asarray(q)


@pytest.mark.parametrize("h,m,nvals", [
    (3, 8192, 1200), (4, 4096, 2000), (7, 2048, 3000),
])
def test_fused_walk_bit_parity(h, m, nvals):
    """The fused driver is bit-identical to the per-round driver on every
    output — hops included — and hops match the scalar engine's transfer
    statistic, on a churned tree (marks, buffers, expansions, merges)."""
    cfg, t, q = _churned_tree(h, m, nvals, seed=h)
    fused = delta_walk_fused(t.value, t.child, t.root, q, height=h,
                             q_tile=128)
    per_round = delta_walk(t.value, t.child, t.root, q, height=h,
                           q_tile=128, fused=False)
    names = ("leaf_val", "leaf_b", "final_dn", "hops", "cand")
    for name, a, b in zip(names, fused, per_round):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    _, chops = search_jit(cfg, t, q)
    np.testing.assert_array_equal(np.asarray(fused[3]), np.asarray(chops))


def test_fused_kernel_vs_ref_mirror_direct():
    """`veb_walk_fused` (Pallas, interpret) vs `ref_delta_walk_fused`
    (the XLA-compiled mirror it falls back to): same 5-tuple, same bits,
    on a padded arena with per-query roots."""
    from repro.kernels.ref import ref_delta_walk_fused
    from repro.kernels.veb_search import pad_arena, veb_walk_fused

    h = 5
    cfg, t, q = _churned_tree(h, 2048, 3000, seed=11)
    k = 384  # q_tile multiple: the raw kernel takes pre-padded batches
    q = q[:k]
    value_p, child_p = pad_arena(t.value, t.child)
    roots = jnp.broadcast_to(jnp.asarray(t.root, jnp.int32), (k,))
    cap = walk_round_cap(h, int(t.value.shape[0]))
    kern = veb_walk_fused(value_p, child_p, roots, q, height=h,
                          q_tile=128, max_rounds=cap, interpret=True)
    ref = ref_delta_walk_fused(t.value, t.child, roots, q, height=h,
                               max_rounds=cap)
    for i, (a, b) in enumerate(zip(kern, ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"out{i}")


def test_fused_sentinel_lanes_born_resolved():
    """Real lanes carrying the reserved ROUTE_LEFT key (`walk_big`) are
    born resolved under the fused driver — 0 hops, miss leaf, no successor
    candidate — exactly like the per-round driver (the forest router's
    dense-lane padding depends on this)."""
    cfg, t, q = _churned_tree(4, 512, 800, seed=3, n_updates=32)
    big = walk_big(jnp.int32)
    qs = jnp.concatenate([q[:45], jnp.full((3,), big, jnp.int32)])
    for fused in (True, False):
        lv, lb, dn, hops, cand = delta_walk(
            t.value, t.child, t.root, qs, height=4, q_tile=16, fused=fused)
        assert (np.asarray(hops)[-3:] == 0).all()
        assert (np.asarray(lv)[-3:] == 0).all()
        assert (np.asarray(cand)[-3:] == big).all()


def test_fused_multi_root_seeding():
    """(K,) per-query roots over a `fuse_arenas` view: the fused driver
    matches per-arena fused walks bit for bit (the fused-forest frontier's
    seeding contract)."""
    from repro.core import deltatree as DT
    from repro.kernels.veb_search import fuse_arenas

    rng = np.random.default_rng(9)
    tcfg = TreeConfig(height=4, max_dnodes=128, buf_cap=8)
    vals_a = np.unique(rng.integers(1, 500, 120).astype(np.int32))
    vals_b = np.unique(rng.integers(500, 999, 120).astype(np.int32))
    ta, tb = DT.bulk_build(tcfg, vals_a), DT.bulk_build(tcfg, vals_b)
    qa = rng.integers(1, 500, 40).astype(np.int32)
    qb = rng.integers(500, 999, 40).astype(np.int32)
    fv, fc, froots = fuse_arenas(jnp.stack([ta.value, tb.value]),
                                 jnp.stack([ta.child, tb.child]),
                                 jnp.stack([ta.root, tb.root]))
    lid = jnp.asarray([0] * 40 + [1] * 40, jnp.int32)
    q = jnp.asarray(np.concatenate([qa, qb]))
    fused = delta_walk_fused(fv, fc, froots[lid], q, height=4, q_tile=16)
    ra = delta_walk_fused(ta.value, ta.child, ta.root, jnp.asarray(qa),
                          height=4, q_tile=16)
    rb = delta_walk_fused(tb.value, tb.child, tb.root, jnp.asarray(qb),
                          height=4, q_tile=16)
    m = int(ta.value.shape[0])
    for i, (a, b) in enumerate(zip(ra, rb)):
        one = np.concatenate([np.asarray(a), np.asarray(b)])
        if i == 2:  # final_dn: arena-local ids shift by the shard base
            one = np.concatenate([np.asarray(a), np.asarray(b) + m])
        np.testing.assert_array_equal(np.asarray(fused[i]), one)


def test_round_cap_derived_and_never_hit():
    """`max_rounds=None` derives the cap from arena geometry; the cap
    strictly clears the deepest observed walk (a truncated walk would
    return wrong leaves silently), and matches an effectively-unbounded
    walk bit for bit."""
    for h, m in ((3, 8192), (4, 4096), (7, 2048)):
        cfg, t, q = _churned_tree(h, m, 2000, seed=h + 20)
        cap = walk_round_cap(h, m)
        derived = delta_walk(t.value, t.child, t.root, q, height=h,
                             q_tile=128)  # max_rounds=None -> cap
        unbounded = delta_walk(t.value, t.child, t.root, q, height=h,
                               q_tile=128, max_rounds=256)
        for a, b in zip(derived, unbounded):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(np.asarray(derived[3]).max()) < cap


def test_tree_config_walk_round_cap_property():
    cfg = TreeConfig(height=7, max_dnodes=2048)
    assert cfg.walk_round_cap == walk_round_cap(7, 2048)
    assert TreeConfig(height=7, max_dnodes=2048,
                      walk_rounds=33).walk_round_cap == 33


def test_resolve_engine_auto_table():
    from repro.core.engine import resolve_engine

    # compiled mode: the committed bench table says lockstep wins reads
    assert resolve_engine("auto", "deltatree", compiled=True) == "lockstep"
    assert resolve_engine("auto", "forest", compiled=True) == "lockstep"
    # interpret mode / unknown backends: scalar (never pay the Pallas
    # interpreter tax by default)
    assert resolve_engine("auto", "deltatree", compiled=False) == "scalar"
    assert resolve_engine("auto", "sorted_array", compiled=True) == "scalar"
    # non-auto names pass through untouched
    assert resolve_engine("lockstep", "deltatree", compiled=False) == "lockstep"


def test_make_index_auto_engine_interpret():
    """In this (interpret-mode) process, engine="auto" resolves to scalar
    — and the row-level engine stamp records the resolved name, never the
    sentinel."""
    from repro.api import make_index

    ix = make_index("deltatree", initial=np.asarray([5, 9, 42], np.int32),
                    engine="auto", height=3, max_dnodes=64)
    assert ix.engine == "scalar"
    found = ix.search(jnp.asarray([5, 7], jnp.int32))[0]
    np.testing.assert_array_equal(np.asarray(found), [True, False])


def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    """save_cache/load_cache round-trip through REPRO_PALLAS_AUTOTUNE, the
    cache wins over BAKED in best_q_tile, default_q_tile consumes it, and
    a corrupt cache degrades to the baked table instead of failing."""
    from repro.kernels import autotune
    from repro.kernels.ops import default_q_tile

    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(path))
    monkeypatch.delenv("REPRO_PALLAS_QTILE", raising=False)
    assert autotune.load_cache() == {}

    key = autotune._key(7, compiled=False, bits=32)
    autotune.save_cache({key: 512})
    assert autotune.load_cache() == {key: 512}
    assert autotune.best_q_tile(7, compiled=False) == 512
    # default_q_tile consults the cache for the current (interpret) mode
    assert default_q_tile(7) == 512
    # merge semantics: a second save keeps existing keys
    autotune.save_cache({autotune._key(5, compiled=False, bits=32): 128})
    assert autotune.load_cache()[key] == 512

    path.write_text("not json{")
    assert autotune.load_cache() == {}
    assert (autotune.best_q_tile(7, compiled=True)
            == autotune.BAKED.get((7, True, 32)))

    monkeypatch.delenv(autotune.ENV_CACHE)
    assert autotune.cache_path() is None
    assert autotune.save_cache({key: 64}) is None  # no cache = no-op


def test_walk_dispatch_counter(monkeypatch):
    """REPRO_TRACE=1 makes every delta_walk dispatch count under
    `delta_walk.dispatch` (the host half of walk_launches telemetry)."""
    from repro.obs import trace as TR

    cfg, t, q = _churned_tree(4, 512, 800, seed=5, n_updates=32)
    monkeypatch.setenv("REPRO_TRACE", "1")
    TR.reset_counters()
    delta_walk(t.value, t.child, t.root, q, height=4, q_tile=128)
    delta_walk(t.value, t.child, t.root, q, height=4, q_tile=128)
    assert TR.counters().get("delta_walk.dispatch") == 2
    TR.reset_counters()
    monkeypatch.setenv("REPRO_TRACE", "0")
    delta_walk(t.value, t.child, t.root, q, height=4, q_tile=128)
    assert TR.counters() == {}


def test_fused_walk_map_mode_int64_subprocess():
    """Map-mode (int64 packed rows) fused walk parity — x64 subprocess:
    fused vs per-round vs the legacy search contract on packed queries."""
    out = run_py("""
import numpy as np, jax.numpy as jnp
from repro.core import TreeConfig, bulk_build
from repro.kernels.ops import delta_walk
from repro.kernels.ref import ref_delta_search

cfg = TreeConfig(height=4, max_dnodes=1024, buf_cap=8, payload_bits=16)
rng = np.random.default_rng(2)
vals = np.unique(rng.integers(1, 60_000, 1500).astype(np.int32))
pay = rng.integers(0, 2**16, vals.size).astype(np.int32)
t = bulk_build(cfg, jnp.asarray(vals), jnp.asarray(pay))
assert t.value.dtype == jnp.int64
q = cfg.qpack(jnp.asarray(rng.integers(1, 60_000, 300).astype(np.int32)))
fused = delta_walk(t.value, t.child, t.root, q, height=4, q_tile=64)
per_round = delta_walk(t.value, t.child, t.root, q, height=4, q_tile=64,
                       fused=False)
for i, (a, b) in enumerate(zip(fused, per_round)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=str(i))
rlv, rlb, rdn = ref_delta_search(t.value, t.child, t.root, q, height=4)
np.testing.assert_array_equal(np.asarray(fused[0]), np.asarray(rlv))
np.testing.assert_array_equal(np.asarray(fused[2]), np.asarray(rdn))
print("MAP64_OK")
""", x64=True)
    assert "MAP64_OK" in out


def test_compiled_mode_subprocess_parity_and_auto_engine():
    """REPRO_PALLAS_INTERPRET=0 leg: the compiled fused walk (the XLA
    mirror on CPU) matches the interpret-mode Pallas kernel bit for bit,
    walks run under the derived round cap, and engine="auto" resolves to
    lockstep — the committed compiled-mode table winner."""
    out = run_py("""
import os
os.environ["REPRO_PALLAS_INTERPRET"] = "0"
import numpy as np, jax.numpy as jnp
from repro.core import TreeConfig, bulk_build, search_jit, update_batch
from repro.kernels.ops import default_interpret, delta_walk
assert default_interpret() is False

rng = np.random.default_rng(13)
cfg = TreeConfig(height=5, max_dnodes=2048, buf_cap=16)
vals = np.unique(rng.integers(1, 80_000, 2500).astype(np.int32))
t = bulk_build(cfg, vals)
kinds = rng.choice([1, 2], size=96).astype(np.int32)
keys = rng.integers(1, 80_000, size=96).astype(np.int32)
t, _, _ = update_batch(cfg, t, jnp.asarray(kinds), jnp.asarray(keys))
q = jnp.asarray(rng.integers(1, 80_000, 400).astype(np.int32))
compiled = delta_walk(t.value, t.child, t.root, q, height=5)
interp = delta_walk(t.value, t.child, t.root, q, height=5, interpret=True)
for i, (a, b) in enumerate(zip(compiled, interp)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=str(i))
_, chops = search_jit(cfg, t, q)
np.testing.assert_array_equal(np.asarray(compiled[3]), np.asarray(chops))

from repro.api import make_index
ix = make_index("deltatree", initial=vals, engine="auto", height=5,
                max_dnodes=2048)
assert ix.engine == "lockstep", ix.engine
found = ix.search(jnp.asarray([int(vals[0]), 0x7ead]))[0]
assert bool(np.asarray(found)[0])
print("COMPILED_OK")
""")
    assert "COMPILED_OK" in out


def test_autotune_smoke_cli_subprocess(tmp_path):
    """The autotune CLI at smoke scale: emits winner rows and writes the
    REPRO_PALLAS_AUTOTUNE cache with mode-stamped keys."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    repo = Path(__file__).resolve().parent.parent
    cache = tmp_path / "tune.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + str(repo)
    env.pop("XLA_FLAGS", None)
    env["REPRO_PALLAS_AUTOTUNE"] = str(cache)
    out = subprocess.run(
        [sys.executable, str(repo / "benchmarks" / "autotune_qtile.py"),
         "--smoke"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr
    rows = [json.loads(ln) for ln in out.stdout.splitlines()
            if ln.strip().startswith("{")]
    winners = [r for r in rows if r.get("winner")]
    assert winners and all(r["bench"] == "autotune_qtile" for r in rows)
    table = json.loads(cache.read_text())
    assert all("/" in k and isinstance(v, int) for k, v in table.items())
    assert any(k.startswith("5/") for k in table)
