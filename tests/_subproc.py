"""Run a python snippet in a subprocess (own device count / x64 flags)."""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_py(code: str, *, devices: int | None = None, x64: bool = False,
           timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout
