"""Per-kernel interpret-mode validation vs the pure-jnp oracles (ref.py):
shape/dtype sweeps per the assignment."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import TreeConfig, bulk_build, search_jit, update_batch
from repro.kernels.delta_paged_attention import paged_decode_attention
from repro.kernels.ops import default_interpret, delta_contains, delta_search, delta_walk
from repro.kernels.ref import ref_delta_search, ref_paged_decode_attention


@pytest.mark.parametrize("h,m,nvals,qt", [
    (3, 8192, 1200, 64), (4, 4096, 2000, 128), (5, 2048, 3000, 128),
    (7, 2048, 3000, 256),
])
def test_veb_search_kernel_vs_ref(h, m, nvals, qt):
    rng = np.random.default_rng(h)
    cfg = TreeConfig(height=h, max_dnodes=m, buf_cap=16)
    vals = np.unique(rng.integers(1, 100_000, size=nvals).astype(np.int32))
    t = bulk_build(cfg, vals)
    # churn: marks, buffers, expansions, merges
    kinds = rng.choice([1, 2], size=64).astype(np.int32)
    keys = rng.integers(1, 100_000, size=64).astype(np.int32)
    t, _, _ = update_batch(cfg, t, jnp.asarray(kinds), jnp.asarray(keys))
    q = rng.integers(1, 100_000, size=500).astype(np.int32)
    lv, lb, dn = delta_search(t.value, t.child, t.root, jnp.asarray(q),
                              height=h, q_tile=qt)
    rlv, rlb, rdn = ref_delta_search(t.value, t.child, t.root, jnp.asarray(q),
                                     height=h)
    np.testing.assert_array_equal(np.asarray(lv), np.asarray(rlv))
    np.testing.assert_array_equal(np.asarray(lb), np.asarray(rlb))
    np.testing.assert_array_equal(np.asarray(dn), np.asarray(rdn))
    found = delta_contains(t.value, t.mark, t.child, t.buf, t.root,
                           jnp.asarray(q), height=h, q_tile=qt)
    cfound, chops = search_jit(cfg, t, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(found), np.asarray(cfound))
    # full-walk contract: per-query hop counts equal the scalar engine's
    # transfer statistic (rounds active == ΔNodes visited)
    _, _, _, hops, _ = delta_walk(t.value, t.child, t.root, jnp.asarray(q),
                                  height=h, q_tile=qt)
    np.testing.assert_array_equal(np.asarray(hops), np.asarray(chops))


def test_delta_walk_pad_sentinel_no_alias():
    """Query batches not divisible by q_tile pad with a provably-missing
    sentinel and pre-resolved lanes: results must be identical whatever
    the padding width, and a query equal to the old pad value (EMPTY-
    adjacent key 1) must still resolve correctly."""
    rng = np.random.default_rng(7)
    cfg = TreeConfig(height=4, max_dnodes=512, buf_cap=8)
    vals = np.unique(
        np.concatenate([[1], rng.integers(1, 5000, 800)]).astype(np.int32))
    t = bulk_build(cfg, vals)
    q = np.concatenate([[1, 2], rng.integers(1, 5000, 41)]).astype(np.int32)
    outs = [delta_walk(t.value, t.child, t.root, jnp.asarray(q),
                       height=4, q_tile=qt) for qt in (16, 64, 256)]
    for other in outs[1:]:
        for a, b in zip(outs[0], other):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    lv = np.asarray(outs[0][0])
    assert lv[0] == 1  # key 1 (== EMPTY + 1) found despite padded lanes


def test_ref_walk_rows_matches_kernel():
    """The compiled jnp mirror (the int64-on-TPU production fallback) must
    match the Pallas kernel's one-round contract exactly, cand included."""
    from repro.kernels.ref import ref_veb_walk_rows
    from repro.kernels.veb_search import pad_arena, veb_walk_rows

    rng = np.random.default_rng(3)
    cfg = TreeConfig(height=5, max_dnodes=2048, buf_cap=16)
    vals = np.unique(rng.integers(1, 50_000, 2500).astype(np.int32))
    t = bulk_build(cfg, vals)
    n_alive = int(np.asarray(t.alive).sum())
    q = jnp.asarray(rng.integers(1, 50_000, 256).astype(np.int32))
    vp, cp = pad_arena(t.value, t.child)
    dns = jnp.asarray(rng.integers(0, n_alive, 256).astype(np.int32))
    rows, childrows = vp[dns], cp[dns]
    out_k = veb_walk_rows(rows, childrows, q, height=5, q_tile=256,
                          interpret=True)
    out_r = ref_veb_walk_rows(rows, childrows, q, height=5)
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_default_interpret_env_override(monkeypatch):
    """REPRO_PALLAS_INTERPRET overrides the backend auto-detection."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert default_interpret() is True
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    import jax

    assert default_interpret() is (jax.default_backend() != "tpu")


@pytest.mark.parametrize("b,qh,kvh,d,ps,maxp", [
    (2, 4, 2, 64, 8, 4),
    (3, 8, 1, 128, 16, 3),
    (1, 2, 2, 32, 4, 6),
    (4, 8, 8, 64, 8, 2),   # MHA (G=1)
])
@pytest.mark.parametrize("dtype,tol", [(np.float32, 2e-5), (jnp.bfloat16, 0.12)])
def test_paged_attention_kernel_vs_ref(b, qh, kvh, d, ps, maxp, dtype, tol):
    rng = np.random.default_rng(b * 100 + qh)
    npages = b * maxp + 3
    q = rng.standard_normal((b, qh, d)).astype(np.float32)
    kp = rng.standard_normal((npages, ps, kvh, d)).astype(np.float32)
    vp = rng.standard_normal((npages, ps, kvh, d)).astype(np.float32)
    lens = rng.integers(1, maxp * ps + 1, size=b).astype(np.int32)
    bt = np.full((b, maxp), -1, np.int32)
    perm = rng.permutation(npages)
    c = 0
    for i in range(b):
        for j in range(-(-int(lens[i]) // ps)):
            bt[i, j] = perm[c]
            c += 1
    ref = ref_paged_decode_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt),
        jnp.asarray(lens))
    out = paged_decode_attention(
        jnp.asarray(q, dtype), jnp.asarray(kp, dtype), jnp.asarray(vp, dtype),
        jnp.asarray(bt), jnp.asarray(lens))
    err = np.abs(np.asarray(out, np.float32) - np.asarray(ref, np.float32)).max()
    assert err < tol, (b, qh, kvh, d, ps, maxp, dtype, err)


def test_paged_attention_ignores_garbage_pages():
    """Pages not referenced by a sequence's block table must not leak in."""
    rng = np.random.default_rng(0)
    b, qh, kvh, d, ps, maxp = 2, 4, 2, 32, 8, 3
    npages = 10
    q = rng.standard_normal((b, qh, d)).astype(np.float32)
    kp = rng.standard_normal((npages, ps, kvh, d)).astype(np.float32)
    vp = rng.standard_normal((npages, ps, kvh, d)).astype(np.float32)
    lens = np.asarray([9, 17], np.int32)
    bt = np.asarray([[4, 5, -1], [6, 7, 8]], np.int32)
    out1 = paged_decode_attention(jnp.asarray(q), jnp.asarray(kp),
                                  jnp.asarray(vp), jnp.asarray(bt),
                                  jnp.asarray(lens))
    kp2 = kp.copy()
    vp2 = vp.copy()
    for g in (0, 1, 2, 3, 9):  # unreferenced pages scrambled
        kp2[g] = 1e3
        vp2[g] = -1e3
    out2 = paged_decode_attention(jnp.asarray(q), jnp.asarray(kp2),
                                  jnp.asarray(vp2), jnp.asarray(bt),
                                  jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
