"""Continuous-batching serve scheduler (DESIGN.md §10).

Host-side units for the queue / op-combining / maintenance-worker
pieces, the fused-view hoisting regression (consecutive reads build the
``fuse_arenas`` view once; updates invalidate), and the two engine legs:
static-trace parity (no churn + eager maintenance → the scheduler is
bit-identical to the legacy lockstep loop) and the churn leg (arrivals,
cancels, zipf probes, deferred maintenance drained by the worker —
every finished request still matches the dense-decode oracle).
"""

import dataclasses
import types
import warnings

import numpy as np
import pytest

from tests._subproc import run_py

# ---------------------------------------------------------------------------
# op combining (pure numpy)
# ---------------------------------------------------------------------------


def test_combine_annihilates_insert_delete_pairs():
    from repro.api.opbatch import OP_DELETE, OP_INSERT, OP_SEARCH
    from repro.serve.combine import combine_ops

    kinds = [OP_INSERT, OP_INSERT, OP_DELETE, OP_SEARCH, OP_SEARCH,
             OP_INSERT, OP_DELETE, OP_DELETE]
    keys = [5, 5, 5, 9, 9, 7, 7, 5]
    pays = [50, 51, 0, 0, 0, 70, 0, 0]
    k2, key2, _, combined = combine_ops(kinds, keys, pays)
    # DELETE@2 pops the *nearest* open INSERT (row 1), DELETE@7 pops row
    # 0; the (INSERT 7, DELETE 7) pair annihilates; the duplicate SEARCH
    # 9 collapses.  Only the first SEARCH survives.
    assert combined == 7
    assert k2.tolist() == [OP_SEARCH] and key2.tolist() == [9]


def test_combine_keeps_unmatched_rows_in_batch_order():
    from repro.api.opbatch import OP_DELETE, OP_INSERT, OP_SEARCH
    from repro.serve.combine import combine_ops

    # a DELETE with no open INSERT targets a pre-existing key: NOT a
    # no-op pair, must survive (the discipline's asymmetry)
    kinds = [OP_DELETE, OP_INSERT, OP_SEARCH]
    keys = [3, 4, 3]
    k2, key2, p2, combined = combine_ops(kinds, keys, [0, 40, 0])
    assert combined == 0
    assert k2.tolist() == kinds and key2.tolist() == keys
    assert p2.tolist() == [0, 40, 0]


def test_dedupe_lookups_roundtrip():
    from repro.serve.combine import dedupe_lookups

    keys = np.asarray([9, 3, 9, 9, 3], np.int64)
    uniq, inverse, combined = dedupe_lookups(keys)
    assert combined == 3 and len(uniq) == 2
    np.testing.assert_array_equal(uniq[inverse], keys)


# ---------------------------------------------------------------------------
# request queue
# ---------------------------------------------------------------------------


def _req(sid, submit_step=0, max_new=4):
    from repro.serve.queue import ServeRequest

    return ServeRequest(sid, np.zeros(1, np.int32), max_new,
                        submit_step=submit_step)


def test_queue_fifo_admission_and_slot_recycling():
    from repro.serve.queue import RequestQueue

    q = RequestQueue(2)
    reqs = [_req(i) for i in range(4)]
    assert all(q.submit(r) for r in reqs)
    adm = q.admit(step=3)
    assert [(s, r.seq_id) for s, r in adm] == [(0, 0), (1, 1)]
    assert reqs[0].wait_steps == 3 and reqs[1].wait_steps == 3
    q.release(0)                       # a finisher departs slot 0
    adm2 = q.admit(step=5)             # ... and the SAME slot refills
    assert [(s, r.seq_id) for s, r in adm2] == [(0, 2)]
    assert q.depth == 1 and q.n_live == 2
    assert [r.seq_id for _, r in q.live()] == [2, 1]   # slot order


def test_queue_admission_control_bounds_and_cancel():
    from repro.serve.queue import RequestQueue

    q = RequestQueue(2, max_waiting=2)
    reqs = [_req(i) for i in range(5)]
    oks = [q.submit(r) for r in reqs]
    assert oks == [True, True, False, False, False]
    assert q.rejected == 3 and reqs[2].cancelled
    q.admit(step=0)
    late = _req(9)
    assert q.submit(late)              # FIFO drained: admitted again
    assert q.cancel(9) == "waiting" and q.depth == 0
    assert q.cancel(0) == "live" and reqs[0].cancelled
    assert q.cancel(123) == "missing"


# ---------------------------------------------------------------------------
# maintenance worker
# ---------------------------------------------------------------------------


class _StubPager:
    def __init__(self, high_water):
        self.pending = 0
        self.flushes = 0
        self.cfg = types.SimpleNamespace(maint_high_water=high_water)

    def flush(self):
        self.flushes += 1
        self.pending = 0
        return None


def test_worker_drains_on_high_water_not_stride():
    from repro.serve.worker import MaintenanceWorker

    pg = _StubPager(high_water=4)
    w = MaintenanceWorker(pg)          # inherits the pager config's mark
    assert w.high_water == 4
    pg.pending = 3
    assert not w.maybe_drain(1) and pg.flushes == 0
    pg.pending = 4
    assert w.maybe_drain(2)
    assert pg.flushes == 1 and w.drains == 1 and w.last_drain_step == 2
    pg.pending = 1
    assert not w.maybe_drain(3)
    assert w.maybe_drain(4, force=True)      # the final barrier
    # <=0 disables the trigger entirely (but force still drains)
    w0 = MaintenanceWorker(pg, high_water=0)
    pg.pending = 100
    assert not w0.maybe_drain(5)
    assert w0.maybe_drain(5, force=True)


# ---------------------------------------------------------------------------
# pager config: explicit trigger fields, flush_every deprecation
# ---------------------------------------------------------------------------


def test_flush_every_deprecated_on_both_pager_configs():
    from repro.serving.pager import PagerConfig
    from repro.serving.sharded_pager import ShardedPagerConfig

    with pytest.warns(DeprecationWarning, match="flush_every"):
        PagerConfig(flush_every=4)
    with pytest.warns(DeprecationWarning, match="flush_every"):
        ShardedPagerConfig(flush_every=4)
    with warnings.catch_warnings():    # the replacement field never warns
        warnings.simplefilter("error", DeprecationWarning)
        cfg = PagerConfig(maint_high_water=8)
    assert cfg.maint_high_water == 8 and cfg.flush_every == 0


# ---------------------------------------------------------------------------
# fused-view hoisting: build once across reads, invalidate on update
# ---------------------------------------------------------------------------


def _lockstep_fcfg():
    from repro.core import TreeConfig
    from repro.distributed import forest as F

    return F.ForestConfig(
        num_shards=4, key_max=4000, fused=True,
        tree=TreeConfig(height=4, max_dnodes=64, buf_cap=8,
                        engine="lockstep"))


def test_fused_view_built_once_across_consecutive_reads():
    import jax.numpy as jnp

    from repro.distributed import forest as F

    fcfg = _lockstep_fcfg()
    vals = np.arange(10, 4000, 17, dtype=np.int32)
    f = F.bulk_build(fcfg, vals)
    q = jnp.asarray(vals[:16])
    F.reset_fused_view_cache()
    for _ in range(3):                 # consecutive fused reads ...
        F.search_batch(fcfg, f, q)
    F.successor_jit(fcfg, f, q)        # ... of any read kind
    s = F.fused_view_cache_stats()
    assert s["builds"] == 1 and s["hits"] == 3, s

    # an update bumps the epoch: the next read rebuilds, then re-reuses
    f, res, _ = F.update_batch(fcfg, f, jnp.asarray([1], jnp.int32),
                               jnp.asarray([11], jnp.int32))
    assert bool(np.asarray(res)[0])
    F.search_batch(fcfg, f, q)
    F.search_batch(fcfg, f, q)
    s = F.fused_view_cache_stats()
    assert s["builds"] == 2 and s["hits"] == 4, s

    # flush (maintenance) invalidates too — structural moves change the
    # arena even when the key set does not
    f, _ = F.flush(fcfg, f)
    F.search_batch(fcfg, f, q)
    assert F.fused_view_cache_stats()["builds"] == 3


def test_fused_view_cached_reads_match_dense_dispatch():
    import jax.numpy as jnp

    from repro.distributed import forest as F

    fcfg = _lockstep_fcfg()
    fcfg_dense = dataclasses.replace(fcfg, fused=False)
    vals = np.arange(5, 4000, 23, dtype=np.int32)
    f = F.bulk_build(fcfg, vals)
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.integers(0, 4000, size=64).astype(np.int32))
    F.reset_fused_view_cache()
    for _ in range(2):                 # second pass runs off the cache
        found_f, hops_f = F.search_batch(fcfg, f, q)
        found_d, hops_d = F.search_batch(fcfg_dense, f, q)
        np.testing.assert_array_equal(np.asarray(found_f),
                                      np.asarray(found_d))
        np.testing.assert_array_equal(np.asarray(hops_f),
                                      np.asarray(hops_d))
    assert F.fused_view_cache_stats()["hits"] >= 1


def test_fused_view_cache_multidevice():
    """The hoisted view crosses shard_map: built under the mesh once,
    passed back in as a sharded operand on later reads (8 fake devs)."""
    out = run_py("""
import numpy as np, jax.numpy as jnp
from repro.core import TreeConfig
from repro.distributed import forest as F

fcfg = F.ForestConfig(
    num_shards=8, key_max=4000, fused=True,
    tree=TreeConfig(height=4, max_dnodes=64, buf_cap=8, engine="lockstep"))
vals = np.arange(10, 4000, 13, dtype=np.int32)
f = F.bulk_build(fcfg, vals)
q = jnp.asarray(vals[:32])
F.reset_fused_view_cache()
for _ in range(3):
    found, hops = F.search_batch(fcfg, f, q)
assert np.asarray(found).all()
s = F.fused_view_cache_stats()
assert s["builds"] == 1 and s["hits"] == 2, s
import dataclasses
dense = dataclasses.replace(fcfg, fused=False)
fd, hd = F.search_batch(dense, f, q)
np.testing.assert_array_equal(np.asarray(found), np.asarray(fd))
np.testing.assert_array_equal(np.asarray(hops), np.asarray(hd))
print("MULTIDEV VIEW OK")
""", devices=8)
    assert "MULTIDEV VIEW OK" in out


# ---------------------------------------------------------------------------
# engine legs (subprocess: pager needs JAX_ENABLE_X64)
# ---------------------------------------------------------------------------


def test_scheduler_matches_lockstep_on_static_trace():
    """No churn + eager maintenance: the scheduler's pipeline degenerates
    to the lockstep loop — outputs must be bit-identical, page pool fully
    reclaimed by both, same index search count."""
    out = run_py("""
import numpy as np, jax
from repro.configs import get_smoke_config
from repro.models.registry import api
from repro.serving import ServeEngine, PagerConfig
from repro.serving.engine import LockstepServeEngine

cfg = get_smoke_config("granite_8b")
m = api(cfg)
params = m.init_params(jax.random.PRNGKey(0))
rng = np.random.default_rng(7)
pc = PagerConfig(num_pages=64, page_size=4, max_seqs=16, max_blocks=64,
                 tree_height=4)
prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
           for n in (5, 9, 3, 7)]
outs, searches = [], []
for cls in (LockstepServeEngine, ServeEngine):
    eng = cls(cfg, params, pc, max_batch=4)
    sids = [eng.submit(p, max_new=6) for p in prompts]
    for _ in range(8):
        eng.step()
    assert all(eng.active[s].done for s in sids)
    outs.append([eng.active[s].out for s in sids])
    searches.append(eng.pager.stats["searches"])
    assert len(eng.pager.free_pages) == pc.num_pages
assert outs[0] == outs[1], (outs[0], outs[1])
assert searches[0] == searches[1], searches
print("STATIC PARITY OK")
""", x64=True, timeout=1800)
    assert "STATIC PARITY OK" in out


def test_churn_trace_matches_dense_oracle():
    """Sustained mixed arrivals + cancels + zipf probe traffic, deferred
    maintenance drained by the worker at the high-water mark: every
    finished request still bit-matches the dense decode oracle, ops were
    combined, pages reclaimed, and the decode path ran ZERO inline
    structural maintenance."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models.registry import api
from repro.serve import SchedulerConfig, ServeScheduler, synth_trace
from repro.serving import PagerConfig

cfg = get_smoke_config("granite_8b")
m = api(cfg)
params = m.init_params(jax.random.PRNGKey(0))
pc = PagerConfig(num_pages=128, page_size=4, max_seqs=64, max_blocks=128,
                 tree_height=4, maintenance="deferred", maint_high_water=6)
sch = ServeScheduler(cfg, params, pc, SchedulerConfig(max_live=3))
plans = synth_trace(14, seed=11, arrive_p=0.6, prompt_lens=(3, 9),
                    max_new=(3, 7), cancel_p=0.25, probes_per_step=12,
                    vocab=cfg.vocab_size)
summary = sch.run_trace(plans)
assert summary["finished"] >= 5, summary
obs = sch.obs.asdict()
assert obs["combined"] > 0, obs                 # hot keys collapsed
assert sch.worker.stats()["drains"] > 0          # worker path ran ...
assert sch.pager.stats["inline_maint"] == 0      # ... decode path did not
assert len(sch.pager.free_pages) == pc.num_pages # churned pool reclaimed
assert obs["queue_hwm"] >= 1, obs
# every request that ever held a slot shows up in the admission count
# (rejected / cancelled-while-waiting never do)
assert obs["admitted"] == sum(
    r.admit_step >= 0 for r in sch.active.values()), obs
# probe read-side traffic lands in ServeStats (zipf probes in the plan)
n_probes = sum(len(p.probe_refs) for p in plans)
assert obs["probe_queries"] == n_probes > 0, obs
assert 0 <= obs["probe_hits"] <= obs["probe_queries"], obs
# metrics() snapshots every stats source in all three formats
snap = sch.metrics()
assert snap["serve"]["probe_queries"] == n_probes
assert snap["maintenance"]["drains"] == sch.worker.stats()["drains"]
assert snap["pager"]["searches"] == sch.pager.stats["searches"]
prom = sch.metrics("prometheus")
assert "# TYPE repro_serve_steps gauge" in prom
assert "repro_pager_searches" in prom
import json as _json
assert _json.loads(sch.metrics("json"))["serve"]["steps"] == obs["steps"]
for sid, req in sch.active.items():
    if not req.done:
        continue
    caches = m.init_caches(1, 128)
    logits, caches = m.prefill(params, jnp.asarray(req.prompt)[None], caches)
    toks = [int(jnp.argmax(logits[0, -1]))]
    ln = len(req.prompt)
    while len(toks) < req.max_new:
        lg, caches = m.decode_step(params,
            jnp.asarray([[toks[-1]]], jnp.int32), caches,
            jnp.asarray([ln], jnp.int32))
        toks.append(int(jnp.argmax(lg[0, 0])))
        ln += 1
    assert req.out == toks, (sid, req.out, toks)
print("CHURN ORACLE OK", summary["finished"])
""", x64=True, timeout=1800)
    assert "CHURN ORACLE OK" in out
