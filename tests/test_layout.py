"""vEB layout math properties (paper §2)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import layout


@pytest.mark.parametrize("h", [1, 2, 3, 4, 5, 7, 10, 13])
def test_veb_order_is_permutation(h):
    order = layout.veb_order(h)
    assert sorted(order) == list(range(1, 2**h))


@pytest.mark.parametrize("h", [2, 4, 7, 8])
def test_veb_recursive_contiguity(h):
    """At the top split (ht = h//2), the top subtree and each bottom subtree
    occupy contiguous storage ranges — the defining vEB property."""
    pos = layout.veb_pos_table(h)
    ht = h // 2
    hb = h - ht
    top_nodes = [b for b in range(1, 2**ht)]
    top_pos = sorted(int(pos[b]) for b in top_nodes)
    assert top_pos == list(range(len(top_nodes)))  # top first, contiguous
    for r in range(2**ht, 2 ** (ht + 1)):
        sub = []
        frontier = [r]
        for _ in range(hb):
            sub.extend(frontier)
            frontier = [c for b in frontier for c in (2 * b, 2 * b + 1)
                        if c < 2**h]
        sp = sorted(int(pos[b]) for b in sub)
        assert sp == list(range(sp[0], sp[0] + len(sub))), (h, r)


def test_root_first():
    for h in (1, 3, 6):
        assert layout.veb_pos_table(h)[1] == 0


@settings(max_examples=20, deadline=None)
@given(h=st.integers(1, 8), m=st.integers(0, 128), seed=st.integers(0, 99))
def test_rebuild_bst_property(h, m, seed):
    """Rebuilt ΔNode rows are valid leaf-oriented BSTs containing exactly
    the input keys (walked via storage positions)."""
    m = min(m, 2 ** (h - 1))
    rng = np.random.default_rng(seed)
    vals = np.sort(rng.choice(np.arange(1, 10_000), size=m, replace=False)
                   ).astype(np.int32)
    row = layout.rebuild_values_np(h, vals, m)
    pos = layout.veb_pos_table(h)
    bottom0 = 2 ** (h - 1)

    def search(key):
        b = 1
        while True:
            at_bottom = b >= bottom0
            left = layout.EMPTY if at_bottom else row[pos[2 * b]]
            if at_bottom or left == layout.EMPTY:
                return row[pos[b]] == key
            b = 2 * b + (1 if key >= row[pos[b]] else 0)

    for v in vals:
        assert search(int(v)), (h, m, v)
    for v in rng.integers(1, 10_000, size=32):
        if int(v) not in set(vals.tolist()):
            assert not search(int(v))
