"""Export hygiene: ``__all__`` is the single source of truth per package.

Every name a package's ``__all__`` declares must resolve (catching the
historical drift where ``repro.parallel`` advertised shardings/decode-attn
helpers its ``__init__`` never exported), and the deprecation shims in
``repro.core`` / ``repro.distributed`` must keep old imports working while
warning.
"""

import importlib
import warnings

import pytest

PACKAGES = [
    "repro.api",
    "repro.checkpoint",
    "repro.core",
    "repro.data",
    "repro.distributed",
    "repro.kernels",
    "repro.maintenance",
    "repro.obs",
    "repro.optim",
    "repro.parallel",
    "repro.serve",
    "repro.serving",
    "repro.train",
]


@pytest.mark.parametrize("pkg", PACKAGES)
def test_every_all_name_imports(pkg):
    mod = importlib.import_module(pkg)
    assert hasattr(mod, "__all__"), f"{pkg} must declare __all__"
    assert len(set(mod.__all__)) == len(mod.__all__), f"{pkg}: duplicate names"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for name in mod.__all__:
            obj = getattr(mod, name)  # raises AttributeError on drift
            assert obj is not None, f"{pkg}.{name} resolved to None"


def test_core_shim_warns_and_resolves():
    import repro.core
    from repro.core import deltatree

    with pytest.warns(DeprecationWarning, match="make_index"):
        fn = repro.core.update_batch
    assert fn is deltatree.update_batch
    # stable names never warn
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _ = repro.core.TreeConfig, repro.core.OP_INSERT, repro.core.layout


def test_distributed_shim_warns_and_resolves():
    import repro.distributed
    from repro.distributed import forest

    with pytest.warns(DeprecationWarning, match="make_index"):
        fn = repro.distributed.search_batch
    assert fn is forest.search_batch
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _ = repro.distributed.ForestConfig, repro.distributed.router


def test_unknown_attribute_still_raises():
    import repro.core
    import repro.distributed

    with pytest.raises(AttributeError):
        _ = repro.core.not_a_real_name
    with pytest.raises(AttributeError):
        _ = repro.distributed.not_a_real_name
