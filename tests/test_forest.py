"""DeltaForest equivalence: routed sharded forest == single ΔTree / oracle.

In-process tests run on the default single CPU device (the "shards" mesh
degenerates to vmap); subprocess tests exercise real shard_map over 8 fake
host devices and the x64 map-mode / sharded-pager paths.
"""

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core import TreeConfig, live_keys as core_live_keys
from repro.core import empty as core_empty
from repro.core import search_jit, successor_jit as core_successor
from repro.core import update_batch as core_update
from repro.core.oracle import SetOracle
import repro.distributed as D
from repro.distributed import splits as SP
from tests._subproc import run_py


def _mixed_batch(rng, k, key_hi):
    kinds = rng.integers(1, 3, size=k).astype(np.int32)
    keys = rng.integers(1, key_hi, size=k).astype(np.int32)
    return kinds, keys


# ---------------------------------------------------------------- router ---


def test_router_roundtrip():
    from repro.distributed import router as R

    rng = np.random.default_rng(0)
    splits = jnp.asarray([50, 100, 150], jnp.int32)
    keys = jnp.asarray(rng.integers(1, 200, size=64), jnp.int32)
    r = R.route(splits, keys)
    # ownership matches the host-side partitioner
    np.testing.assert_array_equal(
        np.asarray(r.sid), SP.shard_of_np(np.asarray(splits), np.asarray(keys)))
    # scatter/gather is an exact inverse (padding never leaks through)
    dense = R.scatter_dense(r, 4, keys, jnp.int32(0))
    back = R.gather_batch(r, dense)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(keys))
    # each dense row only holds its own shard's keys (or padding)
    dense_np = np.asarray(dense)
    for s in range(4):
        row = dense_np[s][dense_np[s] != 0]
        assert (SP.shard_of_np(np.asarray(splits), row) == s).all()


def test_read_pads_born_resolved():
    """Dense read dispatch pads with the reserved ROUTE_LEFT sentinel (not
    the legal key 0): pad lanes terminate in round 0 under the lockstep
    walk (zero hops, no successor candidate) and pad-lane results are
    never gathered back into the batch."""
    from repro.core import layout
    from repro.core import deltatree as DT
    from repro.distributed import router as R
    from repro.kernels.ops import delta_walk
    from repro.kernels.veb_search import walk_big

    rng = np.random.default_rng(8)
    tcfg = TreeConfig(height=4, max_dnodes=256, buf_cap=8)
    vals = np.unique(rng.integers(1, 400, 150).astype(np.int32))
    t = DT.bulk_build(tcfg, vals)
    # sentinel lanes: born resolved — 0 hops, miss, no candidate — while
    # real lanes in the same batch walk normally
    q = np.concatenate([vals[:8], [layout.ROUTE_LEFT] * 5]).astype(np.int32)
    lv, _, _, hops, cand = delta_walk(t.value, t.child, t.root,
                                      jnp.asarray(q), height=4, q_tile=16)
    assert (np.asarray(hops)[-5:] == 0).all()
    assert (np.asarray(hops)[:8] > 0).all()
    assert (np.asarray(lv)[-5:] == 0).all()          # EMPTY: a miss
    assert (np.asarray(cand)[-5:] == walk_big(jnp.int32)).all()
    # router level: every dense pad lane carries the sentinel, and the
    # inverse permutation never reads one (poison check)
    splits = jnp.asarray([100, 200, 300], jnp.int32)
    keys = jnp.asarray(rng.integers(1, 120, size=32), jnp.int32)  # skewed
    r = R.route(splits, keys)
    dense = R.scatter_dense(r, 4, keys, jnp.int32(layout.ROUTE_LEFT))
    dense_np = np.asarray(dense)
    assert (dense_np == layout.ROUTE_LEFT).sum() == 4 * 32 - 32
    poison = jnp.where(dense == layout.ROUTE_LEFT, jnp.int32(-12345), dense)
    back = np.asarray(R.gather_batch(r, poison))
    assert (back != -12345).all()
    np.testing.assert_array_equal(back, np.asarray(keys))
    # forest level: lockstep per-shard hops through the padded dense rows
    # equal the single-tree hops (pads contribute no rounds, and results
    # are identical to the scalar reference)
    lcfg = TreeConfig(height=4, max_dnodes=256, buf_cap=8, engine="lockstep")
    fcfg_l = D.ForestConfig(num_shards=4, tree=lcfg, key_max=400, fused=False)
    fcfg_s = D.ForestConfig(num_shards=4, tree=tcfg, key_max=400, fused=False)
    f = D.bulk_build(fcfg_s, vals)
    q2 = jnp.asarray(rng.integers(0, 420, 64), jnp.int32)
    fl, hl = D.search_batch(fcfg_l, f, q2)
    fs, hs = D.search_batch(fcfg_s, f, q2)
    np.testing.assert_array_equal(np.asarray(fl), np.asarray(fs))
    np.testing.assert_array_equal(np.asarray(hl), np.asarray(hs))


def test_delta_walk_multi_root_seeding():
    """A (K,) root array seeds each query at its own arena root: walking
    a `fuse_arenas` view of two stacked arenas is bit-identical to two
    separate single-root walks."""
    from repro.core import deltatree as DT
    from repro.kernels.ops import delta_walk
    from repro.kernels.veb_search import fuse_arenas

    rng = np.random.default_rng(9)
    tcfg = TreeConfig(height=4, max_dnodes=128, buf_cap=8)
    vals_a = np.unique(rng.integers(1, 500, 120).astype(np.int32))
    vals_b = np.unique(rng.integers(500, 999, 120).astype(np.int32))
    ta, tb = DT.bulk_build(tcfg, vals_a), DT.bulk_build(tcfg, vals_b)
    qa = rng.integers(1, 500, 40).astype(np.int32)
    qb = rng.integers(500, 999, 40).astype(np.int32)
    value = jnp.stack([ta.value, tb.value])
    child = jnp.stack([ta.child, tb.child])
    root = jnp.stack([ta.root, tb.root])
    fv, fc, froots = fuse_arenas(value, child, root)
    lid = jnp.asarray([0] * 40 + [1] * 40, jnp.int32)
    q = jnp.asarray(np.concatenate([qa, qb]))
    fused = delta_walk(fv, fc, froots[lid], q, height=4, q_tile=16)
    ra = delta_walk(ta.value, ta.child, ta.root, jnp.asarray(qa),
                    height=4, q_tile=16)
    rb = delta_walk(tb.value, tb.child, tb.root, jnp.asarray(qb),
                    height=4, q_tile=16)
    m = int(ta.value.shape[0])
    for i, (a, b) in enumerate(zip(ra, rb)):
        one = np.concatenate([np.asarray(a), np.asarray(b)])
        got = np.asarray(fused[i])
        if i == 2:  # final_dn: arena-local ids shift by the shard base
            one = np.concatenate([np.asarray(a), np.asarray(b) + m])
        np.testing.assert_array_equal(got, one)


def test_forest_routes_int32_boundary_keys():
    """An out-of-int32-range probe (x64 caller) must clamp — not wrap —
    before routing: above-domain keys route right and report
    not-found/no-successor, below-domain keys report successor = global
    minimum (subprocess leg: int64 keys need JAX_ENABLE_X64)."""
    out = run_py("""
import numpy as np, jax.numpy as jnp
from repro.core import TreeConfig
import repro.distributed as D
from repro.distributed import router as R

vals = np.asarray([10, 150, 250, 380], np.int32)
hops_by_engine = {}
for engine, fused in (("scalar", False), ("lockstep", True)):
    fcfg = D.ForestConfig(
        num_shards=4, key_max=400, fused=fused,
        tree=TreeConfig(height=4, max_dnodes=64, buf_cap=8, engine=engine))
    f = D.bulk_build(fcfg, vals, splits=np.asarray([100, 200, 300]))
    q = jnp.asarray(np.array([2**31, 2**31 + 100, -5, 0, 2**31 - 2,
                              2**40, 150], np.int64))
    # routing happens on the pre-cast dtype: no wrap to shard 0
    sid = np.asarray(R.shard_ids(f.splits, q))
    assert (sid[[0, 1, 5]] == 3).all(), sid
    assert (sid[[2, 3]] == 0).all(), sid
    found, hops = D.search_batch(fcfg, f, q)
    hops_by_engine[engine] = np.asarray(hops)
    np.testing.assert_array_equal(
        np.asarray(found), [False, False, False, False, False, False, True])
    sf, sv = D.successor_jit(fcfg, f, q)
    np.testing.assert_array_equal(
        np.asarray(sf), [False, False, True, True, False, False, True])
    assert int(np.asarray(sv)[2]) == 10 and int(np.asarray(sv)[3]) == 10
    assert int(np.asarray(sv)[6]) == 250
    # updates share the boundary: out-of-domain keys are no-ops (False),
    # never wrapped inserts the clamped reads could not see
    uk = jnp.asarray(np.array([2**31 + 7, -3, 2**40, 30], np.int64))
    f, res, _ = D.update_batch(fcfg, f, jnp.full(4, 1, jnp.int32), uk)
    np.testing.assert_array_equal(np.asarray(res),
                                  [False, False, False, True])
    assert D.live_keys(fcfg, f).tolist() == [10, 30, 150, 250, 380]
# the engines' bit-identical hops contract holds for clamped sentinel
# probes too (both born resolved: 0 hops)
np.testing.assert_array_equal(hops_by_engine["scalar"],
                              hops_by_engine["lockstep"])
assert (hops_by_engine["scalar"][[0, 1, 5]] == 0).all()
print("BOUNDARY KEYS OK")
""", x64=True)
    assert "BOUNDARY KEYS OK" in out


def test_forest_mesh_tracks_device_count():
    """`router.forest_mesh` must not serve a stale cached mesh after the
    visible device count changes within the process (subprocess leg:
    needs a multi-device start state to observe shrinkage)."""
    out = run_py("""
import jax
from unittest import mock
from repro.distributed import router as R

assert jax.device_count() == 8
m8 = R.forest_mesh(4)
assert m8.devices.size == 4
assert R.forest_mesh(4) is m8           # same visibility: cached
with mock.patch.object(jax, "device_count", return_value=1):
    m1 = R.forest_mesh(4)
    assert m1.devices.size == 1, m1     # fresh mesh, not the stale one
assert R.forest_mesh(4) is m8           # original visibility: original mesh
print("MESH CACHE OK")
""", devices=8)
    assert "MESH CACHE OK" in out


def test_successor_cross_shard_fallback_corners():
    """Cross-shard successor corners vs the single-tree oracle, through
    every dispatch: owner shard empty, key greater than every live key
    (not found), and fallback landing several shards to the right."""
    from repro.core import successor_jit as core_succ

    vals = np.asarray([10, 20, 350, 360], np.int32)   # shards 1, 2 empty
    tcfg = TreeConfig(height=4, max_dnodes=64, buf_cap=8)
    t = core_empty(tcfg)
    t, _, _ = core_update(tcfg, t, jnp.full(4, 1, jnp.int32),
                          jnp.asarray(vals))
    q = jnp.asarray([150, 250, 25, 370, 360, 5, 20], jnp.int32)
    cf, cv = core_succ(tcfg, t, q)
    # oracle: owner-empty -> 350 (shards 1/2 empty), 25 -> 350 (fallback
    # lands 3 shards right), 370/360-upper -> not found, 5 -> 10, 20 -> 350
    np.testing.assert_array_equal(
        np.asarray(cf), [True, True, True, False, False, True, True])
    for engine, fused in (("scalar", False), ("scalar", True),
                          ("lockstep", False), ("lockstep", True)):
        fcfg = D.ForestConfig(
            num_shards=4, key_max=400, fused=fused,
            tree=dataclasses.replace(tcfg, engine=engine))
        f = D.bulk_build(fcfg, vals, splits=np.asarray([100, 200, 300]))
        assert D.live_keys(fcfg, f).tolist() == vals.tolist()
        sf, sv = D.successor_jit(fcfg, f, q)
        np.testing.assert_array_equal(np.asarray(sf), np.asarray(cf))
        np.testing.assert_array_equal(np.asarray(sv)[np.asarray(sf)],
                                      np.asarray(cv)[np.asarray(cf)])


def test_equidepth_splits_balance():
    rng = np.random.default_rng(1)
    # heavily skewed sample: uniform boundaries would starve 3 of 4 shards
    sample = np.concatenate([
        rng.integers(1, 100, size=900),
        rng.integers(1_000_000, 2_000_000, size=100),
    ])
    bnd = SP.equidepth_splits(sample, 4)
    assert bnd.shape == (3,) and (np.diff(bnd) > 0).all()
    counts = np.bincount(SP.shard_of_np(bnd, sample), minlength=4)
    assert counts.min() >= 0.15 * sample.size, counts
    # degenerate sample falls back to a valid equi-width partition
    bnd2 = SP.equidepth_splits(np.full(50, 7), 4, key_min=1, key_max=1000)
    assert bnd2.shape == (3,) and (np.diff(bnd2) > 0).all()


# --------------------------------------------- 1-shard == repro.core ------


def test_one_shard_forest_matches_core():
    tcfg = TreeConfig(height=4, max_dnodes=512, buf_cap=8)
    fcfg = D.ForestConfig(num_shards=1, tree=tcfg, key_max=200)
    f = D.empty(fcfg)
    t = core_empty(tcfg)
    rng = np.random.default_rng(2)
    for step in range(6):
        kinds, keys = _mixed_batch(rng, 20, 150)
        ff, fh = D.search_batch(fcfg, f, jnp.asarray(keys))
        tf, th = search_jit(tcfg, t, jnp.asarray(keys))
        np.testing.assert_array_equal(np.asarray(ff), np.asarray(tf))
        np.testing.assert_array_equal(np.asarray(fh), np.asarray(th))
        f, fres, _ = D.update_batch(fcfg, f, jnp.asarray(kinds),
                                    jnp.asarray(keys))
        t, tres, _ = core_update(tcfg, t, jnp.asarray(kinds),
                                 jnp.asarray(keys))
        np.testing.assert_array_equal(np.asarray(fres), np.asarray(tres))
        np.testing.assert_array_equal(
            D.live_keys(fcfg, f), core_live_keys(tcfg, t))
    q = jnp.asarray(rng.integers(0, 160, size=40), jnp.int32)
    sf, sv = D.successor_jit(fcfg, f, q)
    cf, cv = core_successor(tcfg, t, q)
    np.testing.assert_array_equal(np.asarray(sf), np.asarray(cf))
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(cv))


# ------------------------------------------- S>1 == single-tree oracle ----


def test_multishard_forest_matches_single_tree():
    tcfg = TreeConfig(height=4, max_dnodes=256, buf_cap=8)
    big = TreeConfig(height=4, max_dnodes=1024, buf_cap=8)
    fcfg = D.ForestConfig(num_shards=4, tree=tcfg, key_max=400)
    f = D.empty(fcfg)
    t = core_empty(big)
    oracle = SetOracle()
    rng = np.random.default_rng(3)
    for step in range(6):
        kinds, keys = _mixed_batch(rng, 24, 300)
        found, _ = D.search_batch(fcfg, f, jnp.asarray(keys))
        assert (np.asarray(found) == oracle.snapshot_search(keys)).all()
        f, fres, _ = D.update_batch(fcfg, f, jnp.asarray(kinds),
                                    jnp.asarray(keys))
        t, tres, _ = core_update(big, t, jnp.asarray(kinds),
                                 jnp.asarray(keys))
        exp = oracle.apply_updates(kinds, keys)
        np.testing.assert_array_equal(np.asarray(fres), exp)
        np.testing.assert_array_equal(np.asarray(fres), np.asarray(tres))
        # bit-identical sorted live key set, forest vs single tree
        np.testing.assert_array_equal(
            D.live_keys(fcfg, f), core_live_keys(big, t))
    assert not D.alloc_failed(f)
    # cross-shard successor fall-through
    live = oracle.keys()
    q = rng.integers(0, 420, size=64).astype(np.int32)
    sf, sv = D.successor_jit(fcfg, f, jnp.asarray(q))
    idx = np.searchsorted(live, q, side="right")
    ef = idx < live.size
    es = np.where(ef, live[np.minimum(idx, live.size - 1)], 0)
    np.testing.assert_array_equal(np.asarray(sf), ef)
    np.testing.assert_array_equal(np.asarray(sv)[ef], es[ef])


def test_bulk_build_equidepth_and_rebalance():
    # arena sized so even the deliberately-skewed build (all keys in one
    # shard) fits: 2000 keys / half_cap=8 -> ~250 leaf ΔNodes + interior
    tcfg = TreeConfig(height=5, max_dnodes=512, buf_cap=8)
    fcfg = D.ForestConfig(num_shards=4, tree=tcfg)
    rng = np.random.default_rng(4)
    vals = np.unique(rng.integers(1, 10_000, size=2000).astype(np.int32))
    f = D.bulk_build(fcfg, vals)
    np.testing.assert_array_equal(D.live_keys(fcfg, f), vals.astype(np.int64))
    counts = SP.shard_counts(fcfg, f)
    assert counts.sum() == vals.size
    assert counts.max() <= 1.5 * counts.mean()  # equi-depth build balances
    f2, hops = D.search_batch(fcfg, f, jnp.asarray(vals[:128]))
    assert bool(np.asarray(f2).all())
    # skewed forest -> rebalance restores balance and preserves the key set
    skewed = D.bulk_build(fcfg, vals, splits=np.asarray([9990, 9994, 9997]))
    assert SP.needs_rebalance(fcfg, skewed)
    fixed = SP.rebalance(fcfg, skewed)
    assert not SP.needs_rebalance(fcfg, fixed)
    np.testing.assert_array_equal(D.live_keys(fcfg, fixed),
                                  vals.astype(np.int64))


# ------------------------------------------------ shard_map (8 devices) ---


def test_forest_shard_map_8_devices():
    out = run_py("""
import jax, numpy as np, jax.numpy as jnp
assert jax.device_count() == 8, jax.devices()
from repro.core import TreeConfig
from repro.core.oracle import SetOracle
import repro.distributed as D
from repro.distributed.router import forest_mesh

fcfg = D.ForestConfig(num_shards=4,
                      tree=TreeConfig(height=4, max_dnodes=256, buf_cap=8),
                      key_max=300)
assert forest_mesh(4).devices.size == 4   # real multi-device shard_map
f = D.empty(fcfg)
oracle = SetOracle()
rng = np.random.default_rng(5)
for step in range(5):
    kinds = rng.integers(1, 3, size=16).astype(np.int32)
    keys = rng.integers(1, 250, size=16).astype(np.int32)
    found, _ = D.search_batch(fcfg, f, jnp.asarray(keys))
    assert (np.asarray(found) == oracle.snapshot_search(keys)).all()
    f, res, _ = D.update_batch(fcfg, f, jnp.asarray(kinds), jnp.asarray(keys))
    assert (np.asarray(res) == oracle.apply_updates(kinds, keys)).all()
    assert (D.live_keys(fcfg, f) == oracle.keys()).all()
live = oracle.keys()
q = rng.integers(0, 320, size=32).astype(np.int32)
sf, sv = D.successor_jit(fcfg, f, jnp.asarray(q))
idx = np.searchsorted(live, q, side="right")
ef = idx < live.size
es = np.where(ef, live[np.minimum(idx, live.size - 1)], 0)
np.testing.assert_array_equal(np.asarray(sf), ef)
np.testing.assert_array_equal(np.asarray(sv)[ef], es[ef])
print("FOREST SHARD_MAP OK")
""", devices=8)
    assert "FOREST SHARD_MAP OK" in out


# --------------------------------------------------- map mode (x64) -------


def test_forest_map_mode_x64():
    out = run_py("""
import numpy as np, jax.numpy as jnp
from repro.core import TreeConfig
from repro.core.oracle import MapOracle
import repro.distributed as D

fcfg = D.ForestConfig(
    num_shards=4,
    tree=TreeConfig(height=4, max_dnodes=256, buf_cap=8, payload_bits=8),
    key_max=500)
f = D.empty(fcfg)
oracle = MapOracle()
rng = np.random.default_rng(6)
for step in range(5):
    kinds = rng.integers(1, 3, size=16).astype(np.int32)
    keys = rng.integers(1, 400, size=16).astype(np.int32)
    pays = rng.integers(0, 255, size=16).astype(np.int32)
    found, pay, _ = D.lookup_batch(fcfg, f, jnp.asarray(keys))
    ef, ep = oracle.snapshot_lookup(keys)
    assert (np.asarray(found) == ef).all()
    assert (np.asarray(pay)[ef] == ep[ef]).all()
    f, res, _ = D.update_batch(fcfg, f, jnp.asarray(kinds),
                               jnp.asarray(keys), jnp.asarray(pays))
    oracle.apply_updates(kinds, keys, pays)
    assert D.live_items(fcfg, f) == oracle.items(), step
print("FOREST MAP MODE OK")
""", x64=True)
    assert "FOREST MAP MODE OK" in out


def test_sharded_pager_x64_8_devices():
    out = run_py("""
import numpy as np
from repro.serving import ShardedDeltaPager, ShardedPagerConfig

pc = ShardedPagerConfig(num_pages=128, page_size=4, max_seqs=32,
                        max_blocks=64, tree_height=4, num_shards=4)
pg = ShardedDeltaPager(pc)
p0 = pg.allocate(0, 3)
p1 = pg.allocate(9, 2)          # different shard band than seq 0
assert len(set(p0) | set(p1)) == 5
bt = pg.block_tables([0, 9], 4)
assert (bt[0, :3] == p0).all() and bt[0, 3] == -1
assert (bt[1, :2] == p1).all() and (bt[1, 2:] == -1).all()
p0b = pg.allocate(0, 2)
bt = pg.block_tables([0], 5)
assert (bt[0] == p0 + p0b).all()
pg.free_seq(0)
assert len(pg.free_pages) == 128 - 2
bt = pg.block_tables([0, 9], 4)
assert (bt[0] == -1).all()
pg.free_seq(9)
assert sorted(pg.free_pages) == list(range(128))
print("SHARDED PAGER OK", pg.stats)
""", devices=8, x64=True)
    assert "SHARDED PAGER OK" in out
