"""DeltaForest equivalence: routed sharded forest == single ΔTree / oracle.

In-process tests run on the default single CPU device (the "shards" mesh
degenerates to vmap); subprocess tests exercise real shard_map over 8 fake
host devices and the x64 map-mode / sharded-pager paths.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import TreeConfig, live_keys as core_live_keys
from repro.core import empty as core_empty
from repro.core import search_jit, successor_jit as core_successor
from repro.core import update_batch as core_update
from repro.core.oracle import SetOracle
import repro.distributed as D
from repro.distributed import splits as SP
from tests._subproc import run_py


def _mixed_batch(rng, k, key_hi):
    kinds = rng.integers(1, 3, size=k).astype(np.int32)
    keys = rng.integers(1, key_hi, size=k).astype(np.int32)
    return kinds, keys


# ---------------------------------------------------------------- router ---


def test_router_roundtrip():
    from repro.distributed import router as R

    rng = np.random.default_rng(0)
    splits = jnp.asarray([50, 100, 150], jnp.int32)
    keys = jnp.asarray(rng.integers(1, 200, size=64), jnp.int32)
    r = R.route(splits, keys)
    # ownership matches the host-side partitioner
    np.testing.assert_array_equal(
        np.asarray(r.sid), SP.shard_of_np(np.asarray(splits), np.asarray(keys)))
    # scatter/gather is an exact inverse (padding never leaks through)
    dense = R.scatter_dense(r, 4, keys, jnp.int32(0))
    back = R.gather_batch(r, dense)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(keys))
    # each dense row only holds its own shard's keys (or padding)
    dense_np = np.asarray(dense)
    for s in range(4):
        row = dense_np[s][dense_np[s] != 0]
        assert (SP.shard_of_np(np.asarray(splits), row) == s).all()


def test_equidepth_splits_balance():
    rng = np.random.default_rng(1)
    # heavily skewed sample: uniform boundaries would starve 3 of 4 shards
    sample = np.concatenate([
        rng.integers(1, 100, size=900),
        rng.integers(1_000_000, 2_000_000, size=100),
    ])
    bnd = SP.equidepth_splits(sample, 4)
    assert bnd.shape == (3,) and (np.diff(bnd) > 0).all()
    counts = np.bincount(SP.shard_of_np(bnd, sample), minlength=4)
    assert counts.min() >= 0.15 * sample.size, counts
    # degenerate sample falls back to a valid equi-width partition
    bnd2 = SP.equidepth_splits(np.full(50, 7), 4, key_min=1, key_max=1000)
    assert bnd2.shape == (3,) and (np.diff(bnd2) > 0).all()


# --------------------------------------------- 1-shard == repro.core ------


def test_one_shard_forest_matches_core():
    tcfg = TreeConfig(height=4, max_dnodes=512, buf_cap=8)
    fcfg = D.ForestConfig(num_shards=1, tree=tcfg, key_max=200)
    f = D.empty(fcfg)
    t = core_empty(tcfg)
    rng = np.random.default_rng(2)
    for step in range(6):
        kinds, keys = _mixed_batch(rng, 20, 150)
        ff, fh = D.search_batch(fcfg, f, jnp.asarray(keys))
        tf, th = search_jit(tcfg, t, jnp.asarray(keys))
        np.testing.assert_array_equal(np.asarray(ff), np.asarray(tf))
        np.testing.assert_array_equal(np.asarray(fh), np.asarray(th))
        f, fres, _ = D.update_batch(fcfg, f, jnp.asarray(kinds),
                                    jnp.asarray(keys))
        t, tres, _ = core_update(tcfg, t, jnp.asarray(kinds),
                                 jnp.asarray(keys))
        np.testing.assert_array_equal(np.asarray(fres), np.asarray(tres))
        np.testing.assert_array_equal(
            D.live_keys(fcfg, f), core_live_keys(tcfg, t))
    q = jnp.asarray(rng.integers(0, 160, size=40), jnp.int32)
    sf, sv = D.successor_jit(fcfg, f, q)
    cf, cv = core_successor(tcfg, t, q)
    np.testing.assert_array_equal(np.asarray(sf), np.asarray(cf))
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(cv))


# ------------------------------------------- S>1 == single-tree oracle ----


def test_multishard_forest_matches_single_tree():
    tcfg = TreeConfig(height=4, max_dnodes=256, buf_cap=8)
    big = TreeConfig(height=4, max_dnodes=1024, buf_cap=8)
    fcfg = D.ForestConfig(num_shards=4, tree=tcfg, key_max=400)
    f = D.empty(fcfg)
    t = core_empty(big)
    oracle = SetOracle()
    rng = np.random.default_rng(3)
    for step in range(6):
        kinds, keys = _mixed_batch(rng, 24, 300)
        found, _ = D.search_batch(fcfg, f, jnp.asarray(keys))
        assert (np.asarray(found) == oracle.snapshot_search(keys)).all()
        f, fres, _ = D.update_batch(fcfg, f, jnp.asarray(kinds),
                                    jnp.asarray(keys))
        t, tres, _ = core_update(big, t, jnp.asarray(kinds),
                                 jnp.asarray(keys))
        exp = oracle.apply_updates(kinds, keys)
        np.testing.assert_array_equal(np.asarray(fres), exp)
        np.testing.assert_array_equal(np.asarray(fres), np.asarray(tres))
        # bit-identical sorted live key set, forest vs single tree
        np.testing.assert_array_equal(
            D.live_keys(fcfg, f), core_live_keys(big, t))
    assert not D.alloc_failed(f)
    # cross-shard successor fall-through
    live = oracle.keys()
    q = rng.integers(0, 420, size=64).astype(np.int32)
    sf, sv = D.successor_jit(fcfg, f, jnp.asarray(q))
    idx = np.searchsorted(live, q, side="right")
    ef = idx < live.size
    es = np.where(ef, live[np.minimum(idx, live.size - 1)], 0)
    np.testing.assert_array_equal(np.asarray(sf), ef)
    np.testing.assert_array_equal(np.asarray(sv)[ef], es[ef])


def test_bulk_build_equidepth_and_rebalance():
    # arena sized so even the deliberately-skewed build (all keys in one
    # shard) fits: 2000 keys / half_cap=8 -> ~250 leaf ΔNodes + interior
    tcfg = TreeConfig(height=5, max_dnodes=512, buf_cap=8)
    fcfg = D.ForestConfig(num_shards=4, tree=tcfg)
    rng = np.random.default_rng(4)
    vals = np.unique(rng.integers(1, 10_000, size=2000).astype(np.int32))
    f = D.bulk_build(fcfg, vals)
    np.testing.assert_array_equal(D.live_keys(fcfg, f), vals.astype(np.int64))
    counts = SP.shard_counts(fcfg, f)
    assert counts.sum() == vals.size
    assert counts.max() <= 1.5 * counts.mean()  # equi-depth build balances
    f2, hops = D.search_batch(fcfg, f, jnp.asarray(vals[:128]))
    assert bool(np.asarray(f2).all())
    # skewed forest -> rebalance restores balance and preserves the key set
    skewed = D.bulk_build(fcfg, vals, splits=np.asarray([9990, 9994, 9997]))
    assert SP.needs_rebalance(fcfg, skewed)
    fixed = SP.rebalance(fcfg, skewed)
    assert not SP.needs_rebalance(fcfg, fixed)
    np.testing.assert_array_equal(D.live_keys(fcfg, fixed),
                                  vals.astype(np.int64))


# ------------------------------------------------ shard_map (8 devices) ---


def test_forest_shard_map_8_devices():
    out = run_py("""
import jax, numpy as np, jax.numpy as jnp
assert jax.device_count() == 8, jax.devices()
from repro.core import TreeConfig
from repro.core.oracle import SetOracle
import repro.distributed as D
from repro.distributed.router import forest_mesh

fcfg = D.ForestConfig(num_shards=4,
                      tree=TreeConfig(height=4, max_dnodes=256, buf_cap=8),
                      key_max=300)
assert forest_mesh(4).devices.size == 4   # real multi-device shard_map
f = D.empty(fcfg)
oracle = SetOracle()
rng = np.random.default_rng(5)
for step in range(5):
    kinds = rng.integers(1, 3, size=16).astype(np.int32)
    keys = rng.integers(1, 250, size=16).astype(np.int32)
    found, _ = D.search_batch(fcfg, f, jnp.asarray(keys))
    assert (np.asarray(found) == oracle.snapshot_search(keys)).all()
    f, res, _ = D.update_batch(fcfg, f, jnp.asarray(kinds), jnp.asarray(keys))
    assert (np.asarray(res) == oracle.apply_updates(kinds, keys)).all()
    assert (D.live_keys(fcfg, f) == oracle.keys()).all()
live = oracle.keys()
q = rng.integers(0, 320, size=32).astype(np.int32)
sf, sv = D.successor_jit(fcfg, f, jnp.asarray(q))
idx = np.searchsorted(live, q, side="right")
ef = idx < live.size
es = np.where(ef, live[np.minimum(idx, live.size - 1)], 0)
np.testing.assert_array_equal(np.asarray(sf), ef)
np.testing.assert_array_equal(np.asarray(sv)[ef], es[ef])
print("FOREST SHARD_MAP OK")
""", devices=8)
    assert "FOREST SHARD_MAP OK" in out


# --------------------------------------------------- map mode (x64) -------


def test_forest_map_mode_x64():
    out = run_py("""
import numpy as np, jax.numpy as jnp
from repro.core import TreeConfig
from repro.core.oracle import MapOracle
import repro.distributed as D

fcfg = D.ForestConfig(
    num_shards=4,
    tree=TreeConfig(height=4, max_dnodes=256, buf_cap=8, payload_bits=8),
    key_max=500)
f = D.empty(fcfg)
oracle = MapOracle()
rng = np.random.default_rng(6)
for step in range(5):
    kinds = rng.integers(1, 3, size=16).astype(np.int32)
    keys = rng.integers(1, 400, size=16).astype(np.int32)
    pays = rng.integers(0, 255, size=16).astype(np.int32)
    found, pay, _ = D.lookup_batch(fcfg, f, jnp.asarray(keys))
    ef, ep = oracle.snapshot_lookup(keys)
    assert (np.asarray(found) == ef).all()
    assert (np.asarray(pay)[ef] == ep[ef]).all()
    f, res, _ = D.update_batch(fcfg, f, jnp.asarray(kinds),
                               jnp.asarray(keys), jnp.asarray(pays))
    oracle.apply_updates(kinds, keys, pays)
    assert D.live_items(fcfg, f) == oracle.items(), step
print("FOREST MAP MODE OK")
""", x64=True)
    assert "FOREST MAP MODE OK" in out


def test_sharded_pager_x64_8_devices():
    out = run_py("""
import numpy as np
from repro.serving import ShardedDeltaPager, ShardedPagerConfig

pc = ShardedPagerConfig(num_pages=128, page_size=4, max_seqs=32,
                        max_blocks=64, tree_height=4, num_shards=4)
pg = ShardedDeltaPager(pc)
p0 = pg.allocate(0, 3)
p1 = pg.allocate(9, 2)          # different shard band than seq 0
assert len(set(p0) | set(p1)) == 5
bt = pg.block_tables([0, 9], 4)
assert (bt[0, :3] == p0).all() and bt[0, 3] == -1
assert (bt[1, :2] == p1).all() and (bt[1, 2:] == -1).all()
p0b = pg.allocate(0, 2)
bt = pg.block_tables([0], 5)
assert (bt[0] == p0 + p0b).all()
pg.free_seq(0)
assert len(pg.free_pages) == 128 - 2
bt = pg.block_tables([0, 9], 4)
assert (bt[0] == -1).all()
pg.free_seq(9)
assert sorted(pg.free_pages) == list(range(128))
print("SHARDED PAGER OK", pg.stats)
""", devices=8, x64=True)
    assert "SHARDED PAGER OK" in out
